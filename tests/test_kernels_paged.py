"""Paged decode attention vs the dense-gather oracle (DESIGN.md §15).

Standalone from test_kernels.py (which importorskips hypothesis) so the
paged parity sweep always runs in tier-1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
# ------------------------------------------------- paged decode attention ---
from repro.kernels.decode_attention.ops import (  # noqa: E402
    paged_decode_attention,
    paged_decode_attention_chunked,
    resolve_interpret,
)
from repro.kernels.decode_attention.ref import (  # noqa: E402
    gather_paged_kv,
    paged_decode_attention_ref,
)


def _paged_case(seed, B, NB, BS, KVH, H, hd, n_pages=None):
    """Random paged layout with shuffled block tables, sentinel tails and
    mixed per-row kv_len (some rows not spanning all their blocks)."""
    rng = np.random.default_rng(seed)
    P = n_pages or B * NB + 3          # spare pages the tables never touch
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, BS, KVH, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, BS, KVH, hd)), jnp.float32)
    perm = rng.permutation(P)[:B * NB].reshape(B, NB)
    kv_len = rng.integers(1, NB * BS + 1, B).astype(np.int32)
    tables = np.full((B, NB), P, np.int32)     # sentinel = P
    for b in range(B):
        nb = -(-int(kv_len[b]) // BS)
        tables[b, :nb] = perm[b, :nb]
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(kv_len)


@pytest.mark.parametrize("B,NB,BS,KVH,H,hd", [
    (3, 4, 16, 2, 4, 32),
    (1, 8, 8, 1, 8, 64),      # MQA, many small blocks
    (4, 2, 32, 4, 4, 16),     # MHA-ish, two big blocks
])
def test_paged_decode_pallas_matches_ref(B, NB, BS, KVH, H, hd):
    q, kp, vp, tables, kv_len = _paged_case(B * 10 + NB, B, NB, BS,
                                            KVH, H, hd)
    got = paged_decode_attention(q, kp, vp, tables, kv_len,
                                 impl="pallas", interpret=True)
    want = paged_decode_attention_ref(q, kp, vp, tables, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("ppc", [1, 2, 8])
def test_paged_decode_chunked_matches_ref(ppc):
    q, kp, vp, tables, kv_len = _paged_case(11, 3, 4, 16, 2, 4, 32)
    got = paged_decode_attention_chunked(q, kp, vp, tables, kv_len,
                                         pages_per_chunk=ppc)
    want = paged_decode_attention_ref(q, kp, vp, tables, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_gather_matches_dense_layout():
    """gather_paged_kv of an identity-table pool is exactly the dense
    cache it was split from — the bit-parity bridge the serving engine
    relies on (DESIGN.md §15)."""
    rng = np.random.default_rng(5)
    B, S, KVH, hd, BS = 2, 64, 2, 32, 16
    dense = jnp.asarray(rng.normal(size=(B, S, KVH, hd)), jnp.float32)
    NB = S // BS
    pages = dense.reshape(B * NB, BS, KVH, hd)
    tables = jnp.arange(B * NB, dtype=jnp.int32).reshape(B, NB)
    got = gather_paged_kv(pages, pages, tables)[0]
    assert np.array_equal(np.asarray(got), np.asarray(dense))


def test_paged_decode_ref_ignores_sentinel_and_spare_pages():
    """Pages beyond kv_len (sentinel table tail + unreferenced spare
    pages) must not leak into the output: corrupting them changes
    nothing."""
    q, kp, vp, tables, kv_len = _paged_case(13, 2, 4, 8, 2, 4, 16)
    want = paged_decode_attention_ref(q, kp, vp, tables, kv_len)
    t = np.asarray(tables)
    used = set()
    for b in range(t.shape[0]):
        nb = -(-int(kv_len[b]) // 8)
        used.update(t[b, :nb].tolist())
    unused = [p for p in range(kp.shape[0]) if p not in used]
    assert unused, "case must leave some pages unreferenced"
    kp2 = kp.at[jnp.asarray(unused)].set(1e9)
    vp2 = vp.at[jnp.asarray(unused)].set(1e9)
    got = paged_decode_attention_ref(q, kp2, vp2, tables, kv_len)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_resolve_interpret_auto_default():
    """interpret=None auto-selects from the backend: compiled on TPU,
    interpreted elsewhere — so the TPU path runs the real kernel by
    default and CPU tests never try to compile Mosaic."""
    auto = resolve_interpret(None)
    assert auto == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # the default path must actually run on this backend
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
    lens = jnp.asarray([17], jnp.int32)
    got = decode_attention(q, k, v, lens)          # interpret unspecified
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
