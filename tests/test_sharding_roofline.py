"""Logical-axis sharding resolution + HLO roofline analyzer."""
import jax
import numpy as np

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    axis_rules,
    default_rules,
    shardings_like,
    spec_for,
)
from repro.launch.roofline import analyze_hlo

SCAN_HLO = """\
HloModule jit_h, is_scheduled=true

%fused_computation (param_0.1: f32[1,256,256]) -> f32[256,256] {
  %param_0.1 = f32[1,256,256]{2,0,1} parameter(0)
  ROOT %bitcast.1 = f32[256,256]{1,0} bitcast(%param_0.1)
}

%region_0.1_spmd (param: (s32[], f32[64,256], f32[10,64,256])) -> (s32[], f32[64,256], f32[10,64,256]) {
  %param = (s32[], f32[64,256]{1,0}, f32[10,64,256]{2,0,1}) parameter(0)
  %get-tuple-element.25 = f32[64,256]{1,0} get-tuple-element(%param), index=1
  %get-tuple-element.26 = f32[10,64,256]{2,0,1} get-tuple-element(%param), index=2
  %wrapped_dynamic-slice = f32[1,64,256]{2,0,1} dynamic-slice(%get-tuple-element.26), dynamic_slice_sizes={1,64,256}
  %all-gather = f32[1,256,256]{2,0,1} all-gather(%wrapped_dynamic-slice), channel_id=1, replica_groups=[1,4]<=[4], dimensions={1}
  %copy_bitcast_fusion = f32[256,256]{1,0} fusion(%all-gather), kind=kLoop, calls=%fused_computation
  %dot = f32[64,256]{1,0} dot(%get-tuple-element.25, %copy_bitcast_fusion), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tuple = (s32[], f32[64,256]{1,0}, f32[10,64,256]{2,0,1}) tuple(%get-tuple-element.25, %dot, %get-tuple-element.26)
}

ENTRY %main.3_spmd (param.2: f32[64,256], param.3: f32[10,64,256]) -> f32[64,256] {
  %param.2 = f32[64,256]{1,0} parameter(0)
  %param.3 = f32[10,64,256]{2,0,1} parameter(1)
  %tuple.6 = (s32[], f32[64,256]{1,0}, f32[10,64,256]{2,0,1}) tuple(%param.2, %param.2, %param.3)
  %while.8 = (s32[], f32[64,256]{1,0}, f32[10,64,256]{2,0,1}) while(%tuple.6), condition=%region_1.2_spmd, body=%region_0.1_spmd, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %get-tuple-element.30 = f32[64,256]{1,0} get-tuple-element(%while.8), index=1
}
"""


def test_spec_resolution_and_taken_axes(make_auto_mesh):
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    rules = default_rules(multi_pod=False)
    # heads -> model; second use of model in the same spec is dropped
    s = spec_for(("embed", "heads"), rules, mesh)
    assert s == P("data", "model")
    s2 = spec_for(("heads", "mlp"), rules, mesh)
    assert s2 == P("model", None)  # mlp loses: model already taken
    # pod axis silently dropped on a single-pod mesh
    rules_mp = default_rules(multi_pod=True)
    s3 = spec_for(("batch",), rules_mp, mesh)
    assert s3 == P("data")


def test_logical_constraint_noop_without_rules():
    import jax.numpy as jnp
    from repro.distributed.sharding import logical_constraint
    x = jnp.ones((4, 4))
    y = logical_constraint(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shardings_like_tuple_leaves(make_auto_mesh):
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    rules = default_rules()
    template = {"w": jax.ShapeDtypeStruct((8, 8), np.float32),
                "inner": {"b": jax.ShapeDtypeStruct((8,), np.float32)}}
    specs = {"w": ("embed", "mlp"), "inner": {"b": (None,)}}
    sh = shardings_like(template, specs, rules, mesh)
    assert sh["w"].spec == P("data", "model")
    assert sh["inner"]["b"].spec == P(None)


def test_analyzer_trip_scaling_and_collectives():
    a = analyze_hlo(SCAN_HLO)
    assert a.flops == 10 * 2 * 64 * 256 * 256          # dot x10 trips
    assert a.bytes_collective == 10 * 1 * 64 * 256 * 4  # all-gather operand
    assert a.coll_breakdown["all-gather"] == a.bytes_collective
    assert a.unresolved_dots == 0


def test_analyzer_skips_fusion_internals_for_bytes():
    a = analyze_hlo(SCAN_HLO)
    # bytes are counted at fusion boundaries only; the bitcast inside
    # %fused_computation must not be double counted. The fusion op itself
    # (result 256KB + operand 256KB) x 10 trips is included:
    assert a.bytes_hbm >= 10 * 2 * 256 * 256 * 4
    # and nothing from inside the fused computation:
    assert a.bytes_hbm < 60 * 1024 * 1024


@pytest.mark.parametrize("shape,expect", [
    ("f32[2,3]", 24), ("bf16[128]", 256), ("pred[8]", 8), ("s32[]", 4)])
def test_shape_bytes(shape, expect):
    from repro.launch.roofline import _shapes_in, _nbytes_many
    assert _nbytes_many(_shapes_in(shape)) == expect
