"""Seeded parity suite for the vectorized non-dominated sort.

Complements the hypothesis property test in test_pareto.py (which skips when
hypothesis is unavailable) with deterministic coverage that always runs:
random clouds, duplicated rows, degenerate columns, and many-front chains.
"""
import numpy as np
import pytest

from repro.core.pareto import (
    domination_matrix,
    dominates,
    non_dominated_sort,
    non_dominated_sort_reference,
)


def _assert_same_fronts(pts):
    ref = non_dominated_sort_reference(pts)
    vec = non_dominated_sort(pts)
    assert len(ref) == len(vec)
    for a, b in zip(ref, vec):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("m", [1, 2, 3, 9])
def test_random_clouds_match_reference(seed, m):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(rng.integers(1, 60), m))
    _assert_same_fronts(pts)


def test_duplicates_and_degenerate_columns():
    rng = np.random.default_rng(99)
    pts = rng.integers(0, 3, size=(50, 4)).astype(np.float64)  # many ties
    pts[:, 2] = 7.0  # constant objective
    pts[10:20] = pts[:10]  # exact duplicate rows
    _assert_same_fronts(pts)


def test_total_order_chain_yields_singleton_fronts():
    # strictly improving chain: every point is its own front
    pts = np.arange(30, dtype=np.float64)[:, None].repeat(3, axis=1)
    fronts = non_dominated_sort(pts)
    assert len(fronts) == 30
    assert all(len(f) == 1 for f in fronts)
    _assert_same_fronts(pts)


def test_empty_and_single():
    assert non_dominated_sort(np.zeros((0, 3))) == []
    fronts = non_dominated_sort(np.asarray([[1.0, 2.0]]))
    assert len(fronts) == 1 and fronts[0].tolist() == [0]


def test_domination_matrix_chunking_and_semantics():
    rng = np.random.default_rng(7)
    pts = rng.normal(size=(33, 5))
    dom = domination_matrix(pts, row_chunk=8)  # chunk smaller than n
    for i in range(len(pts)):
        for j in range(len(pts)):
            assert dom[i, j] == dominates(pts[i], pts[j])


def test_domination_matrices_subset_views_match_direct():
    """The shared-pass subset matrices (multi-platform / goal-conditioned
    fronts) must equal a direct domination_matrix over the sliced points."""
    from repro.core.pareto import domination_matrices
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(70, 9))
    groups = [np.arange(9), np.asarray([0, 3, 5]), np.asarray([7, 8]),
              np.asarray([2])]
    doms = domination_matrices(pts, groups, row_chunk=16)
    for g, dom in zip(groups, doms):
        np.testing.assert_array_equal(dom, domination_matrix(pts[:, g]))
    with pytest.raises(ValueError):
        domination_matrices(pts, [np.arange(9), np.asarray([], np.int64)])
