"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.conv1d.ops import dwsep_conv1d
from repro.kernels.conv1d.ref import dwsep_conv1d_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- conv1d ---
@pytest.mark.parametrize("B,L,Cin,K,Cout,S", [
    (2, 64, 2, 5, 8, 1),
    (1, 200, 8, 3, 16, 2),
    (3, 97, 4, 7, 32, 4),
    (2, 50, 16, 1, 2, 1),
    (1, 33, 2, 3, 130, 1),     # C_out > one lane block -> multi-block grid
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_matches_ref(B, L, Cin, K, Cout, S, dtype):
    x = jnp.asarray(RNG.normal(size=(B, L, Cin)), dtype)
    dw = jnp.asarray(RNG.normal(size=(K, Cin)), dtype)
    pw = jnp.asarray(RNG.normal(size=(Cin, Cout)), dtype)
    b = jnp.asarray(RNG.normal(size=(Cout,)), dtype)
    got = dwsep_conv1d(x, dw, pw, b, stride=S, interpret=True)
    want = dwsep_conv1d_ref(x, dw, pw, b, stride=S)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(k=st.sampled_from([1, 3, 5, 7]), s=st.sampled_from([1, 2, 4]),
       cin=st.sampled_from([2, 4, 8]), cout=st.sampled_from([2, 8, 32]))
@settings(max_examples=12, deadline=None)
def test_conv1d_hypothesis_sweep(k, s, cin, cout):
    rng = np.random.default_rng(k * 100 + s * 10 + cin + cout)
    L = 64
    x = jnp.asarray(rng.normal(size=(1, L, cin)), jnp.float32)
    dw = jnp.asarray(rng.normal(size=(k, cin)), jnp.float32)
    pw = jnp.asarray(rng.normal(size=(cin, cout)), jnp.float32)
    b = jnp.zeros((cout,), jnp.float32)
    got = dwsep_conv1d(x, dw, pw, b, stride=s, interpret=True)
    want = dwsep_conv1d_ref(x, dw, pw, b, stride=s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- flash attention ---
@pytest.mark.parametrize("B,S,H,KVH,hd,causal", [
    (2, 64, 4, 2, 32, True),
    (1, 128, 8, 1, 64, True),     # MQA
    (2, 96, 6, 6, 16, False),     # MHA bidirectional
    (1, 256, 4, 4, 128, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, S, H, KVH, hd, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, KVH, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, KVH, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_chunked_jnp_attention_matches_ref():
    from repro.models.attention import chunked_attention
    q = jnp.asarray(RNG.normal(size=(2, 96, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 96, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 96, 2, 32)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, chunk=16)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------------- ssd ---
@pytest.mark.parametrize("B,L,H,P,G,N,Q", [
    (2, 64, 4, 16, 1, 16, 16),
    (1, 128, 8, 32, 2, 32, 32),
    (2, 96, 6, 8, 3, 8, 24),
])
def test_ssd_kernel_and_chunked_match_naive(B, L, H, P, G, N, Q):
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, size=(B, L, H)), jnp.float32)
    a_neg = -jnp.asarray(RNG.uniform(1, 8, size=(H,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    want, state_ref = ssd_ref(x, dt, a_neg, bm, cm)
    got_pallas = ssd(x, dt, a_neg, bm, cm, chunk=Q, interpret=True)
    got_jnp, state_jnp = ssd_chunked(x, dt, a_neg, bm, cm, Q)
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_jnp), np.asarray(state_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_matches_sequence():
    """Step-by-step decode must reproduce the chunked full-sequence output."""
    from repro.models.mamba2 import ssd_decode_step
    B, L, H, P, G, N = 1, 16, 2, 8, 1, 8
    x = jnp.asarray(RNG.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, size=(B, L, H)), jnp.float32)
    a_neg = -jnp.asarray(RNG.uniform(1, 8, size=(H,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(B, L, G, N)), jnp.float32)
    full, _ = ssd_ref(x, dt, a_neg, bm, cm)
    state = jnp.zeros((B, H, N, P), jnp.float32)
    outs = []
    for t in range(L):
        y, state = ssd_decode_step(x[:, t:t+1], dt[:, t:t+1], a_neg,
                                   bm[:, t:t+1], cm[:, t:t+1], state)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- moe gmm ---
@pytest.mark.parametrize("E,C,D,F", [(4, 32, 64, 48), (8, 16, 128, 64),
                                     (2, 64, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_matches_ref(E, C, D, F, dtype):
    x = jnp.asarray(RNG.normal(size=(E, C, D)), dtype)
    w = jnp.asarray(RNG.normal(size=(E, D, F)), dtype)
    got = gmm(x, w, interpret=True, block_c=16, block_f=16, block_d=32)
    want = gmm_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ------------------------------------------------------- decode attention ---
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@pytest.mark.parametrize("B,S,H,KVH,hd,bk", [
    (2, 128, 4, 2, 32, 32),
    (1, 256, 8, 1, 64, 64),     # MQA, long cache
    (3, 64, 6, 6, 16, 16),      # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, S, H, KVH, hd, bk, dtype):
    rng = np.random.default_rng(B * 100 + S)
    q = jnp.asarray(rng.normal(size=(B, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KVH, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KVH, hd)), dtype)
    lens = jnp.asarray(rng.integers(1, S, B), jnp.int32)
    got = decode_attention(q, k, v, lens, interpret=True, block_k=bk)
    want = decode_attention_ref(q, k, v, lens)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_empty_blocks_skipped():
    """kv_len=1 in a long cache: only block 0 contributes (block-skip path)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    lens = jnp.asarray([1], jnp.int32)
    got = decode_attention(q, k, v, lens, interpret=True, block_k=32)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
