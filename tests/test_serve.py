"""Continuous-batching serving engine (repro.serve) — DESIGN.md §12.

The load-bearing property is *bit-identical greedy parity*: every request
served through the slotted engine (bucketed prefill, mixed lengths in
flight, slot reuse) must produce exactly the tokens a scalar one-request
decode produces.  Everything else — admission, buckets, stats — is tested
around that invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.registry import build_model
from repro.serve import (
    EngineConfig,
    ServeEngine,
    ServeRequest,
    build_buckets,
    greedy_reference,
    latency_stats,
    poisson_workload,
)
from repro.serve.buckets import pad_batch, pad_length

CACHE_LEN = 48


def _bundle(arch):
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _requests(cfg, lens_out, seed=0):
    """Mixed (prompt_len, max_new) pairs as a burst workload."""
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i,
                         prompt=rng.integers(0, cfg.vocab_size, pl).astype(
                             np.int32),
                         max_new=mn)
            for i, (pl, mn) in enumerate(lens_out)]


def _refs(bundle, params, reqs):
    dec = jax.jit(bundle.decode_step)
    return {r.rid: greedy_reference(bundle, params, r.prompt, r.max_new,
                                    CACHE_LEN, decode_jit=dec)
            for r in reqs}


# ---------------------------------------------------------------- buckets


def test_pad_helpers():
    assert [pad_length(n, 8) for n in (1, 8, 9, 24)] == [8, 8, 16, 24]
    assert [pad_length(n, 1) for n in (1, 7)] == [1, 7]
    assert [pad_batch(n, 8) for n in (1, 2, 3, 5, 8, 11)] == \
        [1, 2, 4, 8, 8, 8]


def test_build_buckets_groups_and_pads():
    prompts = [np.arange(n, dtype=np.int32) for n in (3, 5, 9, 11, 20)]
    buckets = build_buckets(prompts, slots=[0, 1, 2, 3, 4], n_slots=8,
                            pad_to=8, max_batch=4)
    # padded lengths: 8,8,16,16,24 -> three buckets
    by_len = {b.tokens.shape[1]: b for b in buckets}
    assert set(by_len) == {8, 16, 24}
    assert list(by_len[8].lens) == [3, 5]
    # batch rows are padded to powers of two; pad rows scatter out of range
    assert by_len[8].tokens.shape[0] == 2
    b16 = by_len[16]
    assert b16.tokens.shape[0] == 2 and list(b16.slot_idx) == [2, 3]
    # right padding is zeros beyond each row's length
    assert not by_len[8].tokens[0, 3:].any()


def test_build_buckets_chunks_to_max_batch():
    prompts = [np.arange(4, dtype=np.int32)] * 10
    buckets = build_buckets(prompts, slots=list(range(10)), n_slots=16,
                            pad_to=4, max_batch=4)
    assert [len(b.rows) for b in buckets] == [4, 4, 2]
    # every original row appears exactly once across chunks
    assert sorted(i for b in buckets for i in b.rows) == list(range(10))


# ------------------------------------------------------- engine bit-parity


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-7b"])
def test_engine_greedy_parity(arch):
    """Burst workload with mixed prompt/output lengths: every request's
    greedy tokens are bit-identical to the scalar one-request reference."""
    cfg, bundle, params = _bundle(arch)
    reqs = _requests(cfg, [(4, 6), (11, 3), (7, 9), (16, 5), (5, 5),
                           (9, 8), (13, 4), (6, 7), (20, 3), (8, 6)])
    refs = _refs(bundle, params, reqs)
    pad_to = 8 if bundle.prefill_pads else 1
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=pad_to, max_prefill_batch=4))
    done = engine.run(reqs)
    assert len(done) == len(reqs)
    for r in done:
        assert r.out == refs[r.rid], f"req {r.rid} diverged"
    # 10 requests through 4 slots exercises slot reuse
    assert engine.prefill_calls >= 3


def test_engine_per_slot_length_independence():
    """Slots at wildly different sequence positions decode together —
    the fix for the shared ``cache['len']`` scalar."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(3, 20), (30, 2), (12, 10), (25, 16)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run(reqs)
    for r in done:
        assert r.out == refs[r.rid]
    # the longest-running request kept decoding after the others finished
    assert engine.decode_steps >= 19


def test_engine_mid_flight_admission():
    """Virtual-clock arrivals land while earlier requests are mid-decode:
    no wave barrier, and parity still holds."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(6, 12), (9, 12), (7, 10), (5, 8), (11, 6)])
    refs = _refs(bundle, params, reqs)
    # slots=2: rids 0,1 admitted at t=0; the rest arrive mid-decode
    for r, arr in zip(reqs, [0.0, 0.0, 3.0, 4.0, 5.0]):
        r.arrival_s = arr
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run(reqs)
    for r in done:
        assert r.out == refs[r.rid]
    admits = sorted(r.t_admit for r in done)
    assert admits[0] == 0.0
    # at least one admission happened strictly mid-run (after decode began)
    assert admits[-1] > 0.0


def test_engine_slot_reuse_many_requests():
    """3x more requests than slots: every slot is recycled, FCFS order."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [((i % 5) + 4, (i % 3) + 2) for i in range(12)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=8, max_prefill_batch=4))
    done = engine.run(reqs)
    assert [r.rid for r in done] == list(range(12))
    for r in done:
        assert r.out == refs[r.rid]
    # earlier arrivals are admitted no later than later ones (FCFS)
    admits = [r.t_admit for r in sorted(done, key=lambda r: r.rid)]
    assert all(a <= b for a, b in zip(admits, admits[1:]))


def test_engine_padded_prefill_matches_exact():
    """pad_to=8 bucketed prefill must not change a single token vs
    exact-length prefill (right-padding contributes exact zeros)."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(5, 6), (9, 6), (13, 6), (3, 6)])
    exact = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=1))
    padded = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=8))
    out_e = {r.rid: r.out for r in exact.run(
        [ServeRequest(r.rid, r.prompt, r.max_new) for r in reqs])}
    out_p = {r.rid: r.out for r in padded.run(
        [ServeRequest(r.rid, r.prompt, r.max_new) for r in reqs])}
    assert out_e == out_p
    # padding actually batched prompts into fewer dispatches
    assert padded.prefill_calls <= exact.prefill_calls


def test_engine_truncates_at_cache_capacity():
    cfg, bundle, params = _bundle("qwen2-0.5b")
    req = ServeRequest(rid=0, prompt=np.arange(CACHE_LEN - 3,
                                               dtype=np.int32) % 64,
                       max_new=50)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=1, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run([req])
    # prompt(45) + out hits cache_len, not the 50-token budget
    assert len(done[0].out) == 3


def test_engine_rejects_unservable_family_and_prompts():
    cfg, bundle, params = _bundle("whisper-tiny")        # encdec
    with pytest.raises(ValueError, match="no slotted serving path"):
        ServeEngine(bundle, params, EngineConfig(slots=2,
                                                 cache_len=CACHE_LEN))
    cfg, bundle, params = _bundle("mamba2-780m")         # pure SSM
    with pytest.raises(ValueError, match="pad_to=1"):
        ServeEngine(bundle, params, EngineConfig(slots=2, pad_to=8,
                                                 cache_len=CACHE_LEN))
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, pad_to=1, cache_len=CACHE_LEN))
    with pytest.raises(ValueError, match="exceeds cache_len"):
        engine.submit(ServeRequest(
            rid=0, prompt=np.zeros(CACHE_LEN + 1, np.int32), max_new=1))


# ------------------------------------------- failure semantics (§13)


def test_engine_deadline_expiry_reclaims_slot():
    """A request that blows its latency budget is expired: its partial
    output is a prefix of the reference, the freed slot serves the queue,
    and every surviving request still matches the scalar reference."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(5, 20), (7, 6), (6, 8)])
    refs = _refs(bundle, params, reqs)
    reqs[0].deadline_s = 5.0          # virtual clock: a 5-decode-step budget
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run(reqs)
    by = {r.rid: r for r in done}
    assert by[0].expired and by[0].done and not by[0].rejected
    assert 0 < len(by[0].out) < 20    # partial, not abandoned silently
    assert by[0].out == refs[0][:len(by[0].out)]
    for rid in (1, 2):                # survivors: exact parity
        assert not by[rid].expired and by[rid].out == refs[rid]
    # the reclaimed slot admitted the queued request mid-run
    assert by[2].t_admit >= 5.0


def test_engine_bounded_queue_rejects_overflow():
    """max_queue=2 with one slot: a burst of 5 bounces 3 explicitly —
    flagged ``rejected``, returned unserved — and the admitted ones still
    decode bit-identically."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(4, 4), (5, 4), (6, 4), (7, 4), (8, 4)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=1, cache_len=CACHE_LEN, pad_to=1, max_queue=2))
    done = engine.run(reqs)
    assert len(done) == 5             # every request comes back exactly once
    served = [r for r in done if not r.rejected]
    bounced = [r for r in done if r.rejected]
    assert [r.rid for r in bounced] == [2, 3, 4]
    assert all(not r.out and not r.done for r in bounced)
    assert len(engine.rejected) == 3
    for r in served:
        assert r.out == refs[r.rid]


def test_engine_drain_completes_in_flight_only():
    """Graceful shutdown: drain() decodes the in-flight requests to
    completion (bit-identical) without touching the admission queue."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(5, 8), (9, 6), (6, 10)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=1))
    for r in reqs:
        assert engine.submit(r)       # unbounded queue: all accepted
    engine._admit(0.0)                # rids 0,1 in flight; rid 2 queued
    engine.step(0.0)                  # mid-decode when the drain begins
    done = engine.drain()
    assert {r.rid for r in done} == {0, 1}
    for r in done:
        assert not r.expired and r.out == refs[r.rid]
    assert [r.rid for r in engine.waiting] == [2]   # held for the caller
    assert all(s is None for s in engine.active)


# ---------------------------------------------- wave baseline (regression)


def test_batched_server_mixed_lengths_regression():
    """The old BatchedServer shared one scalar ``cache['len']`` across
    slots, so a wave mixing prompt lengths decoded from wrong positions.
    The slotted rewrite must match the scalar reference bit for bit."""
    from repro.launch.serve import BatchedServer
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(4, 8), (17, 8), (9, 8), (26, 8)])  # one wave
    refs = _refs(bundle, params, reqs)
    server = BatchedServer(bundle, params, slots=4, cache_len=CACHE_LEN)
    done = server.run(reqs, log=lambda *_: None)
    assert len(done) == 4
    for r in done:
        assert r.out == refs[r.rid], f"req {r.rid} diverged (stale cache)"


def test_batched_server_hybrid_family():
    from repro.launch.serve import BatchedServer
    cfg, bundle, params = _bundle("zamba2-7b")
    reqs = _requests(cfg, [(6, 4), (12, 4)])
    refs = _refs(bundle, params, reqs)
    server = BatchedServer(bundle, params, slots=2, cache_len=CACHE_LEN)
    done = server.run(reqs, log=lambda *_: None)
    for r in done:
        assert r.out == refs[r.rid]


# -------------------------------------------------------------- load gen


def test_poisson_workload_deterministic():
    a = poisson_workload(8, vocab_size=64, rate_per_s=10.0, seed=3)
    b = poisson_workload(8, vocab_size=64, rate_per_s=10.0, seed=3)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr) and arr[-1] > 0
    burst = poisson_workload(4, vocab_size=64, rate_per_s=0.0)
    assert all(r.arrival_s == 0.0 for r in burst)


def test_latency_stats():
    reqs = []
    for i in range(4):
        r = ServeRequest(rid=i, prompt=np.zeros(4, np.int32), max_new=2,
                         arrival_s=float(i))
        r.t_arrival, r.t_first, r.t_done = float(i), i + 0.5, i + 1.0
        r.out = [1, 2]
        reqs.append(r)
    s = latency_stats(reqs, makespan_s=4.0)
    assert s["requests"] == 4 and s["tokens"] == 8
    assert s["tok_per_s"] == pytest.approx(2.0)
    assert s["p50_latency_s"] == pytest.approx(1.0)
    assert s["p50_ttft_s"] == pytest.approx(0.5)


# --------------------------------------------------------- winner serving


@pytest.mark.slow
def test_serve_winner_end_to_end(tiny_ecg):
    """search → select_for_goal → train+compile → serve: the closed loop."""
    from repro.core.evolution import EvolutionarySearch, NASConfig
    from repro.serve import serve_winner
    (tr, va) = tiny_ecg
    cfg = NASConfig(generations=1, children_per_gen=3, n_accept=2,
                    init_population=3, train_steps=60, train_batch=32,
                    n_workers=2, seed=0, det_min=0.5, fa_max=0.5)
    search = EvolutionarySearch(cfg, tr, va, log=lambda *_: None)
    state = search.run()
    winner = serve_winner(search, state, "low_energy",
                          data_train=tr, data_val=va,
                          train_steps=60, train_batch=32,
                          log=lambda *_: None)
    x_va = va[0][:10]
    logits = winner.predict(x_va)
    assert logits.shape == (10, 2)
    assert np.isfinite(logits).all()
    preds = winner.classify(x_va)
    assert set(np.unique(preds)) <= {0, 1}
    assert winner.batches_served == 2
    assert "goal=low_energy" in winner.report()


def test_serve_winner_raises_without_feasible(tiny_ecg):
    from repro.core.evolution import EvolutionarySearch, NASConfig
    from repro.core.objective_schema import Constraints, DesignGoal
    from repro.serve import serve_winner
    (tr, va) = tiny_ecg
    cfg = NASConfig(generations=0, children_per_gen=2, n_accept=1,
                    init_population=2, train_steps=5, train_batch=16,
                    n_workers=1, seed=0)
    search = EvolutionarySearch(cfg, tr, va, log=lambda *_: None)
    state = search.run()
    impossible = DesignGoal(name="impossible",
                            constraints=Constraints(det_min=1.01))
    with pytest.raises(LookupError, match="no feasible candidate"):
        serve_winner(search, state, impossible, data_train=tr, data_val=va)
