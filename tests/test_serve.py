"""Continuous-batching serving engine (repro.serve) — DESIGN.md §12.

The load-bearing property is *bit-identical greedy parity*: every request
served through the slotted engine (bucketed prefill, mixed lengths in
flight, slot reuse) must produce exactly the tokens a scalar one-request
decode produces.  Everything else — admission, buckets, stats — is tested
around that invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.registry import build_model
from repro.serve import (
    EngineConfig,
    ReplicaRouter,
    RouterConfig,
    ServeEngine,
    ServeRequest,
    build_buckets,
    gamma_workload,
    greedy_reference,
    latency_stats,
    onoff_workload,
    poisson_workload,
)
from repro.serve.buckets import pad_batch, pad_length

CACHE_LEN = 48


def _bundle(arch):
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _requests(cfg, lens_out, seed=0):
    """Mixed (prompt_len, max_new) pairs as a burst workload."""
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i,
                         prompt=rng.integers(0, cfg.vocab_size, pl).astype(
                             np.int32),
                         max_new=mn)
            for i, (pl, mn) in enumerate(lens_out)]


def _refs(bundle, params, reqs):
    dec = jax.jit(bundle.decode_step)
    return {r.rid: greedy_reference(bundle, params, r.prompt, r.max_new,
                                    CACHE_LEN, decode_jit=dec)
            for r in reqs}


# ---------------------------------------------------------------- buckets


def test_pad_helpers():
    assert [pad_length(n, 8) for n in (1, 8, 9, 24)] == [8, 8, 16, 24]
    assert [pad_length(n, 1) for n in (1, 7)] == [1, 7]
    assert [pad_batch(n, 8) for n in (1, 2, 3, 5, 8, 11)] == \
        [1, 2, 4, 8, 8, 8]


def test_build_buckets_groups_and_pads():
    prompts = [np.arange(n, dtype=np.int32) for n in (3, 5, 9, 11, 20)]
    buckets = build_buckets(prompts, slots=[0, 1, 2, 3, 4], n_slots=8,
                            pad_to=8, max_batch=4)
    # padded lengths: 8,8,16,16,24 -> three buckets
    by_len = {b.tokens.shape[1]: b for b in buckets}
    assert set(by_len) == {8, 16, 24}
    assert list(by_len[8].lens) == [3, 5]
    # batch rows are padded to powers of two; pad rows scatter out of range
    assert by_len[8].tokens.shape[0] == 2
    b16 = by_len[16]
    assert b16.tokens.shape[0] == 2 and list(b16.slot_idx) == [2, 3]
    # right padding is zeros beyond each row's length
    assert not by_len[8].tokens[0, 3:].any()


def test_build_buckets_chunks_to_max_batch():
    prompts = [np.arange(4, dtype=np.int32)] * 10
    buckets = build_buckets(prompts, slots=list(range(10)), n_slots=16,
                            pad_to=4, max_batch=4)
    assert [len(b.rows) for b in buckets] == [4, 4, 2]
    # every original row appears exactly once across chunks
    assert sorted(i for b in buckets for i in b.rows) == list(range(10))


# ------------------------------------------------------- engine bit-parity


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-7b"])
def test_engine_greedy_parity(arch):
    """Burst workload with mixed prompt/output lengths: every request's
    greedy tokens are bit-identical to the scalar one-request reference."""
    cfg, bundle, params = _bundle(arch)
    reqs = _requests(cfg, [(4, 6), (11, 3), (7, 9), (16, 5), (5, 5),
                           (9, 8), (13, 4), (6, 7), (20, 3), (8, 6)])
    refs = _refs(bundle, params, reqs)
    pad_to = 8 if bundle.prefill_pads else 1
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=pad_to, max_prefill_batch=4))
    done = engine.run(reqs)
    assert len(done) == len(reqs)
    for r in done:
        assert r.out == refs[r.rid], f"req {r.rid} diverged"
    # 10 requests through 4 slots exercises slot reuse
    assert engine.prefill_calls >= 3


def test_engine_per_slot_length_independence():
    """Slots at wildly different sequence positions decode together —
    the fix for the shared ``cache['len']`` scalar."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(3, 20), (30, 2), (12, 10), (25, 16)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run(reqs)
    for r in done:
        assert r.out == refs[r.rid]
    # the longest-running request kept decoding after the others finished
    assert engine.decode_steps >= 19


def test_engine_mid_flight_admission():
    """Virtual-clock arrivals land while earlier requests are mid-decode:
    no wave barrier, and parity still holds."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(6, 12), (9, 12), (7, 10), (5, 8), (11, 6)])
    refs = _refs(bundle, params, reqs)
    # slots=2: rids 0,1 admitted at t=0; the rest arrive mid-decode
    for r, arr in zip(reqs, [0.0, 0.0, 3.0, 4.0, 5.0]):
        r.arrival_s = arr
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run(reqs)
    for r in done:
        assert r.out == refs[r.rid]
    admits = sorted(r.t_admit for r in done)
    assert admits[0] == 0.0
    # at least one admission happened strictly mid-run (after decode began)
    assert admits[-1] > 0.0


def test_engine_slot_reuse_many_requests():
    """3x more requests than slots: every slot is recycled, FCFS order."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [((i % 5) + 4, (i % 3) + 2) for i in range(12)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=8, max_prefill_batch=4))
    done = engine.run(reqs)
    assert [r.rid for r in done] == list(range(12))
    for r in done:
        assert r.out == refs[r.rid]
    # earlier arrivals are admitted no later than later ones (FCFS)
    admits = [r.t_admit for r in sorted(done, key=lambda r: r.rid)]
    assert all(a <= b for a, b in zip(admits, admits[1:]))


def test_engine_padded_prefill_matches_exact():
    """pad_to=8 bucketed prefill must not change a single token vs
    exact-length prefill (right-padding contributes exact zeros)."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(5, 6), (9, 6), (13, 6), (3, 6)])
    exact = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=1))
    padded = ServeEngine(bundle, params, EngineConfig(
        slots=4, cache_len=CACHE_LEN, pad_to=8))
    out_e = {r.rid: r.out for r in exact.run(
        [ServeRequest(r.rid, r.prompt, r.max_new) for r in reqs])}
    out_p = {r.rid: r.out for r in padded.run(
        [ServeRequest(r.rid, r.prompt, r.max_new) for r in reqs])}
    assert out_e == out_p
    # padding actually batched prompts into fewer dispatches
    assert padded.prefill_calls <= exact.prefill_calls


def test_engine_truncates_at_cache_capacity():
    cfg, bundle, params = _bundle("qwen2-0.5b")
    req = ServeRequest(rid=0, prompt=np.arange(CACHE_LEN - 3,
                                               dtype=np.int32) % 64,
                       max_new=50)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=1, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run([req])
    # prompt(45) + out hits cache_len, not the 50-token budget
    assert len(done[0].out) == 3


def test_engine_rejects_unservable_family_and_prompts():
    cfg, bundle, params = _bundle("whisper-tiny")        # encdec
    with pytest.raises(ValueError, match="no slotted serving path"):
        ServeEngine(bundle, params, EngineConfig(slots=2,
                                                 cache_len=CACHE_LEN))
    cfg, bundle, params = _bundle("mamba2-780m")         # pure SSM
    with pytest.raises(ValueError, match="pad_to=1"):
        ServeEngine(bundle, params, EngineConfig(slots=2, pad_to=8,
                                                 cache_len=CACHE_LEN))
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, pad_to=1, cache_len=CACHE_LEN))
    with pytest.raises(ValueError, match="exceeds cache_len"):
        engine.submit(ServeRequest(
            rid=0, prompt=np.zeros(CACHE_LEN + 1, np.int32), max_new=1))


# ------------------------------------------- failure semantics (§13)


def test_engine_deadline_expiry_reclaims_slot():
    """A request that blows its latency budget is expired: its partial
    output is a prefix of the reference, the freed slot serves the queue,
    and every surviving request still matches the scalar reference."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(5, 20), (7, 6), (6, 8)])
    refs = _refs(bundle, params, reqs)
    reqs[0].deadline_s = 5.0          # virtual clock: a 5-decode-step budget
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=1))
    done = engine.run(reqs)
    by = {r.rid: r for r in done}
    assert by[0].expired and by[0].done and not by[0].rejected
    assert 0 < len(by[0].out) < 20    # partial, not abandoned silently
    assert by[0].out == refs[0][:len(by[0].out)]
    for rid in (1, 2):                # survivors: exact parity
        assert not by[rid].expired and by[rid].out == refs[rid]
    # the reclaimed slot admitted the queued request mid-run
    assert by[2].t_admit >= 5.0


def test_engine_bounded_queue_rejects_overflow():
    """max_queue=2 with one slot: a burst of 5 bounces 3 explicitly —
    flagged ``rejected``, returned unserved — and the admitted ones still
    decode bit-identically."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(4, 4), (5, 4), (6, 4), (7, 4), (8, 4)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=1, cache_len=CACHE_LEN, pad_to=1, max_queue=2))
    done = engine.run(reqs)
    assert len(done) == 5             # every request comes back exactly once
    served = [r for r in done if not r.rejected]
    bounced = [r for r in done if r.rejected]
    assert [r.rid for r in bounced] == [2, 3, 4]
    assert all(not r.out and not r.done for r in bounced)
    assert len(engine.rejected) == 3
    for r in served:
        assert r.out == refs[r.rid]


def test_engine_drain_completes_in_flight_only():
    """Graceful shutdown: drain() decodes the in-flight requests to
    completion (bit-identical) without touching the admission queue."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(5, 8), (9, 6), (6, 10)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=1))
    for r in reqs:
        assert engine.submit(r)       # unbounded queue: all accepted
    engine._admit(0.0)                # rids 0,1 in flight; rid 2 queued
    engine.step(0.0)                  # mid-decode when the drain begins
    done = engine.drain()
    assert {r.rid for r in done} == {0, 1}
    for r in done:
        assert not r.expired and r.out == refs[r.rid]
    assert [r.rid for r in engine.waiting] == [2]   # held for the caller
    assert all(s is None for s in engine.active)


# ---------------------------------------------- wave baseline (regression)


def test_batched_server_mixed_lengths_regression():
    """The old BatchedServer shared one scalar ``cache['len']`` across
    slots, so a wave mixing prompt lengths decoded from wrong positions.
    The slotted rewrite must match the scalar reference bit for bit."""
    from repro.launch.serve import BatchedServer
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(4, 8), (17, 8), (9, 8), (26, 8)])  # one wave
    refs = _refs(bundle, params, reqs)
    server = BatchedServer(bundle, params, slots=4, cache_len=CACHE_LEN)
    done = server.run(reqs, log=lambda *_: None)
    assert len(done) == 4
    for r in done:
        assert r.out == refs[r.rid], f"req {r.rid} diverged (stale cache)"


def test_batched_server_hybrid_family():
    from repro.launch.serve import BatchedServer
    cfg, bundle, params = _bundle("zamba2-7b")
    reqs = _requests(cfg, [(6, 4), (12, 4)])
    refs = _refs(bundle, params, reqs)
    server = BatchedServer(bundle, params, slots=2, cache_len=CACHE_LEN)
    done = server.run(reqs, log=lambda *_: None)
    for r in done:
        assert r.out == refs[r.rid]


# -------------------------------------------------------------- load gen


def test_poisson_workload_deterministic():
    a = poisson_workload(8, vocab_size=64, rate_per_s=10.0, seed=3)
    b = poisson_workload(8, vocab_size=64, rate_per_s=10.0, seed=3)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    arr = [r.arrival_s for r in a]
    assert arr == sorted(arr) and arr[-1] > 0
    burst = poisson_workload(4, vocab_size=64, rate_per_s=0.0)
    assert all(r.arrival_s == 0.0 for r in burst)


def test_latency_stats():
    reqs = []
    for i in range(4):
        r = ServeRequest(rid=i, prompt=np.zeros(4, np.int32), max_new=2,
                         arrival_s=float(i))
        r.t_arrival, r.t_first, r.t_done = float(i), i + 0.5, i + 1.0
        r.out = [1, 2]
        reqs.append(r)
    s = latency_stats(reqs, makespan_s=4.0)
    assert s["requests"] == 4 and s["tokens"] == 8
    assert s["tok_per_s"] == pytest.approx(2.0)
    assert s["p50_latency_s"] == pytest.approx(1.0)
    assert s["p50_ttft_s"] == pytest.approx(0.5)


# --------------------------------------------------------- winner serving


@pytest.mark.slow
def test_serve_winner_end_to_end(tiny_ecg):
    """search → select_for_goal → train+compile → serve: the closed loop."""
    from repro.core.evolution import EvolutionarySearch, NASConfig
    from repro.serve import serve_winner
    (tr, va) = tiny_ecg
    cfg = NASConfig(generations=1, children_per_gen=3, n_accept=2,
                    init_population=3, train_steps=60, train_batch=32,
                    n_workers=2, seed=0, det_min=0.5, fa_max=0.5)
    search = EvolutionarySearch(cfg, tr, va, log=lambda *_: None)
    state = search.run()
    winner = serve_winner(search, state, "low_energy",
                          data_train=tr, data_val=va,
                          train_steps=60, train_batch=32,
                          log=lambda *_: None)
    x_va = va[0][:10]
    logits = winner.predict(x_va)
    assert logits.shape == (10, 2)
    assert np.isfinite(logits).all()
    preds = winner.classify(x_va)
    assert set(np.unique(preds)) <= {0, 1}
    assert winner.batches_served == 2
    assert "goal=low_energy" in winner.report()


def test_serve_winner_raises_without_feasible(tiny_ecg):
    from repro.core.evolution import EvolutionarySearch, NASConfig
    from repro.core.objective_schema import Constraints, DesignGoal
    from repro.serve import serve_winner
    (tr, va) = tiny_ecg
    cfg = NASConfig(generations=0, children_per_gen=2, n_accept=1,
                    init_population=2, train_steps=5, train_batch=16,
                    n_workers=1, seed=0)
    search = EvolutionarySearch(cfg, tr, va, log=lambda *_: None)
    state = search.run()
    impossible = DesignGoal(name="impossible",
                            constraints=Constraints(det_min=1.01))
    with pytest.raises(LookupError, match="no feasible candidate"):
        serve_winner(search, state, impossible, data_train=tr, data_val=va)


# ------------------------------------------- engine replication hooks (§14)


def test_engine_cancel_and_take_finished():
    """The router-facing surface: cancel withdraws in-flight and queued
    requests without recording a result; take_finished drains completions
    incrementally; the load metrics track slot occupancy."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(5, 8), (9, 6), (6, 10)])
    refs = _refs(bundle, params, reqs)
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=1))
    engine.reset()
    for r in reqs:
        engine.submit(r)
    assert engine.has_work and engine.queue_depth == 3
    engine._admit(0.0)                # rids 0,1 in flight; rid 2 queued
    assert [r.rid for r in engine.in_flight] == [0, 1]
    assert engine.queue_depth == 1
    assert engine.cancel(2) is reqs[2]        # queued: leaves the queue
    assert engine.cancel(1) is reqs[1]        # in flight: slot reclaimed
    assert engine.cancel(99) is None          # unknown rid: no-op
    assert [r.rid for r in engine.in_flight] == [0]
    while engine.has_work:
        engine.tick(float(engine.decode_steps))
    got = engine.take_finished()
    assert [r.rid for r in got] == [0] and got[0].out == refs[0]
    assert engine.take_finished() == []       # drained: second take is empty
    assert not engine.has_work


# ------------------------------------------------- replica router (§14)


def _router_requests(cfg, triples, seed=0):
    """(prompt_len, max_new, arrival_s) triples."""
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i,
                         prompt=rng.integers(0, cfg.vocab_size, pl).astype(
                             np.int32),
                         max_new=mn, arrival_s=arr)
            for i, (pl, mn, arr) in enumerate(triples)]


def test_router_greedy_parity_no_faults():
    """Fault-free baseline: requests split across two replicas (open-loop
    arrivals, mixed lengths) each decode bit-identically to the scalar
    reference, and the router's accounting balances."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _router_requests(cfg, [(4, 6, 0.0), (8, 5, 0.0), (6, 4, 2.0),
                                  (5, 7, 3.0), (7, 3, 5.0), (4, 6, 8.0)])
    refs = _refs(bundle, params, reqs)
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=2, engine=EngineConfig(slots=2, cache_len=CACHE_LEN,
                                        pad_to=4, max_prefill_batch=2)))
    done = router.run(reqs)
    assert [r.rid for r in done] == list(range(6))
    for r in done:
        assert not r.rejected and not r.expired
        assert r.out == refs[r.rid]
    s = router.stats
    assert s["admitted"] == s["completed"] == s["dispatches"] == 6
    assert s["failovers"] == s["restarts"] == 0 and s["quarantined"] == []
    # both replicas actually served work (least-loaded spreads the burst)
    assert all(rep.engine.decode_steps > 0 for rep in router.replicas)


def test_router_queue_shedding_is_explicit():
    """A burst over the bounded router queue: overflow is bounced at
    admission — flagged ``rejected``, returned unserved, counted — and
    every admitted request still decodes bit-identically.  Zero silent
    drops: submitted == served + shed."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _router_requests(cfg, [(4, 4, 0.0)] * 10, seed=1)
    refs = _refs(bundle, params, reqs)
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=1, max_queue=4,
        engine=EngineConfig(slots=2, cache_len=CACHE_LEN, pad_to=4,
                            max_prefill_batch=2)))
    done = router.run(reqs)
    assert len(done) == 10            # every request back exactly once
    shed = [r for r in done if r.rejected]
    served = [r for r in done if not r.rejected]
    assert len(shed) == router.stats["shed_queue"] == 6
    assert all(not r.out and not r.done for r in shed)
    for r in served:
        assert r.out == refs[r.rid]
    assert router.stats["admitted"] == 4
    assert router.stats["completed"] == len(served) == 4


def test_router_deadline_shedding_rejects_unmeetable():
    """Deadline-aware admission: once observed service times prove a
    deadline unmeetable from the back of the queue, the request is bounced
    up front instead of being admitted to die."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    # warmup (no deadlines) seeds the service-time estimate at ~3 virtual
    # seconds; then a burst with 1s budgets — provably unmeetable for
    # anything that has to queue
    warm = _router_requests(cfg, [(4, 3, 0.0), (4, 3, 4.0), (4, 3, 8.0)],
                            seed=2)
    burst = _router_requests(cfg, [(4, 3, 20.0)] * 6, seed=3)
    for i, r in enumerate(burst):
        r.rid = 10 + i
        r.deadline_s = 1.0
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=1, engine=EngineConfig(slots=1, cache_len=CACHE_LEN,
                                        pad_to=4, max_prefill_batch=1)))
    done = router.run(warm + burst)
    assert len(done) == 9             # zero silent drops
    s = router.stats
    assert s["shed_deadline"] == 5    # queue-empty head admitted, rest shed
    shed = [r for r in done if r.rejected]
    assert len(shed) == 5 and all(not r.out for r in shed)
    # warmups completed; the one admitted burst request expired in flight
    # (1s budget vs ~3s service) — expired, never silently dropped
    assert s["completed"] == 3 and s["expired"] == 1


def test_router_hedges_straggler_first_completion_wins():
    """A silent stall with the heartbeat effectively off: the hedge path
    alone must rescue the stuck requests — stragglers past the seeded
    service-time percentile are twinned onto the healthy replica, the twin
    wins, and the output is still bit-identical."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    from repro.core.faults import FaultPlan, FaultSpec
    reqs = _router_requests(cfg, [(4, 4, float(i)) for i in range(20)],
                            seed=4)
    refs = _refs(bundle, params, reqs)
    plan = FaultPlan([FaultSpec(site="serve.replica", kind="stall",
                                hang_s=30.0, times=1,
                                when=lambda c: c["replica"] == 0
                                and c["tick"] == 12)])
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=2, hedge=True, hedge_percentile=90.0, hedge_min_samples=4,
        heartbeat_misses=50,          # heartbeat off: hedging must carry it
        engine=EngineConfig(slots=2, cache_len=CACHE_LEN, pad_to=4,
                            max_prefill_batch=2)), faults=plan)
    done = router.run(reqs)
    assert len(done) == 20
    for r in done:
        assert not r.rejected and not r.expired
        assert r.out == refs[r.rid]
    s = router.stats
    assert s["hedges"] >= 1 and s["hedge_wins"] >= 1
    assert s["quarantined"] == []     # nobody died — just a straggler


def test_router_drain_completes_in_flight_only():
    """Graceful shutdown across the replica set: drain() finishes the
    dispatched requests bit-identically and leaves the undispatched queue
    for the caller."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _router_requests(cfg, [(4, 5, 0.0)] * 8, seed=5)
    refs = _refs(bundle, params, reqs)
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=2, engine=EngineConfig(slots=2, cache_len=CACHE_LEN,
                                        pad_to=4, max_prefill_batch=2)))
    router.reset()
    for r in reqs:
        assert router.submit(r)
    router._dispatch(0.0)             # 4 slots filled, 4 left queued
    drained = router.drain()
    assert {r.rid for r in drained} == {0, 1, 2, 3}
    for r in drained:
        assert not r.expired and r.out == refs[r.rid]
    assert [r.rid for r in router.queue] == [4, 5, 6, 7]   # held


# ------------------------------------------------ load generators (§14)


def test_gamma_workload_deterministic_heavy_tail():
    a = gamma_workload(64, vocab_size=64, rate_per_s=2.0, cv=4.0, seed=3)
    b = gamma_workload(64, vocab_size=64, rate_per_s=2.0, cv=4.0, seed=3)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert all(np.array_equal(x.prompt, y.prompt) and x.max_new == y.max_new
               for x, y in zip(a, b))
    arr = np.array([r.arrival_s for r in a])
    assert (np.diff(arr) >= 0).all() and arr[-1] > 0
    # heavy tail: the burstier stream has a larger max/median gap ratio
    gaps_hi = np.diff([r.arrival_s for r in a])
    gaps_lo = np.diff([r.arrival_s for r in gamma_workload(
        64, vocab_size=64, rate_per_s=2.0, cv=1.0, seed=3)])
    assert gaps_hi.max() > gaps_lo.max()
    with pytest.raises(ValueError, match="rate_per_s"):
        gamma_workload(4, vocab_size=64, rate_per_s=0.0)
    with pytest.raises(ValueError, match="variation"):
        gamma_workload(4, vocab_size=64, rate_per_s=1.0, cv=-1.0)


def test_onoff_workload_bursts_inside_on_windows():
    a = onoff_workload(40, vocab_size=64, rate_per_s=5.0, on_s=2.0,
                       off_s=3.0, seed=9)
    b = onoff_workload(40, vocab_size=64, rate_per_s=5.0, on_s=2.0,
                       off_s=3.0, seed=9)
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    arr = np.array([r.arrival_s for r in a])
    assert (np.diff(arr) >= 0).all()
    # every arrival lands strictly inside an on window of the 5s period
    assert ((arr % 5.0) < 2.0).all()
    with pytest.raises(ValueError, match="onoff"):
        onoff_workload(4, vocab_size=64, rate_per_s=5.0, on_s=0.0, off_s=1.0)


# ------------------------------------------------ replicated winner (§14)


def test_replicated_winner_parity_and_failover(tiny_ecg):
    """Replicated classification dispatch: round-robin replicas return the
    same logits as the single winner; a replica that keeps crashing fails
    over mid-call (same batch, same logits) and is quarantined — last-live
    protection keeps the survivor."""
    from repro.core.faults import FaultPlan, FaultSpec
    from repro.core.genome import random_genome
    from repro.serve import compile_winner, replicate_winner
    (tr, va) = tiny_ecg
    g = random_genome(np.random.default_rng(0))
    winner = compile_winner(g, tr, va, train_steps=20, train_batch=32,
                            seed=0, goal="test")
    x = va[0][:10]
    ref = winner.predict(x)

    rw = replicate_winner(winner, 2)
    assert np.array_equal(rw.predict(x), ref)
    assert np.array_equal(rw.predict(x), ref)   # round-robins to replica 1
    assert [r.batches_served for r in rw.replicas] == [1, 1]
    assert rw.live_replicas == [0, 1]

    plan = FaultPlan([FaultSpec(site="router.dispatch", kind="crash",
                                when=lambda c: c["replica"] == 0)])
    rw2 = replicate_winner(winner, 2, faults=plan)
    for _ in range(8):
        assert np.array_equal(rw2.predict(x), ref)  # failover: same logits
    assert rw2.stats["failovers"] >= 1
    assert rw2.stats["quarantined"] == [0]
    assert rw2.live_replicas == [1]             # last live: never retired
    assert "replicas=1/2" in rw2.report()
    with pytest.raises(ValueError, match="at least one replica"):
        replicate_winner(winner, 0)
