"""NAS search-state checkpointing: preempt mid-search, resume, identical
machinery state (population, caches, history)."""
import numpy as np

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.trainer import TrainResult


def _mock(g):
    return TrainResult(detection_rate=min(0.99, 0.7 + 0.05 * g.depth()),
                       false_alarm_rate=max(0.0, 0.3 - 0.03 * g.depth()),
                       val_loss=0.4, steps=0)


def _search(seed=0):
    cfg = NASConfig(generations=4, children_per_gen=6, n_accept=3,
                    init_population=4, n_workers=2, seed=seed)
    return EvolutionarySearch(cfg, None, None, train_fn=_mock,
                              log=lambda *_: None)


def test_save_load_roundtrip(tmp_path):
    s = _search()
    state = s.init_state()
    state = s.step(state)
    path = str(tmp_path / "nas.json")
    s.save_state(state, path)
    restored = s.load_state(path)
    assert restored.generation == state.generation
    assert len(restored.population) == len(state.population)
    for a, b in zip(state.population, restored.population):
        assert a.phash == b.phash
        assert a.genome == b.genome
        np.testing.assert_allclose(a.cheap, b.cheap)
    assert set(restored.evaluated_hashes) == set(state.evaluated_hashes)


def test_resume_is_bit_reproducible(tmp_path):
    """A preempted + resumed search must equal an uninterrupted one exactly:
    the checkpoint carries the driver's RNG state, so generations 3-4 draw
    the same mutations either way."""
    # uninterrupted reference run: 4 generations
    sA = _search()
    ref = sA.init_state()
    for _ in range(4):
        ref = sA.step(ref)
    # same search preempted after generation 2 ...
    path = str(tmp_path / "nas.json")
    sB = _search()
    state = sB.init_state()
    for _ in range(2):
        state = sB.step(state)
        sB.save_state(state, path)
    # ... and resumed by a fresh driver object
    sC = _search()
    final = sC.run_resumable(path, generations=4)

    assert final.generation == ref.generation
    assert list(final.pop.phash) == list(ref.pop.phash)
    np.testing.assert_array_equal(final.pop.enc.op, ref.pop.enc.op)
    np.testing.assert_array_equal(final.pop.enc.conn, ref.pop.enc.conn)
    np.testing.assert_array_equal(final.pop.cheap, ref.pop.cheap)
    np.testing.assert_array_equal(final.pop.expensive, ref.pop.expensive)
    assert set(final.evaluated_hashes) == set(ref.evaluated_hashes)
    # the drivers end in identical RNG states: future steps stay aligned too
    assert sC.rng.bit_generator.state == sA.rng.bit_generator.state


def test_checkpoint_without_rng_state_still_loads(tmp_path):
    """Pre-RNG-persistence checkpoints (no "rng_state" key) remain loadable."""
    import json
    s = _search()
    state = s.init_state()
    path = str(tmp_path / "nas.json")
    s.save_state(state, path)
    with open(path) as f:
        payload = json.load(f)
    del payload["rng_state"]
    with open(path, "w") as f:
        json.dump(payload, f)
    restored = _search().load_state(path)
    assert len(restored.pop) == len(state.pop)


def test_resume_after_preemption(tmp_path):
    path = str(tmp_path / "nas.json")
    # run 2 generations, "preempt"
    s1 = _search()
    state = s1.init_state()
    for _ in range(2):
        state = s1.step(state)
        s1.save_state(state, path)
    # fresh process resumes and completes to 4
    s2 = _search()
    final = s2.run_resumable(path, generations=4)
    assert final.generation == 4
    assert len(final.history) >= 2
    # dormant-gene cache survived the restart
    assert set(state.evaluated_hashes) <= set(final.evaluated_hashes)
