"""HALF-for-TPU codesign: the analytic frontier must reproduce the
hand-tuned §Perf configurations (cross-validation against measurements)."""
import numpy as np

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.core.tpu_codesign import (
    ImplGenome,
    best_by_bound,
    enumerate_frontier,
    estimate_train_cell,
)

MESH = {"data": 16, "model": 16}
CELL = SHAPES["train_4k"]


def test_ep_a2a_dominates_sort_for_moe():
    """Measured on kimi (B2): a2a EP cut collectives 3x. The analytic model
    must rank every ep_a2a point above its sort twin on collectives."""
    cfg = get_config("kimi-k2-1t-a32b")
    for mb in (1, 4, 8):
        a = estimate_train_cell(cfg, CELL, ImplGenome(mb, 8, "sort",
                                                      "full"), MESH)
        b = estimate_train_cell(cfg, CELL, ImplGenome(mb, 8, "ep_a2a",
                                                      "full"), MESH)
        assert b.collective_s < a.collective_s


def test_codesign_selects_adopted_kimi_config():
    """The frontier pick under the 16 GiB activation constraint must match
    the adopted config (mb=4, ep_a2a) found by manual hillclimbing."""
    cfg = get_config("kimi-k2-1t-a32b")
    genomes, costs, front = enumerate_frontier(cfg, CELL, MESH)
    g, _ = best_by_bound(genomes, costs, front, max_act_gib=16.0)
    assert g.moe_impl == "ep_a2a"
    assert g.microbatches == cfg.microbatches == 4


def test_qblocking_cuts_compute():
    """Measured (C1): q-blocking cut qwen2 FLOPs 34 %. The model must show
    monotone compute reduction with more q blocks."""
    cfg = get_config("qwen2-0.5b")
    prev = None
    for qb in (1, 4, 8, 16):
        c = estimate_train_cell(cfg, CELL, ImplGenome(2, qb, "sort",
                                                      "full"), MESH)
        if prev is not None:
            assert c.compute_s < prev
        prev = c.compute_s


def test_microbatches_trade_activation_for_collectives():
    cfg = get_config("mistral-large-123b")
    lo = estimate_train_cell(cfg, CELL, ImplGenome(2, 8, "sort", "full"),
                             MESH)
    hi = estimate_train_cell(cfg, CELL, ImplGenome(16, 8, "sort", "full"),
                             MESH)
    assert hi.act_gib < lo.act_gib
    assert hi.collective_s >= lo.collective_s


def test_frontier_is_nondominated():
    cfg = get_config("dbrx-132b")
    genomes, costs, front = enumerate_frontier(cfg, CELL, MESH)
    pts = np.stack([c.vector() for c in costs])
    for i in front:
        for j in range(len(pts)):
            assert not (np.all(pts[j] <= pts[i]) and np.any(pts[j] < pts[i]))
