"""Per-arch smoke tests (reduced configs) + serve/train consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.configs.shapes import SHAPES, ShapeCell
from repro.models.registry import build_model
from repro.training.step import TrainState, loss_fn, make_train_step

CELL = ShapeCell("smoke", "train", 64, 4)


def _batch_for(bundle, cell, seed=0):
    specs, _ = bundle.input_specs(cell)
    rng = jax.random.PRNGKey(seed)
    batch = {}
    for k, sds in specs.items():
        if sds.dtype == jnp.int32:
            batch[k] = jax.random.randint(rng, sds.shape, 0,
                                          bundle.cfg.vocab_size)
        else:
            batch[k] = jax.random.normal(rng, sds.shape, sds.dtype)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_arch_train_step(arch):
    """One forward/train step on CPU: output shapes + no NaNs (assignment)."""
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(bundle, CELL)
    logits, aux = bundle.apply_train(params, batch)
    assert logits.shape[0] == CELL.global_batch
    assert logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())
    train_step, opt = make_train_step(bundle)
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    state, metrics = train_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-4b", "dbrx-132b",
                                  "mamba2-780m", "zamba2-7b"])
def test_prefill_decode_matches_forward(arch):
    """Serving path == training path on the last token (no capacity drops)."""
    cfg = reduced_config(arch)
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size)
    full, _ = bundle.apply_train(params, {"tokens": toks})
    pl, cache = bundle.prefill(params, {"tokens": toks[:, :-1],
                                        "cache_len": 32})
    dl, cache = bundle.decode_step(params, cache, {"tokens": toks[:, -1:]})
    np.testing.assert_allclose(np.asarray(pl), np.asarray(full[:, -2]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_whisper_decode_runs():
    cfg = reduced_config("whisper-tiny")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                               jnp.float32)
    dec = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                             cfg.vocab_size)
    pl, cache = bundle.prefill(params, {"frames": frames, "dec_tokens": dec,
                                        "cache_len": 16})
    dl, cache = bundle.decode_step(params, cache,
                                   {"tokens": dec[:, -1:]})
    assert dl.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(dl).any())
    assert int(cache["len"]) == 9


def test_vlm_mrope_positions_change_logits():
    """M-RoPE must actually consume the 3-component position ids."""
    cfg = reduced_config("qwen2-vl-2b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    emb = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    pos_a = jnp.broadcast_to(jnp.arange(16)[None, None], (3, 1, 16))
    pos_b = pos_a.at[1].set(pos_a[1] * 3)   # different height positions
    la, _ = bundle.apply_train(params, {"embeds": emb, "positions": pos_a})
    lb, _ = bundle.apply_train(params, {"embeds": emb, "positions": pos_b})
    assert float(jnp.abs(la - lb).max()) > 1e-4


def test_long_500k_skip_rules():
    cell = SHAPES["long_500k"]
    for arch in ALL_ARCHS:
        bundle = build_model(get_config(arch))
        ok, why = bundle.supports(cell)
        if arch in ("mamba2-780m", "zamba2-7b"):
            assert ok
        else:
            assert not ok and "full-attention" in why


def test_moe_aux_loss_and_capacity():
    from repro.models.moe import expert_capacity
    cfg = reduced_config("dbrx-132b")
    assert expert_capacity(1024, cfg) >= \
        1024 * cfg.experts_per_token // cfg.n_experts
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(bundle, CELL)
    total, metrics = loss_fn(params, batch, bundle)
    assert float(metrics["moe_aux"]) > 0.0


def test_param_count_analytic_close_to_actual():
    from repro.models.common import count_params
    for arch in ("qwen2-0.5b", "mamba2-780m", "whisper-tiny"):
        cfg = reduced_config(arch)
        bundle = build_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        actual = count_params(params)
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)


def test_chunked_loss_equivalence():
    """§Perf C2': fused chunked unembed+xent == plain loss (values+grads)."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.training.step import loss_fn
    cfg = dataclasses.replace(reduced_config("qwen2-0.5b"),
                              chunked_loss=True)
    b_chunk = build_model(cfg)
    b_plain = build_model(dataclasses.replace(cfg, chunked_loss=False))
    params = b_chunk.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                                          cfg.vocab_size)}
    lc, _ = loss_fn(params, batch, b_chunk)
    lp, _ = loss_fn(params, batch, b_plain)
    assert abs(float(lc) - float(lp)) < 1e-5
    gc = jax.grad(lambda p: loss_fn(p, batch, b_chunk)[0])(params)
    gp = jax.grad(lambda p: loss_fn(p, batch, b_plain)[0])(params)
    err = max(float(jnp.abs(a - b).max()) for a, b in
              zip(jax.tree_util.tree_leaves(gc),
                  jax.tree_util.tree_leaves(gp)))
    assert err < 1e-4, err
