"""ObjectiveSchema / Constraints / DesignGoal unit tests (DESIGN.md §10)."""
import dataclasses

import numpy as np
import pytest

from repro.core.objective_schema import (
    ALL_NAMES,
    CHEAP_NAMES,
    EXPENSIVE_NAMES,
    GOALS,
    Constraints,
    DesignGoal,
    ObjectiveColumn,
    ObjectiveSchema,
    get_goal,
)


# ------------------------------------------------------------------ schema

def two_platform_schema():
    return ObjectiveSchema.concat([ObjectiveSchema.cheap("fpga_zu"),
                                   ObjectiveSchema.cheap("tpu_roofline")])


def test_cheap_schema_matches_canonical_names():
    s = ObjectiveSchema.cheap("fpga_zu")
    assert s.names == CHEAP_NAMES
    assert s.platforms == ("fpga_zu",)
    assert all(c.kind == "cheap" for c in s)
    assert s.qualified_names[0] == "fpga_zu:power_min_alpha_w"


def test_with_expensive_appends_platform_agnostic_columns():
    full = ObjectiveSchema.cheap("fpga_zu").with_expensive()
    assert full.names == ALL_NAMES
    assert [full.columns[i].name for i in full.expensive_indices()] \
        == list(EXPENSIVE_NAMES)
    # agnostic columns keep bare qualified names
    assert full.qualified_names[-1] == "false_alarm_rate"


def test_index_unqualified_qualified_and_platform_kw():
    s = two_platform_schema()
    assert s.index("tpu_roofline:n_params") == 7 + CHEAP_NAMES.index("n_params")
    assert s.index("n_params", platform="fpga_zu") \
        == CHEAP_NAMES.index("n_params")
    with pytest.raises(KeyError):       # ambiguous across platforms
        s.index("n_params")
    with pytest.raises(KeyError):       # unknown name
        s.index("no_such_objective")


def test_platform_group_and_platforms():
    full = two_platform_schema().with_expensive()
    assert full.platforms == ("fpga_zu", "tpu_roofline")
    grp = full.platform_group("tpu_roofline")
    # 7 cheap columns of the platform + the 2 agnostic expensive columns
    assert len(grp) == 9
    assert [full.columns[i].platform for i in grp] \
        == ["tpu_roofline"] * 7 + ["", ""]
    with pytest.raises(KeyError):
        full.platform_group("no_such_platform")


def test_duplicate_platform_columns_rejected():
    with pytest.raises(ValueError):
        ObjectiveSchema.concat([ObjectiveSchema.cheap("fpga_zu"),
                                ObjectiveSchema.cheap("fpga_zu")])


def test_json_round_trip():
    full = two_platform_schema().with_expensive()
    assert ObjectiveSchema.from_json(full.to_json()) == full


def test_bad_column_kind_rejected():
    with pytest.raises(ValueError):
        ObjectiveColumn("x", "weird")


# -------------------------------------------------------------- constraints

def test_constraints_coerce_paths():
    c = Constraints(0.8, 0.3)
    assert Constraints.coerce(c) is c
    assert Constraints.coerce(0.8, 0.3) == c
    assert Constraints.coerce() == Constraints(0.90, 0.20)  # paper defaults
    assert Constraints.coerce(0.8) == Constraints(0.8, 0.20)


def test_constraints_unify_the_three_consumers():
    """One Constraints object must drive TrainResult, Candidate and
    PopulationArrays feasibility identically."""
    from repro.core.objectives import Candidate, PopulationArrays
    from repro.core.trainer import TrainResult

    cons = Constraints(det_min=0.85, fa_max=0.25)
    cases = [(0.9, 0.1, True), (0.85, 0.25, True),
             (0.84, 0.1, False), (0.9, 0.26, False)]
    for det, fa, expect in cases:
        tr = TrainResult(detection_rate=det, false_alarm_rate=fa,
                         val_loss=0.0, steps=0)
        assert tr.meets_constraints(cons) is expect
        assert tr.meets_constraints(cons.det_min, cons.fa_max) is expect
        cand = Candidate(genome=None, cheap=np.zeros(7),
                         expensive=np.asarray([1.0 - det, fa]))
        assert cand.meets_constraints(cons) is expect
    exp = np.asarray([[1.0 - det, fa] for det, fa, _ in cases])
    pop = PopulationArrays(
        enc=_tiny_enc(len(cases)), cheap=np.zeros((len(cases), 7)),
        expensive=exp, phash=np.asarray([str(i) for i in range(len(cases))],
                                        dtype=object),
        born=np.zeros(len(cases), dtype=np.int64))
    np.testing.assert_array_equal(pop.feasible_mask(cons),
                                  [c[2] for c in cases])
    # legacy float-pair call sites still work
    np.testing.assert_array_equal(pop.feasible_mask(0.85, 0.25),
                                  [c[2] for c in cases])


def _tiny_enc(n):
    from repro.core.genome import PopulationEncoding, random_genome
    rng = np.random.default_rng(0)
    from repro.core.search_space import DEFAULT_SPACE
    return PopulationEncoding.from_genomes(
        [random_genome(rng, DEFAULT_SPACE) for _ in range(n)])


def test_untrained_rows_are_infeasible():
    from repro.core.objectives import PopulationArrays
    pop = PopulationArrays(
        enc=_tiny_enc(2), cheap=np.zeros((2, 7)),
        expensive=np.asarray([[np.nan, np.nan], [0.0, 0.0]]),
        phash=np.asarray(["a", "b"], dtype=object),
        born=np.zeros(2, dtype=np.int64))
    np.testing.assert_array_equal(pop.feasible_mask(Constraints()),
                                  [False, True])


# -------------------------------------------------------------------- goals

def test_goal_presets_exist_and_resolve():
    for name in ("balanced", "low_energy", "low_power", "high_throughput"):
        g = get_goal(name)
        assert g.name == name
        assert get_goal(g) is g
    with pytest.raises(KeyError):
        get_goal("no_such_goal")


def test_balanced_goal_selects_every_column():
    full = two_platform_schema().with_expensive()
    np.testing.assert_array_equal(
        GOALS["balanced"].selection_indices(full), np.arange(len(full)))


def test_goal_selection_keeps_expensive_columns():
    full = two_platform_schema().with_expensive()
    for name in ("low_energy", "low_power", "high_throughput"):
        cols = GOALS[name].selection_indices(full)
        assert set(full.expensive_indices().tolist()) <= set(cols.tolist())
        picked = {full.columns[i].name for i in cols}
        assert set(GOALS[name].objectives) <= picked


def test_goal_platform_restriction():
    full = two_platform_schema().with_expensive()
    g = dataclasses.replace(GOALS["low_energy"], platforms=("tpu_roofline",))
    cols = g.selection_indices(full)
    cheap_cols = [i for i in cols if full.columns[i].kind == "cheap"]
    assert all(full.columns[i].platform == "tpu_roofline"
               for i in cheap_cols)
    # primary column once per platform in scope
    assert len(g.primary_indices(full)) == 1
    assert len(GOALS["low_energy"].primary_indices(full)) == 2


def test_goal_with_unknown_objective_raises():
    full = ObjectiveSchema.cheap("fpga_zu").with_expensive()
    g = DesignGoal(name="bad", objectives=("nonexistent",))
    with pytest.raises(KeyError):
        g.selection_indices(full)
    # a typo'd name must raise even when other names match — silently
    # dropping an axis would steer the whole search wrong
    g2 = DesignGoal(name="typo", objectives=("energy_max_alpha_j",
                                             "latency_max_alpa_s"))
    with pytest.raises(KeyError, match="latency_max_alpa_s"):
        g2.selection_indices(full)
    g3 = DesignGoal(name="badplat", platforms=("no_such_platform",))
    with pytest.raises(KeyError, match="no_such_platform"):
        g3.selection_indices(full)


def test_goal_constraint_inheritance():
    fallback = Constraints(0.7, 0.3)
    assert GOALS["low_energy"].effective_constraints(fallback) == fallback
    g = DesignGoal(name="strict", constraints=Constraints(0.95, 0.05))
    assert g.effective_constraints(fallback) == Constraints(0.95, 0.05)
