"""shard_map expert-parallel MoE == pjit sort MoE (values + grads).

Runs in a subprocess with 8 forced host devices (the main pytest process
must keep a single device)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_ep_a2a_equivalence_subprocess():
    script = os.path.join(os.path.dirname(__file__),
                          "ep_equivalence_check.py")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "EP equivalence OK" in proc.stdout
