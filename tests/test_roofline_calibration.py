"""TPURooflineBackend calibration sanity against measured dry-run cells.

The ROADMAP flags the genome-scoring roofline as uncalibrated against the
measured path (``launch/dryrun.py`` compiles real cells and derives roofline
terms from partitioned HLO).  Both paths route through the *same*
``TPU_ROOFLINE.roofline_terms`` helper, so calibration drift can only enter
through (a) the hardware constants and (b) each path's raw FLOP/byte
quantities.  This gate checks both:

* always: the genome-scoring columns of :class:`TPURooflineBackend` are
  self-consistent with ``roofline_terms`` applied to the genome's own
  FLOP/byte totals (the scoring path cannot silently fork the constants);
* when measured cells exist (``results/*.jsonl`` from a dry-run sweep):
  re-deriving every recorded cell's terms from its raw per-device
  quantities must reproduce the recorded ``compute_s / memory_s /
  collective_s`` within tolerance — if the shared constants move, the
  recorded cells catch it.  Skips (does not pass vacuously) when no sweep
  has been run on this checkout.
"""
import json
import os

import numpy as np
import pytest

from repro.core.cost_backend import TPU_ROOFLINE, TPURooflineBackend
from repro.core.genome import PopulationEncoding, random_genome
from repro.core.hw_model import HBM_BW, PEAK_FLOPS_BF16, roofline
from repro.core.search_space import DEFAULT_SPACE

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
RTOL = 1e-6          # same-constants reproduction: tight
CHIPS = {"16x16": 256, "2x16x16": 512}


def _load_cells():
    cells = []
    if not os.path.isdir(RESULTS):
        return cells
    for name in sorted(os.listdir(RESULTS)):
        if not (name.endswith(".jsonl")
                and name.startswith(("dryrun_", "final_"))):
            continue
        with open(os.path.join(RESULTS, name)) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok") and not r.get("note", "").startswith(
                        "SKIPPED") and r.get("flops_dev", 0) > 0:
                    cells.append(r)
    return cells


def test_genome_scoring_consistent_with_shared_roofline():
    """The backend's latency columns must be exactly what roofline_terms
    yields for the genome's own FLOP/byte totals (scoring never forks the
    constants)."""
    rng = np.random.default_rng(0)
    genomes = [random_genome(rng, DEFAULT_SPACE) for _ in range(64)]
    enc = PopulationEncoding.from_genomes(genomes)
    be = TPURooflineBackend()
    objs = be.evaluate_batch(enc, space=DEFAULT_SPACE)
    lat_min, lat_max = objs[:, 4], objs[:, 5]
    from repro.core.hw_model import population_layer_costs
    costs = population_layer_costs(enc, DEFAULT_SPACE)
    macs = np.where(costs.valid, costs.total_macs, 0).sum(axis=1) \
        .astype(np.float64)
    params = np.where(costs.valid, costs.params, 0).sum(axis=1)
    act = np.where(costs.valid, costs.out_len * costs.out_channels, 0) \
        .sum(axis=1).astype(np.float64)
    w_bits = np.asarray(DEFAULT_SPACE.weight_bits, np.float64)[enc.w_bits]
    a_bits = np.asarray(DEFAULT_SPACE.act_bits, np.float64)[enc.a_bits]
    bytes_hbm = params * w_bits / 8.0 + act * a_bits / 8.0
    for i in range(len(enc)):
        terms = TPU_ROOFLINE.roofline_terms(2.0 * macs[i],
                                            float(bytes_hbm[i]), 0.0, 1)
        assert np.isclose(lat_max[i],
                          max(terms.compute_s, terms.memory_s), rtol=RTOL)
        # fully folded datapath is never faster than the roofline bound
        assert lat_min[i] >= lat_max[i] - 1e-12
    # the shared singleton and the raw function agree (one source of truth)
    t = TPU_ROOFLINE.roofline_terms(1e15, 1e12, 1e10, 4)
    r = roofline(1e15, 1e12, 1e10, 4)
    assert (t.compute_s, t.memory_s, t.collective_s) \
        == (r.compute_s, r.memory_s, r.collective_s)
    assert np.isclose(t.compute_s, 1e15 / (4 * PEAK_FLOPS_BF16), rtol=RTOL)
    assert np.isclose(t.memory_s, 1e12 / (4 * HBM_BW), rtol=RTOL)


def test_measured_cells_reproduce_under_current_constants():
    """Tolerance gate: every recorded dry-run cell's roofline terms must be
    reproducible from its raw per-device quantities with today's shared
    constants.  Skips when no dry-run sweep has produced cells."""
    cells = _load_cells()
    if not cells:
        pytest.skip("no measured dry-run cells under results/ "
                    "(run python -m repro.launch.dryrun --out ...)")
    for r in cells:
        chips = CHIPS.get(r["mesh"])
        assert chips is not None, f"unknown mesh {r['mesh']!r}"
        terms = TPU_ROOFLINE.roofline_terms(
            r["flops_dev"] * chips, r["bytes_dev"] * chips,
            r["coll_dev"] * chips, chips)
        cell_id = f"{r['arch']}x{r['shape']}x{r['mesh']}"
        assert np.isclose(terms.compute_s, r["compute_s"],
                          rtol=RTOL, atol=1e-12), cell_id
        assert np.isclose(terms.memory_s, r["memory_s"],
                          rtol=RTOL, atol=1e-12), cell_id
        assert np.isclose(terms.collective_s, r["collective_s"],
                          rtol=RTOL, atol=1e-12), cell_id
        assert terms.dominant == r["dominant"], cell_id
