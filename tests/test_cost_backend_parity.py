"""Batched population engine vs the scalar Eq. 1-4 reference path.

The batched engine must be *bit-for-bit* consistent with the scalar
``estimate``/``cheap_objectives`` path on every profile and strategy — the
assertions here use exact equality, which trivially satisfies the rtol 1e-9
contract.  Edge cases: single-layer phenotypes, fully-folded alpha, and
alpha_cap saturation.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.cost_backend import (
    FPGAAnalyticBackend,
    TPU_ROOFLINE,
    TPURooflineBackend,
    get_backend,
)
from repro.core.genome import Genome, PopulationEncoding, random_genome
from repro.core.hw_model import (
    FPGA_ZU,
    PROFILES,
    batch_resolve_alphas,
    estimate,
    estimate_population,
    population_layer_costs,
)
from repro.core.objectives import (
    CHEAP_NAMES,
    cheap_objectives,
    cheap_objectives_batch,
)
from repro.core.search_space import DEFAULT_SPACE, SearchSpace

N_SWEEP = 200
_FIELDS = ("t_total_s", "latency_s", "p_total_w", "e_total_j", "e_wall_j",
           "throughput_sps", "params", "total_macs")


@pytest.fixture(scope="module")
def sweep():
    rng = np.random.default_rng(0)
    genomes = [random_genome(rng, DEFAULT_SPACE) for _ in range(N_SWEEP)]
    return genomes, PopulationEncoding.from_genomes(genomes)


# ---------------------------------------------------------------- encoding

def test_encoding_round_trips(sweep):
    genomes, enc = sweep
    assert enc.to_genomes() == genomes


def test_batch_phenotype_hash_matches_scalar(sweep):
    genomes, enc = sweep
    assert enc.batch_phenotype_hash(DEFAULT_SPACE) == \
        [g.phenotype_hash(DEFAULT_SPACE) for g in genomes]


def test_batch_decode_matches_scalar(sweep):
    genomes, enc = sweep
    path, depth = enc.decode_paths()
    for i, g in enumerate(genomes):
        active = g.active_nodes()
        assert depth[i] == len(active)
        assert path[i, :depth[i]].tolist() == active


# ----------------------------------------------------- estimate parity sweep

@pytest.mark.parametrize("profile", list(PROFILES.values()),
                         ids=list(PROFILES))
@pytest.mark.parametrize("strategy", ["min", "max"])
def test_estimate_parity(sweep, profile, strategy):
    """>= 200 random genomes, exact equality on every HwEstimate field."""
    genomes, enc = sweep
    batch = estimate_population(enc, strategy=strategy, profile=profile)
    for i, g in enumerate(genomes):
        ref = estimate(g, strategy=strategy, profile=profile)
        row = batch.row(i)
        assert row.alphas == ref.alphas
        for field in _FIELDS:
            assert getattr(row, field) == getattr(ref, field), \
                (profile.name, strategy, i, field)


@pytest.mark.parametrize("profile", list(PROFILES.values()),
                         ids=list(PROFILES))
def test_cheap_objectives_parity(sweep, profile):
    genomes, enc = sweep
    batch = cheap_objectives_batch(enc, profile=profile)
    assert batch.shape == (len(genomes), len(CHEAP_NAMES))
    for i, g in enumerate(genomes):
        assert np.array_equal(batch[i], cheap_objectives(g, profile=profile))


# ------------------------------------------------------------- edge cases

def _single_layer_genome_and_space():
    space = dataclasses.replace(DEFAULT_SPACE, min_depth=1)
    g = Genome(op_genes=(0,) * space.max_depth,
               conn_genes=(0,) * space.max_depth,
               out_gene=1, w_bits_gene=0, a_bits_gene=0, i_bits_gene=0,
               dec_gene=0)
    assert g.depth() == 1
    return g, space


def test_single_layer_phenotype_parity():
    g, space = _single_layer_genome_and_space()
    enc = PopulationEncoding.from_genomes([g])
    for strategy in ("min", "max"):
        ref = estimate(g, strategy=strategy, profile=FPGA_ZU, space=space)
        row = estimate_population(enc, strategy=strategy, profile=FPGA_ZU,
                                  space=space).row(0)
        assert row.alphas == ref.alphas
        for field in _FIELDS:
            assert getattr(row, field) == getattr(ref, field)


def test_fully_folded_alphas_are_all_one(sweep):
    _, enc = sweep
    costs = population_layer_costs(enc, DEFAULT_SPACE)
    alphas = batch_resolve_alphas(costs, "min", FPGA_ZU)
    assert (alphas == 1).all()


@pytest.mark.parametrize("cap", [8, 24, 100])
def test_alpha_cap_saturation_parity(sweep, cap):
    """Tiny resource budgets exercise the partial budget-boundary step."""
    genomes, enc = sweep
    tight = dataclasses.replace(FPGA_ZU, alpha_cap=cap)
    batch = estimate_population(enc, strategy="max", profile=tight)
    costs = population_layer_costs(enc, DEFAULT_SPACE)
    used = np.where(costs.valid, batch.alphas, 0).sum(axis=1)
    # one unit per layer is the free baseline; unrolling beyond it must
    # respect the cap (caps below the layer count leave everything folded)
    assert (used <= np.maximum(cap, costs.n_layers)).all()
    for i in range(0, len(genomes), 7):
        ref = estimate(genomes[i], strategy="max", profile=tight)
        assert batch.row(i).alphas == ref.alphas


def test_alpha_bounds(sweep):
    _, enc = sweep
    costs = population_layer_costs(enc, DEFAULT_SPACE)
    for profile in PROFILES.values():
        alphas = batch_resolve_alphas(costs, "max", profile)
        assert (alphas[costs.valid] >= 1).all()
        assert (alphas <= costs.alpha_max)[costs.valid].all()


# ----------------------------------------------------------- backend layer

def test_get_backend_resolution():
    be = get_backend(FPGA_ZU)
    assert isinstance(be, FPGAAnalyticBackend)
    assert get_backend(FPGA_ZU) is be          # cached per profile
    assert get_backend("fpga_zu").profile is FPGA_ZU
    assert get_backend("tpu_roofline") is TPU_ROOFLINE
    assert get_backend(be) is be               # pass-through
    with pytest.raises(KeyError):
        get_backend("no_such_backend")


def test_tpu_roofline_backend_shape_and_monotonicity(sweep):
    genomes, enc = sweep
    objs = TPURooflineBackend().evaluate_batch(enc, space=DEFAULT_SPACE)
    assert objs.shape == (len(genomes), len(CHEAP_NAMES))
    assert np.isfinite(objs).all() and (objs > 0).all()
    # max-alpha never slower, never cheaper in power than fully folded
    assert (objs[:, 5] <= objs[:, 4] + 1e-12).all()   # latency
    assert (objs[:, 1] >= objs[:, 0] - 1e-12).all()   # power
    # single-genome evaluate agrees with the batch row
    assert np.array_equal(TPURooflineBackend().evaluate(genomes[0]), objs[0])


def test_evolution_routes_through_batch_backend():
    """EvolutionarySearch init + child scoring produce the same cheap
    objectives the scalar path would (and use the configured backend)."""
    from repro.core.evolution import EvolutionarySearch, NASConfig
    from repro.core.trainer import TrainResult

    def fake_train(g):
        return TrainResult(detection_rate=0.95, false_alarm_rate=0.05,
                           val_loss=0.1, steps=0)

    cfg = NASConfig(generations=1, children_per_gen=6, n_accept=2,
                    init_population=5, n_workers=1, seed=3)
    s = EvolutionarySearch(cfg, None, None, train_fn=fake_train,
                           log=lambda *_: None)
    assert isinstance(s.backend, FPGAAnalyticBackend)
    state = s.init_state()
    for c in state.population:
        assert np.array_equal(c.cheap, cheap_objectives(
            c.genome, profile=cfg.profile))
    state = s.step(state)
    for c in state.population:
        assert np.array_equal(c.cheap, cheap_objectives(
            c.genome, profile=cfg.profile))
