"""Bucketed vmap-stacked training: scalar parity, bucketing, cache, routing.

The parity contract (DESIGN.md §9): under matched seeds, every candidate of
a mixed-signature population gets *identical* expensive objectives from the
batched and scalar paths (detection / false-alarm rates are exact; val_loss
agrees to float32 reassociation noise).
"""
import numpy as np
import pytest

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.genome import Genome
from repro.core.objectives import expensive_objectives
from repro.core.search_space import SearchSpace
from repro.core.trainer import TrainResult, train_candidate
from repro.core.trainer_batch import (
    bucket_by_signature,
    compile_cache_stats,
    reset_compile_cache,
    shape_signature,
    train_candidates_batched,
)

# coarse decimation => 250-sample inputs: training stays test-sized
SPACE = SearchSpace(input_decimations=(240,))


def chain_genome(op_ids, quant=(0, 0, 0), dec=0) -> Genome:
    """A plain-chain genome expressing exactly ``op_ids`` (+ the head)."""
    d = SPACE.max_depth
    return Genome(op_genes=tuple(op_ids) + (0,) * (d - len(op_ids)),
                  conn_genes=tuple(range(d)), out_gene=len(op_ids),
                  w_bits_gene=quant[0], a_bits_gene=quant[1],
                  i_bits_gene=quant[2], dec_gene=dec)


# op-table ids (op = channels_idx*12 + kernel_idx*3 + stride_idx):
CONV_C8_K3_S2 = 28
CONV_C4_K5_S4 = 20
CONV_C16_K1_S1 = 36
POOL_S2 = 60


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    x_tr = rng.normal(size=(64, 250, 2)).astype(np.float32)
    x_va = rng.normal(size=(48, 250, 2)).astype(np.float32)
    y_tr = (np.arange(64) % 2).astype(np.int32)
    y_va = (np.arange(48) % 2).astype(np.int32)
    return (x_tr, y_tr), (x_va, y_va)


def mixed_population():
    """Two signature buckets (3 + 2 members, quant variants inside each)
    plus a singleton that exercises the scalar fallback."""
    a = [chain_genome((CONV_C8_K3_S2, CONV_C4_K5_S4), quant=q)
         for q in ((0, 0, 0), (1, 1, 1), (0, 1, 0))]
    b = [chain_genome((CONV_C16_K1_S1, POOL_S2), quant=q)
         for q in ((1, 0, 1), (0, 0, 1))]
    c = [chain_genome((POOL_S2, CONV_C8_K3_S2))]
    return a + b + c


def test_shape_signature_buckets_quant_variants_together():
    pop = mixed_population()
    sigs = [shape_signature(g, SPACE) for g in pop]
    assert sigs[0] == sigs[1] == sigs[2]      # precision is data, not shape
    assert sigs[3] == sigs[4]
    assert len({sigs[0], sigs[3], sigs[5]}) == 3
    buckets = bucket_by_signature(pop, SPACE)
    assert sorted(map(len, buckets.values()), reverse=True) == [3, 2, 1]
    # phenotype hashes all differ (the search would have deduped otherwise)
    assert len({g.phenotype_hash(SPACE) for g in pop}) == len(pop)


def test_batched_matches_scalar_on_mixed_population(data):
    tr, va = data
    pop = mixed_population()
    kw = dict(space=SPACE, steps=12, batch_size=16, lr=3e-3, seed=0)
    scalar = [train_candidate(g, tr, va, **kw) for g in pop]
    batched = train_candidates_batched(pop, tr, va, **kw)
    assert len(batched) == len(pop)
    for s, b in zip(scalar, batched):
        # expensive objectives identical (the search sees the same numbers)
        np.testing.assert_array_equal(expensive_objectives(s),
                                      expensive_objectives(b))
        assert b.steps == s.steps
        assert abs(s.val_loss - b.val_loss) < 5e-3


def test_per_candidate_seeds_match_scalar(data):
    tr, va = data
    pop = [chain_genome((CONV_C8_K3_S2, CONV_C4_K5_S4), quant=(0, 0, 0)),
           chain_genome((CONV_C8_K3_S2, CONV_C4_K5_S4), quant=(1, 1, 1))]
    kw = dict(space=SPACE, steps=10, batch_size=16, lr=3e-3)
    batched = train_candidates_batched(pop, tr, va, seeds=[3, 4], **kw)
    for g, s, b in zip(pop, (3, 4), batched):
        ref = train_candidate(g, tr, va, seed=s, **kw)
        np.testing.assert_array_equal(expensive_objectives(ref),
                                      expensive_objectives(b))


def test_compile_cache_hits_across_generations(data):
    tr, va = data
    pop = mixed_population()
    kw = dict(space=SPACE, steps=2, batch_size=8, lr=3e-3, seed=0)
    reset_compile_cache()
    train_candidates_batched(pop, tr, va, **kw)
    stats = compile_cache_stats()
    # one compiled pair per multi-candidate bucket; the singleton goes scalar
    assert stats == {"hits": 0, "misses": 2, "size": 2}
    train_candidates_batched(pop, tr, va, **kw)  # "next generation"
    stats = compile_cache_stats()
    assert stats["hits"] == 2 and stats["misses"] == 2 and stats["size"] == 2


def test_seeds_must_align():
    with pytest.raises(ValueError):
        train_candidates_batched(mixed_population(), None, None,
                                 space=SPACE, seeds=[0])


def test_evolution_dispatches_signature_buckets(data):
    """The search routes whole generations through bucketed training: the
    injected batch trainer sees signature-homogeneous genome lists and its
    results land on the right population rows."""
    tr, va = data
    calls = []

    def fake_batch_train(genomes):
        calls.append(genomes)
        return [TrainResult(detection_rate=0.95,
                            false_alarm_rate=0.01 * g.depth(),
                            val_loss=0.1, steps=0) for g in genomes]

    cfg = NASConfig(generations=1, children_per_gen=6, n_accept=3,
                    init_population=5, n_workers=2, seed=0)
    s = EvolutionarySearch(cfg, tr, va, space=SPACE,
                           batch_train_fn=fake_batch_train,
                           log=lambda *_: None)
    state = s.run()
    assert state.generation == 1
    assert calls, "batched trainer was never dispatched"
    for genomes in calls:
        assert len({str(shape_signature(g, SPACE)) for g in genomes}) == 1
    # results were scattered back per candidate
    trained = state.pop.trained_mask
    assert trained.any()
    got = state.pop.expensive[trained]
    assert np.all(got[:, 0] == 1.0 - 0.95)  # miss everywhere


def test_bucket_failure_marks_all_members_pessimistic(data):
    tr, va = data

    def exploding_batch_train(genomes):
        raise RuntimeError("bucket OOM")

    cfg = NASConfig(generations=1, children_per_gen=4, n_accept=2,
                    init_population=4, n_workers=2, seed=0)
    s = EvolutionarySearch(cfg, tr, va, space=SPACE,
                           batch_train_fn=exploding_batch_train,
                           log=lambda *_: None)
    s.scheduler.max_retries = 0
    state = s.init_state()
    assert state.pop.trained_mask.all()
    np.testing.assert_array_equal(
        state.pop.expensive, np.ones_like(state.pop.expensive))


def test_explicit_device_placement_is_pure_routing(data):
    """``device=`` commits the staged arrays to one accelerator but never
    changes the numbers: results on ``jax.devices()[0]`` equal the
    uncommitted default bit for bit, and the compile cache keys the device
    so per-device executables don't evict each other."""
    import jax
    tr, va = data
    pop = mixed_population()
    kw = dict(space=SPACE, steps=6, batch_size=16, lr=3e-3, seed=0)
    ref = train_candidates_batched(pop, tr, va, **kw)
    dev = jax.devices()[0]
    reset_compile_cache()
    got = train_candidates_batched(pop, tr, va, device=dev, **kw)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(expensive_objectives(r),
                                      expensive_objectives(g))
    stats = compile_cache_stats()
    assert stats["misses"] == 2 and stats["hits"] == 0
    train_candidates_batched(pop, tr, va, device=dev, **kw)
    assert compile_cache_stats()["hits"] == 2


def test_stage_cache_keys_include_device(data):
    """The staged-dataset cache holds one entry per (length, device) — a
    device-affine search reuses the device-resident copy across
    generations instead of re-transferring."""
    import jax
    tr, va = data
    pop = mixed_population()[:3]  # one 3-member bucket
    cache = {}
    kw = dict(space=SPACE, steps=2, batch_size=8, lr=3e-3, seed=0,
              stage_cache=cache)
    train_candidates_batched(pop, tr, va, **kw)
    train_candidates_batched(pop, tr, va, device=jax.devices()[0], **kw)
    devices_in_keys = {k[-1] for k in cache}
    assert None in devices_in_keys
    assert jax.devices()[0] in devices_in_keys
