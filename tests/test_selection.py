"""Selection machinery degenerate cases + density chunking equivalence."""
import numpy as np

from repro.core.selection import (
    GaussianKDE,
    inverse_density_weights,
    preselect_children,
    sample_parents,
)


# ------------------------------------------------------------ KDE basics

def test_density_chunking_is_equivalent():
    # chunk size must not change results (up to BLAS shape-dependent
    # rounding in the distance GEMM)
    rng = np.random.default_rng(0)
    kde = GaussianKDE(rng.normal(size=(40, 5)))
    q = rng.normal(size=(37, 5))
    full = kde.density(q, chunk=10**9)
    np.testing.assert_allclose(kde.density(q, chunk=1), full, rtol=1e-12)
    np.testing.assert_allclose(kde.density(q, chunk=7), full, rtol=1e-12)
    np.testing.assert_allclose(kde.density(q), full, rtol=1e-12)


def test_density_matches_naive_broadcast_reference():
    rng = np.random.default_rng(4)
    data = rng.normal(size=(25, 3))
    q = rng.normal(size=(11, 3))
    kde = GaussianKDE(data)
    z = (q[:, None, :] - data[None, :, :]) / kde.h[None, None, :]
    ref = np.exp(-0.5 * np.sum(z * z, axis=-1)).sum(axis=1) \
        / (len(data) * np.prod(kde.h) * (2 * np.pi) ** 1.5) + 1e-300
    np.testing.assert_allclose(kde.density(q), ref, rtol=1e-9)


def test_density_auto_chunk_bounded_at_large_population():
    # pop 10k+: the (m, n, d) broadcast must not materialize at full m
    rng = np.random.default_rng(1)
    data = rng.normal(size=(12_000, 7))
    kde = GaussianKDE(data)
    d = kde.density(data[:3000])
    assert d.shape == (3000,) and np.isfinite(d).all() and (d > 0).all()


# ----------------------------------------------------- degenerate inputs

def test_identical_point_population_gives_uniform_weights():
    # zero variance trips the KDE sigma floor; every point has the same
    # density, so inverse-density weights must come out uniform
    pts = np.full((8, 3), 4.2)
    w = inverse_density_weights(pts)
    np.testing.assert_allclose(w, np.full(8, 1 / 8))
    idx = sample_parents(np.random.default_rng(0), pts, 5)
    assert idx.shape == (5,) and (idx >= 0).all() and (idx < 8).all()


def test_single_member_population():
    pts = np.asarray([[1.0, 2.0, 3.0]])
    w = inverse_density_weights(pts)
    np.testing.assert_allclose(w, [1.0])
    idx = sample_parents(np.random.default_rng(0), pts, 3)
    assert idx.tolist() == [0, 0, 0]
    kept = preselect_children(np.random.default_rng(0), pts,
                              np.asarray([[0.5, 0.5, 0.5]]), 4)
    assert kept.tolist() == [0]


def test_preselect_children_with_non_finite_weights():
    rng = np.random.default_rng(2)
    pop = rng.normal(size=(10, 3))
    children = rng.normal(size=(20, 3))
    children[::2] = np.nan  # NaN queries poison the KDE weights
    idx = preselect_children(rng, pop, children, 6)
    assert len(idx) == 6
    assert len(set(idx.tolist())) == 6
    assert idx.min() >= 0 and idx.max() < 20


def test_preselect_children_with_degenerate_population():
    # identical-point population + far-away children: the KDE densities
    # underflow but the guard must still return a valid unique index set
    rng = np.random.default_rng(3)
    pop = np.zeros((6, 4))
    children = rng.normal(loc=1e6, size=(15, 4))
    idx = preselect_children(rng, pop, children, 5)
    assert len(idx) == 5 and len(set(idx.tolist())) == 5
    assert idx.min() >= 0 and idx.max() < 15
