"""hwlib: layer costs match real shapes; quantization & BN folding; profiler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.genome import random_genome
from repro.core.search_space import DEFAULT_SPACE
from repro.core.trainer import forward, init_candidate
from repro.hwlib.layers import (
    DWSEP_CONV,
    LayerSpec,
    apply_layer,
    init_layer,
    layer_cost,
    out_shape,
)
from repro.hwlib.profiler import profile_accumulators
from repro.hwlib.quant import (
    QuantConfig,
    fake_quant,
    fold_batchnorm,
    fold_model,
)


@given(seed=st.integers(0, 3000))
@settings(max_examples=30, deadline=None)
def test_cost_model_matches_real_shapes(seed):
    """The analytic (out_len, channels) must equal the traced shapes."""
    g = random_genome(np.random.default_rng(seed), DEFAULT_SPACE)
    specs = g.phenotype(DEFAULT_SPACE)
    params = init_candidate(jax.random.PRNGKey(0), specs)
    x = jnp.zeros((2, g.input_length(DEFAULT_SPACE), 2))
    l, c = x.shape[1], 2
    h = x
    for p, s in zip(params, specs):
        cost = layer_cost(s, l, c)
        h = apply_layer(p, s, h, train=False)
        if s.kind in (DWSEP_CONV, "maxpool"):
            assert h.shape == (2, cost.out_len, cost.out_channels)
        else:
            assert h.shape == (2, cost.out_channels)
        l, c = cost.out_len, cost.out_channels
        assert cost.params == sum(
            int(np.prod(v.shape)) for k, v in p.items()
            if k in ("dw", "pw", "b", "w"))


@given(bits=st.integers(2, 16), seed=st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_fake_quant_properties(bits, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(64,)) * 3)
    q = fake_quant(x, bits)
    # bounded distortion: one quantization step
    step = float(jnp.max(jnp.abs(x))) / (2 ** (bits - 1) - 1)
    assert float(jnp.max(jnp.abs(q - x))) <= step + 1e-6
    # idempotent-ish: quantizing a quantized tensor changes nothing
    q2 = fake_quant(q, bits)
    assert float(jnp.max(jnp.abs(q2 - q))) <= 1e-6
    # 32 bits = identity
    assert jnp.allclose(fake_quant(x, 32), x)


def test_bn_folding_preserves_inference():
    spec = LayerSpec(kind=DWSEP_CONV, out_channels=8, kernel_size=3,
                     stride=1, use_bn=True)
    params = init_layer(jax.random.PRNGKey(0), spec, 4)
    # make running stats non-trivial
    params["bn_mean"] = jnp.asarray(np.random.default_rng(0).normal(size=8),
                                    jnp.float32)
    params["bn_var"] = jnp.asarray(
        np.random.default_rng(1).uniform(0.5, 2.0, 8), jnp.float32)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 32, 4)),
                    jnp.float32)
    y_bn = apply_layer(params, spec, x, train=False)
    folded = fold_batchnorm(params, spec)
    assert "bn_scale" not in folded
    y_folded = apply_layer(folded, spec, x, train=False)
    np.testing.assert_allclose(np.asarray(y_bn), np.asarray(y_folded),
                               rtol=1e-4, atol=1e-5)


def test_fold_model_then_forward(tiny_ecg):
    g = random_genome(np.random.default_rng(5), DEFAULT_SPACE)
    specs = g.phenotype(DEFAULT_SPACE)
    params = init_candidate(jax.random.PRNGKey(1), specs)
    x = jnp.asarray(tiny_ecg[0][0][:4, :g.input_length(DEFAULT_SPACE)])
    y_ref = forward(params, specs, x)
    folded = fold_model(params, specs)
    y_fold = forward(folded, specs, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fold),
                               rtol=1e-3, atol=1e-4)


def test_profiler_formats_cover_range():
    g = random_genome(np.random.default_rng(7), DEFAULT_SPACE)
    specs = g.phenotype(DEFAULT_SPACE)
    params = init_candidate(jax.random.PRNGKey(2), specs)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(8, g.input_length(DEFAULT_SPACE), 2)), jnp.float32)
    formats = profile_accumulators(params, specs, x)
    assert len(formats) == len(specs)
    for f in formats:
        assert f.int_bits >= 1 and f.frac_bits >= 0
        assert f.total_bits <= 40  # sane accumulator width
