"""Multi-device orchestration check — run as a subprocess (test_multi_device).

Forces 4 host platform devices (the flag must land before jax initializes,
which is why this is not an in-process test) and verifies the device-affine
search orchestration (DESIGN.md §11):

1. the search resolves all 4 devices and widens its worker pool to match;
2. training buckets actually land on more than one device;
3. per-device busy time shows up in the generation records;
4. the ``off`` and ``host_overlap`` pipelines produce bit-identical
   trajectories *with affinity on* — placement is routing, not semantics;
5. the real bucketed trainer returns bit-identical expensive objectives on
   an explicitly chosen device vs. the uncommitted default (host CPU
   devices run the same program — the foundation of the parity contract).
"""
import os
import sys
import time

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.evolution import EvolutionarySearch, NASConfig  # noqa: E402
from repro.core.genome import Genome  # noqa: E402
from repro.core.objectives import expensive_objectives  # noqa: E402
from repro.core.search_space import SearchSpace  # noqa: E402
from repro.core.trainer import TrainResult  # noqa: E402
from repro.core.trainer_batch import train_candidates_batched  # noqa: E402

assert len(jax.local_devices()) == 4, jax.local_devices()


# ---- 1-4: device-affine search, parity with affinity on ------------------
seen_devices = set()


def fake_batch_train(genomes, device=None):
    seen_devices.add(str(device))
    time.sleep(0.05)  # long enough that other workers engage
    return [TrainResult(detection_rate=min(0.99, 0.70 + 0.05 * g.depth()),
                        false_alarm_rate=max(0.0, 0.30 - 0.04 * g.depth()),
                        val_loss=0.2, steps=0) for g in genomes]


def run(pipeline):
    cfg = NASConfig(generations=3, children_per_gen=10, n_accept=4,
                    init_population=8, population_cap=16, n_workers=2,
                    seed=5, pipeline=pipeline, device_affinity=True)
    s = EvolutionarySearch(cfg, None, None, batch_train_fn=fake_batch_train,
                           log=lambda *_: None)
    assert s.devices is not None and len(s.devices) == 4
    assert s.scheduler.n_workers == 4  # widened to cover every device
    return s.run()


a = run("off")
b = run("host_overlap")
assert list(a.pop.phash) == list(b.pop.phash)
assert np.array_equal(a.pop.cheap, b.pop.cheap)
assert np.array_equal(a.pop.expensive, b.pop.expensive)
assert len(seen_devices) >= 2, f"buckets never spread out: {seen_devices}"
busy_keys = {k for rec in a.history for k in rec["device_busy_s"]}
assert any(k != "default" for k in busy_keys), busy_keys


# ---- 5: real bucketed training is device-invariant -----------------------
SPACE = SearchSpace(input_decimations=(240,))


def chain_genome(op_ids, quant=(0, 0, 0)):
    d = SPACE.max_depth
    return Genome(op_genes=tuple(op_ids) + (0,) * (d - len(op_ids)),
                  conn_genes=tuple(range(d)), out_gene=len(op_ids),
                  w_bits_gene=quant[0], a_bits_gene=quant[1],
                  i_bits_gene=quant[2], dec_gene=0)


rng = np.random.default_rng(7)
tr = (rng.normal(size=(32, 250, 2)).astype(np.float32),
      (np.arange(32) % 2).astype(np.int32))
va = (rng.normal(size=(24, 250, 2)).astype(np.float32),
      (np.arange(24) % 2).astype(np.int32))
pop = [chain_genome((28, 20), quant=(0, 0, 0)),   # 2-member bucket
       chain_genome((28, 20), quant=(1, 1, 1)),
       chain_genome((60, 28))]                    # singleton: scalar path
kw = dict(space=SPACE, steps=4, batch_size=8, lr=3e-3, seed=0)
ref = train_candidates_batched(pop, tr, va, **kw)
for dev in jax.local_devices()[1:3]:
    got = train_candidates_batched(pop, tr, va, device=dev, **kw)
    for r, g in zip(ref, got):
        assert np.array_equal(expensive_objectives(r),
                              expensive_objectives(g)), (dev, r, g)

print("MULTI_DEVICE_OK", sorted(seen_devices))
