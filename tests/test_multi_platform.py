"""Multi-platform scoring pipeline + goal-conditioned selection.

Two contracts (ISSUE 5 acceptance):

* a single-backend ``MultiPlatformBackend([fpga_zu])`` reproduces the PR-1
  engine's ``(N, 7)`` matrix bit-for-bit and an identical search trajectory
  / Pareto fronts under fixed seeds (the shared-context evaluation path
  changes no floats);
* a seeded multi-platform search yields per-platform and cross-platform
  Pareto fronts, and the paper's three design-goal presets select distinct
  front members on the same seed.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.cost_backend import (
    FPGAAnalyticBackend,
    MultiPlatformBackend,
    TPURooflineBackend,
    get_backend,
)
from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.genome import PopulationEncoding, random_genome
from repro.core.hw_model import (
    FPGA_ZCU102,
    FPGA_ZU,
    PROFILES,
    SharedPopulationEval,
    batch_resolve_alphas,
    population_layer_costs,
)
from repro.core.objective_schema import CHEAP_NAMES, GOALS
from repro.core.pareto import pareto_front
from repro.core.search_space import DEFAULT_SPACE
from repro.core.trainer import TrainResult

N_SWEEP = 160


@pytest.fixture(scope="module")
def sweep():
    rng = np.random.default_rng(7)
    genomes = [random_genome(rng, DEFAULT_SPACE) for _ in range(N_SWEEP)]
    return PopulationEncoding.from_genomes(genomes)


def _mock_train(g):
    det = min(0.99, 0.75 + 0.04 * g.depth())
    return TrainResult(detection_rate=det,
                       false_alarm_rate=max(0.0, 0.25 - 0.03 * g.depth()),
                       val_loss=0.3, steps=0)


def _search(**kw):
    kw = {"generations": 4, "children_per_gen": 10, "n_accept": 5,
          "init_population": 8, "n_workers": 2, "seed": 0, **kw}
    cfg = NASConfig(**kw)
    return EvolutionarySearch(cfg, None, None, train_fn=_mock_train,
                              log=lambda *_: None)


# ------------------------------------------------------- backend-level parity

def test_single_member_composite_is_bit_identical(sweep):
    """MultiPlatformBackend([fpga_zu]) == the PR-1 engine, exactly."""
    ref = FPGAAnalyticBackend(FPGA_ZU).evaluate_batch(sweep)
    multi = MultiPlatformBackend(["fpga_zu"])
    got = multi.evaluate_batch(sweep)
    assert got.shape == (len(sweep), len(CHEAP_NAMES))
    assert np.array_equal(got, ref)
    assert multi.schema.names == CHEAP_NAMES
    assert multi.schema.platforms == ("fpga_zu",)


def test_composite_columns_match_members_evaluated_alone(sweep):
    """Every member's column block is bit-identical to that backend run
    standalone — the shared decode/tabulation changes no floats."""
    members = ["fpga_zu", "fpga_zcu102", "tpu_roofline"]
    multi = MultiPlatformBackend(members)
    got = multi.evaluate_batch(sweep)
    assert got.shape == (len(sweep), 3 * len(CHEAP_NAMES))
    for k, name in enumerate(members):
        alone = get_backend(name).evaluate_batch(sweep)
        block = got[:, k * len(CHEAP_NAMES):(k + 1) * len(CHEAP_NAMES)]
        assert np.array_equal(block, alone), name
    # schema column groups line up with the blocks
    for k, platform in enumerate(multi.schema.platforms):
        idx = multi.schema.indices(platform=platform)
        np.testing.assert_array_equal(
            idx, np.arange(k * 7, (k + 1) * 7))


def test_alpha_event_table_parity_all_profiles_and_tight_caps(sweep):
    """The shared α event-table path must produce the binary-search path's
    factors exactly, including budget-boundary and negative-budget cases."""
    costs = population_layer_costs(sweep, DEFAULT_SPACE)
    ev = SharedPopulationEval(costs).alpha_events
    for profile in PROFILES.values():
        a = batch_resolve_alphas(costs, "max", profile)
        b = batch_resolve_alphas(costs, "max", profile, events=ev)
        assert np.array_equal(a, b), profile.name
    for cap in (8, 24, 100, 513):
        tight = dataclasses.replace(FPGA_ZU, alpha_cap=cap)
        a = batch_resolve_alphas(costs, "max", tight)
        b = batch_resolve_alphas(costs, "max", tight, events=ev)
        assert np.array_equal(a, b), cap


def test_nested_composites_flatten_and_duplicates_rejected():
    multi = MultiPlatformBackend(
        [MultiPlatformBackend(["fpga_zu"]), "tpu_roofline"])
    assert multi.schema.platforms == ("fpga_zu", "tpu_roofline")
    with pytest.raises(ValueError):
        MultiPlatformBackend(["fpga_zu", "fpga_zu"])
    with pytest.raises(ValueError):
        MultiPlatformBackend([])


def test_get_backend_resolves_sequences():
    be = get_backend(["fpga_zu", FPGA_ZCU102, TPURooflineBackend()])
    assert isinstance(be, MultiPlatformBackend)
    assert be.schema.platforms == ("fpga_zu", "fpga_zcu102", "tpu_roofline")


def test_composite_accepts_bare_protocol_members(sweep):
    """A third-party backend implementing only the documented protocol
    signature (no shared= kwarg) must work inside a composite."""

    class BareBackend:
        name = "bare"
        platform = "bare"

        def evaluate_batch(self, enc, *, space=DEFAULT_SPACE):
            return np.ones((len(enc), 7))

        def evaluate(self, g, *, space=DEFAULT_SPACE):
            return np.ones(7)

    multi = MultiPlatformBackend(["fpga_zu", BareBackend()])
    got = multi.evaluate_batch(sweep)
    assert got.shape == (len(sweep), 14)
    assert np.array_equal(got[:, 7:], np.ones((len(sweep), 7)))
    assert multi.schema.platforms == ("fpga_zu", "bare")


# ------------------------------------------------------ search-level parity

def test_single_backend_search_trajectory_is_bit_identical():
    """backends=[fpga_zu] must reproduce the default engine's whole
    trajectory: same phenotypes, same cheap matrices, same fronts."""
    ref = _search()
    ref_state = ref.run()
    multi = _search(backends=["fpga_zu"])
    got_state = multi.run()
    assert list(got_state.pop.phash) == list(ref_state.pop.phash)
    np.testing.assert_array_equal(got_state.pop.cheap, ref_state.pop.cheap)
    np.testing.assert_array_equal(got_state.pop.expensive,
                                  ref_state.pop.expensive)
    ref_front = pareto_front(ref_state.pop.objective_matrix())
    got_front = pareto_front(got_state.pop.objective_matrix())
    np.testing.assert_array_equal(ref_front, got_front)
    # end-of-run RNG streams identical -> later generations stay aligned
    assert multi.rng.bit_generator.state == ref.rng.bit_generator.state


# ------------------------------------------------- multi-platform search e2e

@pytest.fixture(scope="module")
def multi_state():
    s = _search(backends=["fpga_zu", "fpga_zcu102", "tpu_roofline"])
    return s, s.run()


def test_multi_platform_population_is_schema_shaped(multi_state):
    s, state = multi_state
    assert state.pop.cheap.shape[1] == 3 * len(CHEAP_NAMES)
    assert state.pop.cheap_schema is s.schema
    assert state.pop.objective_matrix().shape[1] == 3 * len(CHEAP_NAMES) + 2
    # resident cheap matrix agrees with a fresh composite evaluation
    np.testing.assert_array_equal(
        state.pop.cheap, s.backend.evaluate_batch(state.pop.enc,
                                                  space=s.space))


def test_per_platform_and_cross_platform_fronts(multi_state):
    s, state = multi_state
    fronts = s.pareto_fronts(state)
    assert set(fronts) == {"cross_platform", "fpga_zu", "fpga_zcu102",
                           "tpu_roofline"}
    objs = state.pop.objective_matrix()
    # cross-platform front == front over the full matrix
    np.testing.assert_array_equal(fronts["cross_platform"],
                                  pareto_front(objs))
    full = s.full_schema
    for platform in s.schema.platforms:
        cols = full.platform_group(platform)
        np.testing.assert_array_equal(fronts[platform],
                                      pareto_front(objs[:, cols]))
        # restricting objectives can only shrink the front
        assert set(fronts[platform]) <= set(fronts["cross_platform"])
        assert len(fronts[platform]) >= 1


def test_goal_presets_select_distinct_members(multi_state):
    """Paper §VI-B: the same searched population serves low-energy,
    low-power and high-throughput deployments — with different picks."""
    s, state = multi_state
    picks = {name: s.select_for_goal(state, name)
             for name in ("low_energy", "low_power", "high_throughput")}
    assert all(c is not None for c in picks.values())
    hashes = [c.phash for c in picks.values()]
    assert len(set(hashes)) == 3, hashes
    # every pick satisfies the effective constraints
    for c in picks.values():
        assert c.meets_constraints(s.constraints)


def test_select_solution_needs_platform_in_multi_schema(multi_state):
    s, state = multi_state
    with pytest.raises(KeyError):
        s.select_solution(state, "energy_max_alpha_j")  # ambiguous
    a = s.select_solution(state, "energy_max_alpha_j", platform="fpga_zu")
    b = s.select_solution(state, "fpga_zcu102:energy_max_alpha_j")
    assert a is not None and b is not None


# --------------------------------------------------- goal-conditioned smoke

@pytest.mark.parametrize("goal", ["balanced", "low_energy", "low_power",
                                  "high_throughput"])
def test_goal_preset_end_to_end_smoke(goal):
    """Seeded end-to-end run per preset: the search must drive selection
    through the goal's column subset and still produce a valid state."""
    s = _search(goal=goal, seed=11)
    state = s.run()
    assert state.generation == 4
    assert len(state.pop) <= s.cfg.population_cap
    assert len(state.history) == 4
    assert np.isfinite(state.pop.cheap).all()
    sol = s.select_for_goal(state)
    if sol is not None:
        assert sol.meets_constraints(s.constraints)
    cols = GOALS[goal].selection_indices(s.full_schema)
    fronts = pareto_front(state.pop.objective_matrix()[:, cols])
    assert len(fronts) >= 1


# ------------------------------------------------------------- checkpoints

def test_checkpoint_round_trip_multi_platform(tmp_path):
    s = _search(backends=["fpga_zu", "tpu_roofline"])
    state = s.init_state()
    state = s.step(state)
    path = str(tmp_path / "nas.json")
    s.save_state(state, path)
    restored = _search(backends=["fpga_zu", "tpu_roofline"]) \
        .load_state(path)
    np.testing.assert_array_equal(restored.pop.cheap, state.pop.cheap)
    assert restored.pop.cheap_schema == s.schema


def test_checkpoint_schema_mismatch_raises(tmp_path):
    s = _search(backends=["fpga_zu", "tpu_roofline"])
    state = s.init_state()
    path = str(tmp_path / "nas.json")
    s.save_state(state, path)
    with pytest.raises(ValueError, match="schema"):
        _search(backends=["fpga_zu", "fpga_zcu102"]).load_state(path)
    with pytest.raises(ValueError, match="schema"):
        _search().load_state(path)   # single-platform driver


def test_multi_platform_resume_is_bit_reproducible(tmp_path):
    kw = dict(backends=["fpga_zu", "fpga_zcu102"], goal="low_energy")
    ref_search = _search(**kw)
    ref = ref_search.init_state()
    for _ in range(4):
        ref = ref_search.step(ref)
    path = str(tmp_path / "nas.json")
    pre = _search(**kw)
    state = pre.init_state()
    for _ in range(2):
        state = pre.step(state)
        pre.save_state(state, path)
    resumed = _search(**kw).run_resumable(path, generations=4)
    assert list(resumed.pop.phash) == list(ref.pop.phash)
    np.testing.assert_array_equal(resumed.pop.cheap, ref.pop.cheap)
    np.testing.assert_array_equal(resumed.pop.expensive, ref.pop.expensive)
