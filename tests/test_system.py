"""End-to-end behaviour tests for the full system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.genome import random_genome
from repro.core.trainer import train_candidate
from repro.data.lm import LMDataConfig, data_iterator
from repro.models.registry import build_model
from repro.training.loop import LoopConfig, train_loop


@pytest.mark.slow
def test_nas_end_to_end_on_ecg(tiny_ecg):
    """The paper's flow at micro scale: the NAS must find a candidate that
    meets (relaxed) detection/false-alarm constraints on synthetic ECG."""
    (tr, va) = tiny_ecg
    cfg = NASConfig(generations=2, children_per_gen=4, n_accept=2,
                    init_population=3, train_steps=80, train_batch=32,
                    n_workers=2, seed=0, det_min=0.8, fa_max=0.3)
    search = EvolutionarySearch(cfg, tr, va, log=lambda *_: None)
    state = search.run()
    assert state.generation == 2
    feasible = [c for c in state.population if c.meets_constraints(0.8, 0.3)]
    assert feasible, "no candidate met detection>=0.8 / fa<=0.3"


@pytest.mark.slow
def test_candidate_training_learns(tiny_ecg):
    (tr, va) = tiny_ecg
    g = random_genome(np.random.default_rng(3))
    res = train_candidate(g, tr, va, steps=120, batch_size=32, seed=0)
    assert res.detection_rate > 0.6
    assert res.false_alarm_rate < 0.4


@pytest.mark.slow
def test_lm_training_reduces_loss(tmp_path):
    cfg = reduced_config("qwen2-0.5b")
    bundle = build_model(cfg)
    data_cfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                            global_batch=8)
    out = train_loop(
        bundle, lambda s: data_iterator(data_cfg, s),
        LoopConfig(total_steps=40, ckpt_every=1000, log_every=5,
                   ckpt_dir=str(tmp_path)),
        log=lambda *_: None)
    losses = out["losses"]
    assert losses[-1] < losses[0] - 0.5, losses


def test_serving_batched_requests():
    """Prefill a batch of prompts, decode several tokens greedily."""
    cfg = reduced_config("qwen3-4b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                 cfg.vocab_size)
    logits, cache = bundle.prefill(params, {"tokens": prompts,
                                            "cache_len": 24})
    tok = jnp.argmax(logits, axis=-1)[:, None]
    outs = []
    for _ in range(6):
        logits, cache = bundle.decode_step(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits, axis=-1)[:, None]
        outs.append(np.asarray(tok))
    seq = np.concatenate(outs, axis=1)
    assert seq.shape == (4, 6)
    assert int(cache["len"]) == 18
    assert seq.min() >= 0 and seq.max() < cfg.vocab_size


def test_compiled_candidate_deployment(tiny_ecg):
    """NAS winner -> compile_candidate -> quantized inference still meets
    the constraints it was selected under (HALF's deployment contract)."""
    from repro.core.compile_model import compile_candidate
    from repro.core.trainer import evaluate, forward, init_candidate
    (tr, va) = tiny_ecg
    g = random_genome(np.random.default_rng(11))
    specs = g.phenotype()
    params = init_candidate(jax.random.PRNGKey(0), specs)
    want_len = g.input_length()
    stride = tr[0].shape[1] // want_len
    x_cal = jnp.asarray(tr[0][:16, :want_len * stride:stride])
    compiled = compile_candidate(g, params, x_cal)
    assert len(compiled.alphas) == len(specs)
    assert compiled.estimate_max.throughput_sps >= \
        compiled.estimate_min.throughput_sps
    # quantized+folded params still run
    y = forward(compiled.params, specs, x_cal, quant=None)
    assert y.shape == (16, 2)
    assert not bool(jnp.isnan(y).any())
