"""Vectorized genetic operators vs. their scalar references.

The batch operators consume the RNG in a different order than the scalar
loops, so outputs cannot match element-wise; instead we check (a) exact
semantic invariants (validity, forced phenotype change, fallback-to-parent)
and (b) seeded-RNG *distribution* equivalence: summary statistics of many
scalar draws must match the batch operator's within sampling tolerance.
"""
import numpy as np
import pytest

from repro.core.genome import (
    Genome,
    PopulationEncoding,
    crossover,
    crossover_batch,
    is_valid_batch,
    mutate,
    mutate_batch,
    random_genome,
    random_population,
)
from repro.core.search_space import DEFAULT_SPACE

SP = DEFAULT_SPACE
FIELDS = ("op", "conn", "out", "w_bits", "a_bits", "i_bits", "dec")


def _tile(g: Genome, n: int) -> PopulationEncoding:
    return PopulationEncoding.from_genomes([g] * n)


def _rows_equal(a: PopulationEncoding, b: PopulationEncoding) -> np.ndarray:
    """(N,) bool — rows whose genes are identical in both encodings."""
    eq = np.ones(len(a), dtype=bool)
    for f in FIELDS:
        av, bv = getattr(a, f), getattr(b, f)
        eq &= (av == bv).all(axis=1) if av.ndim == 2 else av == bv
    return eq


def _tv(a_samples, b_samples, lo, hi) -> float:
    """Total-variation distance between two empirical distributions."""
    bins = np.arange(lo, hi + 2)
    pa = np.histogram(a_samples, bins=bins)[0] / len(a_samples)
    pb = np.histogram(b_samples, bins=bins)[0] / len(b_samples)
    return 0.5 * float(np.abs(pa - pb).sum())


# ---------------------------------------------------------------- validity

def test_is_valid_batch_matches_scalar_on_unfiltered_encodings():
    rng = np.random.default_rng(0)
    d, n = SP.max_depth, 600
    enc = PopulationEncoding(
        op=rng.integers(0, SP.n_ops, (n, d)),
        conn=rng.integers(0, np.arange(1, d + 1), (n, d)),
        out=rng.integers(1, d + 1, n),
        w_bits=rng.integers(0, len(SP.weight_bits), n),
        a_bits=rng.integers(0, len(SP.act_bits), n),
        i_bits=rng.integers(0, len(SP.input_bits), n),
        dec=rng.integers(0, len(SP.input_decimations), n))
    batch = is_valid_batch(enc, SP)
    scalar = np.asarray([enc.genome(i).is_valid(SP) for i in range(n)])
    np.testing.assert_array_equal(batch, scalar)
    assert 0.0 < batch.mean() < 1.0  # the sample covers both outcomes


def test_random_population_all_valid_and_sized():
    pop = random_population(np.random.default_rng(1), 300, SP)
    assert len(pop) == 300
    assert is_valid_batch(pop, SP).all()


def test_random_population_depth_distribution_matches_scalar():
    k = 1000
    scalar_rng = np.random.default_rng(2)
    scalar_depth = [random_genome(scalar_rng, SP).depth() for _ in range(k)]
    pop = random_population(np.random.default_rng(3), k, SP)
    _, batch_depth = pop.decode_paths()
    assert _tv(scalar_depth, batch_depth, 1, SP.max_depth) < 0.1


# ---------------------------------------------------------------- mutation

def test_mutate_batch_outputs_valid_and_forced_change():
    rng = np.random.default_rng(4)
    pop = random_population(rng, 200, SP)
    mut = mutate_batch(pop, rng, SP, force_active_change=True)
    assert is_valid_batch(mut, SP).all()
    same = _rows_equal(pop, mut)
    ph_pop = np.asarray(pop.batch_phenotype_hash(SP), dtype=object)
    ph_mut = np.asarray(mut.batch_phenotype_hash(SP), dtype=object)
    # mutated rows changed phenotype; fallback rows are the parent verbatim
    assert (ph_pop[~same] != ph_mut[~same]).all()
    assert (ph_pop[same] == ph_mut[same]).all()
    assert (~same).mean() > 0.95  # fallback is the rare path


def test_mutate_batch_relaxed_allows_neutral_drift():
    rng = np.random.default_rng(5)
    pop = random_population(rng, 400, SP)
    mut = mutate_batch(pop, rng, SP, rate=0.02, force_active_change=False)
    assert is_valid_batch(mut, SP).all()
    ph_pop = pop.batch_phenotype_hash(SP)
    ph_mut = mut.batch_phenotype_hash(SP)
    neutral = sum(a == b for a, b in zip(ph_pop, ph_mut))
    assert neutral > 0  # low rate: some draws touch nothing / dormant genes


def test_mutate_batch_distribution_matches_scalar():
    k = 3000
    parent = random_genome(np.random.default_rng(6), SP)
    parent_op = np.asarray(parent.op_genes)

    scalar_rng = np.random.default_rng(7)
    s_out, s_depth, s_nop, s_dec = [], [], [], []
    for _ in range(k):
        m = mutate(parent, scalar_rng, SP, force_active_change=True)
        s_out.append(m.out_gene)
        s_depth.append(m.depth())
        s_nop.append(int((np.asarray(m.op_genes) != parent_op).sum()))
        s_dec.append(m.dec_gene)

    batch = mutate_batch(_tile(parent, k), np.random.default_rng(8), SP,
                         force_active_change=True)
    _, b_depth = batch.decode_paths()
    b_nop = (batch.op != parent_op[None, :]).sum(axis=1)

    assert _tv(s_out, batch.out, 1, SP.max_depth) < 0.1
    assert _tv(s_depth, b_depth, 1, SP.max_depth) < 0.1
    assert _tv(s_nop, b_nop, 0, SP.max_depth) < 0.1
    assert abs(np.mean(s_dec) - batch.dec.mean()) < 0.05
    assert abs(np.mean(s_nop) - b_nop.mean()) < 0.25


# --------------------------------------------------------------- crossover

def _distinct_parents():
    rng = np.random.default_rng(9)
    while True:
        a = random_genome(rng, SP)
        b = random_genome(rng, SP)
        distinct = (np.asarray(a.op_genes) != np.asarray(b.op_genes))
        if a.dec_gene != b.dec_gene and distinct.sum() >= 10:
            return a, b, distinct


def test_crossover_batch_outputs_valid():
    rng = np.random.default_rng(10)
    a = random_population(rng, 200, SP)
    b = a.take(rng.permutation(200))
    c = crossover_batch(a, b, rng, SP)
    assert is_valid_batch(c, SP).all()


def test_crossover_batch_distribution_matches_scalar():
    k = 3000
    a, b, distinct = _distinct_parents()
    b_op = np.asarray(b.op_genes)

    scalar_rng = np.random.default_rng(11)
    s_children = [crossover(a, b, scalar_rng, SP) for _ in range(k)]
    s_from_b = np.asarray([np.asarray(c.op_genes) == b_op
                           for c in s_children]).mean(axis=0)
    s_dec_b = np.mean([c.dec_gene == b.dec_gene for c in s_children])

    batch = crossover_batch(_tile(a, k), _tile(b, k),
                            np.random.default_rng(12), SP)
    b_from_b = (batch.op == b_op[None, :]).mean(axis=0)
    b_dec_b = (batch.dec == b.dec_gene).mean()

    # single-point cut: P(op gene comes from b) rises with position; the
    # scalar and batch cut distributions must agree per position
    assert np.abs(s_from_b[distinct] - b_from_b[distinct]).max() < 0.1
    # fair-coin donor for the non-node genes (modulated by rejection)
    assert abs(s_dec_b - b_dec_b) < 0.05


def test_crossover_batch_requires_aligned_shapes():
    rng = np.random.default_rng(13)
    pop = random_population(rng, 8, SP)
    with pytest.raises(Exception):
        crossover_batch(pop, pop.take([0, 1]), rng, SP)
