"""Subprocess check: shard_map EP MoE == pjit sort MoE (run on 8 devices).

Executed by tests/test_ep_moe.py with XLA_FLAGS forcing 8 host devices.
Exits non-zero on mismatch.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ModelConfig
from repro.distributed.sharding import axis_rules, default_rules
from repro.models.moe import init_moe, moe_block


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = default_rules(multi_pod=False)

    cfg = ModelConfig(
        name="tiny-moe", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=128, n_experts=8,
        experts_per_token=2, moe_d_ff=48, n_shared_experts=1,
        capacity_factor=8.0,  # no drops -> paths must agree exactly
        dtype="float32")

    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

    with axis_rules(rules, mesh):
        y_sort, aux_sort = jax.jit(
            lambda p_, x_: moe_block(p_, x_, cfg))(p, x)
        cfg_ep = dataclasses.replace(cfg, moe_impl="ep_a2a")
        y_ep, aux_ep = jax.jit(
            lambda p_, x_: moe_block(p_, x_, cfg_ep))(p, x)

        # gradients must agree too (the dispatch is differentiable)
        def loss(p_, impl_cfg):
            y, aux = moe_block(p_, x, impl_cfg)
            return jnp.sum(y ** 2) + aux

        g_sort = jax.jit(jax.grad(loss), static_argnums=1)(p, cfg)
        g_ep = jax.jit(jax.grad(loss), static_argnums=1)(p, cfg_ep)

    err_y = float(jnp.abs(y_sort - y_ep).max())
    err_aux = abs(float(aux_sort) - float(aux_ep))
    print(f"y err={err_y:.3e} aux err={err_aux:.3e}")
    assert err_y < 1e-4, err_y
    assert err_aux < 1e-5, err_aux
    for k in ("router", "gate", "up", "down", "shared_gate"):
        ga, gb = g_sort[k], g_ep[k]
        err = float(jnp.abs(ga - gb).max())
        denom = float(jnp.abs(ga).max()) + 1e-9
        print(f"grad[{k}] rel err={err/denom:.3e}")
        assert err / denom < 1e-3, (k, err, denom)
    print("EP equivalence OK")


if __name__ == "__main__":
    main()
