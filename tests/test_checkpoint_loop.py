"""Checkpointing (atomicity, gc, restore) + fault-tolerant training loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np

import pytest

from repro.checkpoint import Checkpointer
from repro.configs import reduced_config
from repro.data.lm import LMDataConfig, data_iterator, make_batch
from repro.models.registry import build_model
from repro.training.loop import LoopConfig, train_loop
from repro.training.step import TrainState, make_train_step


def _state():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "step": jnp.asarray(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = _state()
    ck.save(10, state)
    step, restored = ck.restore(jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state())
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save_async(7, _state())
    ck.wait()
    assert ck.latest_step() == 7


def test_checkpoint_ignores_partial_tmp(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    os.makedirs(tmp_path / "step_0000000099.tmp")  # crashed mid-save
    ck.save(5, _state())
    assert ck.latest_step() == 5  # tmp dir never counts


def test_lm_data_deterministic_restart():
    cfg = LMDataConfig(vocab_size=97, seq_len=16, global_batch=4)
    a = make_batch(cfg, 12)
    b = make_batch(cfg, 12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = data_iterator(cfg, start_step=12)
    c = next(it)
    np.testing.assert_array_equal(a["labels"], c["labels"])


@pytest.mark.slow
def test_train_loop_survives_injected_failures(tmp_path):
    """Kill the 'node' twice mid-run; the loop must restore and finish with
    exactly the same loss trajectory as an uninterrupted run."""
    cfg = reduced_config("qwen2-0.5b")
    bundle = build_model(cfg)
    data_cfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=4)
    lc = lambda d: LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(d),
                              log_every=100, max_restarts=3)

    out_clean = train_loop(bundle, lambda s: data_iterator(data_cfg, s),
                           lc(tmp_path / "clean"),
                           log=lambda *_: None, jit=True)
    assert out_clean["restarts"] == 0

    failures = {5: True, 9: True}

    def injector(step):
        if failures.pop(step, False):
            raise RuntimeError(f"injected node failure @ step {step}")

    out_faulty = train_loop(bundle, lambda s: data_iterator(data_cfg, s),
                            lc(tmp_path / "faulty"),
                            fail_injector=injector,
                            log=lambda *_: None, jit=True)
    assert out_faulty["restarts"] == 2
    # identical final params (bitwise): deterministic data + restored state
    pa = jax.tree_util.tree_leaves(out_clean["state"].params)
    pb = jax.tree_util.tree_leaves(out_faulty["state"].params)
    for a, b in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_elastic_restore_with_resharding(tmp_path, make_auto_mesh):
    """Checkpoints are mesh-agnostic: restore with explicit shardings on the
    (single-device) 'new mesh' still works leaf-for-leaf."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_auto_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path), keep=1)
    state = _state()
    ck.save(1, state)
    shardings = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P()), state)
    _, restored = ck.restore(
        jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
        shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
