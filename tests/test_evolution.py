"""Evolutionary NAS loop with a mock trainer: selection + dormant-gene cache."""
import numpy as np

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.objectives import cheap_matrix
from repro.core.pareto import pareto_front
from repro.core.selection import (
    inverse_density_weights,
    preselect_children,
    sample_parents,
)
from repro.core.trainer import TrainResult


def mock_trainer(calls):
    def train(g):
        calls.append(g.phenotype_hash())
        det = min(0.99, 0.75 + 0.04 * g.depth())
        return TrainResult(detection_rate=det,
                           false_alarm_rate=max(0.0, 0.25 - 0.03 * g.depth()),
                           val_loss=0.3, steps=0)
    return train


def make_search(calls, **kw):
    cfg = NASConfig(generations=4, children_per_gen=8, n_accept=4,
                    init_population=6, n_workers=2, seed=0, **kw)
    return EvolutionarySearch(cfg, None, None, train_fn=mock_trainer(calls),
                              log=lambda *_: None)


def test_search_progresses_and_respects_capacity():
    calls = []
    s = make_search(calls)
    state = s.run()
    assert state.generation == 4
    assert len(state.population) <= s.cfg.population_cap
    objs = np.stack([c.objective_vector() for c in state.population])
    assert len(pareto_front(objs)) >= 1
    assert len(state.history) == 4


def test_dormant_gene_cache_prevents_retraining():
    calls = []
    s = make_search(calls)
    state = s.run()
    # every phenotype hash is trained at most once
    assert len(calls) == len(set(calls))
    assert set(calls) <= set(state.evaluated_hashes)


def test_solution_selection_honours_constraints():
    calls = []
    s = make_search(calls)
    state = s.run()
    sol = s.select_solution(state, "energy_max_alpha_j")
    if sol is not None:
        assert sol.meets_constraints(s.cfg.det_min, s.cfg.fa_max)


def test_soa_state_is_self_consistent():
    """The resident arrays must agree with what a fresh recompute (cheap
    objectives, phenotype hashes) and the object view say."""
    calls = []
    s = make_search(calls)
    state = s.init_state()
    for _ in range(2):
        state = s.step(state)
    pop = state.pop
    np.testing.assert_array_equal(
        pop.cheap, s.backend.evaluate_batch(pop.enc, space=s.space))
    assert list(pop.phash) == pop.enc.batch_phenotype_hash(s.space)
    assert len(set(pop.phash)) == len(pop)  # dedup invariant
    # object view mirrors the arrays
    for i, c in enumerate(state.population):
        assert c.phash == pop.phash[i]
        np.testing.assert_array_equal(c.cheap, pop.cheap[i])
        if c.expensive is None:
            assert not pop.trained_mask[i]
        else:
            np.testing.assert_array_equal(c.expensive, pop.expensive[i])
    # trained members are all in the dormant-gene cache
    for h in pop.phash[pop.trained_mask]:
        assert h in state.evaluated_hashes


def test_kde_weights_prefer_sparse_regions():
    # dense cluster at origin + one isolated point: the isolated point must
    # receive the largest parent-sampling weight
    pts = np.vstack([np.random.default_rng(0).normal(0, 0.01, (20, 3)),
                     np.array([[10.0, 10.0, 10.0]])])
    w = inverse_density_weights(pts)
    assert np.argmax(w) == 20
    assert np.isclose(w.sum(), 1.0)


def test_preselection_size_and_bounds():
    rng = np.random.default_rng(0)
    pop = rng.normal(size=(12, 4))
    children = rng.normal(size=(30, 4))
    idx = preselect_children(rng, pop, children, 10)
    assert len(idx) == 10 and len(set(idx.tolist())) == 10
    assert idx.max() < 30
    few = preselect_children(rng, pop, children[:5], 10)
    assert len(few) == 5


# ---------------------------------------------------------------------------
# Pipelined generation loop (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _det_batch_trainer(calls=None):
    """Deterministic genome-dependent batch trainer (accepts the worker's
    device like the real bucketed path)."""
    def train(genomes, device=None):
        if calls is not None:
            calls.append([g.phenotype_hash() for g in genomes])
        out = []
        for g in genomes:
            det = min(0.99, 0.70 + 0.05 * g.depth())
            out.append(TrainResult(detection_rate=det,
                                   false_alarm_rate=max(0.0,
                                                        0.3 - 0.04 * g.depth()),
                                   val_loss=0.2, steps=0))
        return out
    return train


def pipeline_search(pipeline, seed=3, calls=None, **kw):
    cfg = NASConfig(generations=4, children_per_gen=10, n_accept=4,
                    init_population=8, population_cap=16, n_workers=2,
                    seed=seed, pipeline=pipeline, **kw)
    return EvolutionarySearch(cfg, None, None,
                              batch_train_fn=_det_batch_trainer(calls),
                              log=lambda *_: None)


def test_host_overlap_trajectory_is_bit_identical_to_off():
    """The determinism contract: ``pipeline="host_overlap"`` only overlaps
    order-independent host folds with device dispatches, so on a fixed seed
    its whole trajectory — survivors, objectives, history — equals the
    synchronous loop's bit for bit."""
    a = pipeline_search("off").run()
    b = pipeline_search("host_overlap").run()
    assert a.generation == b.generation
    assert list(a.pop.phash) == list(b.pop.phash)
    np.testing.assert_array_equal(a.pop.cheap, b.pop.cheap)
    np.testing.assert_array_equal(a.pop.expensive, b.pop.expensive)
    np.testing.assert_array_equal(a.pop.born, b.pop.born)
    assert set(a.evaluated_hashes) == set(b.evaluated_hashes)
    for ra, rb in zip(a.history, b.history):
        for k in ("generation", "children", "trained", "population",
                  "front_size", "feasible", "best_primary"):
            assert ra[k] == rb[k] or (
                np.isnan(ra[k]) and np.isnan(rb[k])), k


def test_async_pipeline_completes_and_keeps_invariants():
    """``pipeline="async"`` relaxes the trajectory but not the structural
    invariants: every generation folds, the population stays deduped and
    fully trained, and each record is tagged with the mode."""
    calls = []
    s = pipeline_search("async", calls=calls, lookahead=2)
    state = s.run()
    assert state.generation == 4
    assert len(state.history) == 4
    assert all(r.get("pipeline") == "async" for r in state.history)
    assert len(set(state.pop.phash)) == len(state.pop)
    assert state.pop.trained_mask.all()
    # the dormant-gene cache never trained a phenotype twice
    flat = [h for bucket in calls for h in bucket]
    assert len(flat) == len(set(flat))


def test_history_records_timing_breakdown():
    """Each generation records its wall-time split and the per-device busy
    time of its training jobs — the observability surface the pipeline
    benchmark (and CI gate) reads."""
    state = pipeline_search("off").run()
    for rec in state.history:
        t = rec["timings"]
        assert set(t) == {"children", "cheap_score", "train", "select"}
        assert all(v >= 0.0 for v in t.values())
        assert isinstance(rec["device_busy_s"], dict)
        assert rec["train_jobs"] >= 0
    trained_recs = [r for r in state.history if r["trained"]]
    assert any(r["train_jobs"] > 0 for r in trained_recs)
    assert any(r["device_busy_s"] for r in trained_recs)


def test_failed_candidates_get_schema_derived_pessimism():
    """A candidate whose training fails permanently lands at the schema's
    worst-case expensive row (not a hard-coded 2-vector)."""
    from repro.core.objective_schema import pessimistic_expensive

    def explode(g):
        raise RuntimeError("bucket OOM")

    cfg = NASConfig(generations=1, children_per_gen=4, n_accept=2,
                    init_population=4, n_workers=2, seed=0)
    s = EvolutionarySearch(cfg, None, None, train_fn=explode,
                           log=lambda *_: None)
    s.scheduler.max_retries = 0
    state = s.init_state()
    worst = pessimistic_expensive(s.full_schema)
    assert state.pop.expensive.shape[1] == len(worst)
    np.testing.assert_array_equal(
        state.pop.expensive, np.tile(worst, (len(state.pop), 1)))


def test_unknown_pipeline_mode_rejected():
    import pytest
    cfg = NASConfig(pipeline="sometimes")
    with pytest.raises(ValueError, match="pipeline"):
        EvolutionarySearch(cfg, None, None, train_fn=lambda g: None,
                           log=lambda *_: None)


def test_async_pipeline_checkpoints_at_drain_barriers(tmp_path):
    """``run_resumable`` under ``pipeline='async'`` checkpoints at drain
    barriers (DESIGN.md §13) instead of rejecting: the run completes to
    target and leaves a loadable, fully-trained checkpoint behind."""
    path = str(tmp_path / "ckpt.json")
    s = pipeline_search("async")
    final = s.run_resumable(path)
    assert final.generation == 4
    restored = pipeline_search("async").load_state(path)
    assert restored.generation == 4
    assert list(restored.pop.phash) == list(final.pop.phash)
    np.testing.assert_array_equal(restored.pop.expensive,
                                  final.pop.expensive)
    assert restored.pop.trained_mask.all()


def test_device_imbalance_helper():
    from repro.core.evolution import device_imbalance
    # meaningless cases: <2 devices, or a generation with ~no device work
    assert device_imbalance({}) is None
    assert device_imbalance({"cpu:0": 5.0}) is None
    assert device_imbalance({"cpu:0": 0.0, "cpu:1": 0.0}) is None
    # balanced vs skewed
    assert abs(device_imbalance({"cpu:0": 1.0, "cpu:1": 1.1}) - 1.1) < 1e-9
    assert abs(device_imbalance({"cpu:0": 1.0, "cpu:1": 4.0,
                                 "cpu:2": 2.0}) - 4.0) < 1e-9
    # one device idle while others trained: worst possible skew
    assert device_imbalance({"cpu:0": 0.0, "cpu:1": 3.0}) == float("inf")


def test_device_imbalance_warning_logged():
    """A skewed generation surfaces a scheduler warning and records the
    ratio in the history (device-affine sharding can pin the big signature
    buckets to one device — the log line is the operator's signal)."""
    from repro.core.evolution import DEVICE_IMBALANCE_RATIO

    def run_with_busy(busy):
        lines = []
        cfg = NASConfig(generations=1, children_per_gen=6, n_accept=2,
                        init_population=6, n_workers=2, seed=0)
        s = EvolutionarySearch(cfg, None, None, train_fn=mock_trainer([]),
                               log=lambda *a: lines.append(
                                   " ".join(str(x) for x in a)))
        state = s.init_state()
        orig = s._finish_training
        # train for real, but report the synthetic per-device busy split
        s._finish_training = lambda *a, **k: (orig(*a, **k), busy)[1]
        state = s.step(state)
        return lines, state

    lines, state = run_with_busy({"cpu:0": 0.1, "cpu:1": 1.0})
    assert any("WARNING" in ln and "imbalance 10.0x" in ln for ln in lines)
    rec = state.history[-1]
    assert abs(rec["device_imbalance"] - 10.0) < 1e-6
    assert rec["device_imbalance"] > DEVICE_IMBALANCE_RATIO

    lines, state = run_with_busy({"cpu:0": 1.0, "cpu:1": 1.1})
    assert not any("imbalance" in ln for ln in lines)
    assert "device_imbalance" not in state.history[-1]
