"""Evolutionary NAS loop with a mock trainer: selection + dormant-gene cache."""
import numpy as np

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.objectives import cheap_matrix
from repro.core.pareto import pareto_front
from repro.core.selection import (
    inverse_density_weights,
    preselect_children,
    sample_parents,
)
from repro.core.trainer import TrainResult


def mock_trainer(calls):
    def train(g):
        calls.append(g.phenotype_hash())
        det = min(0.99, 0.75 + 0.04 * g.depth())
        return TrainResult(detection_rate=det,
                           false_alarm_rate=max(0.0, 0.25 - 0.03 * g.depth()),
                           val_loss=0.3, steps=0)
    return train


def make_search(calls, **kw):
    cfg = NASConfig(generations=4, children_per_gen=8, n_accept=4,
                    init_population=6, n_workers=2, seed=0, **kw)
    return EvolutionarySearch(cfg, None, None, train_fn=mock_trainer(calls),
                              log=lambda *_: None)


def test_search_progresses_and_respects_capacity():
    calls = []
    s = make_search(calls)
    state = s.run()
    assert state.generation == 4
    assert len(state.population) <= s.cfg.population_cap
    objs = np.stack([c.objective_vector() for c in state.population])
    assert len(pareto_front(objs)) >= 1
    assert len(state.history) == 4


def test_dormant_gene_cache_prevents_retraining():
    calls = []
    s = make_search(calls)
    state = s.run()
    # every phenotype hash is trained at most once
    assert len(calls) == len(set(calls))
    assert set(calls) <= set(state.evaluated_hashes)


def test_solution_selection_honours_constraints():
    calls = []
    s = make_search(calls)
    state = s.run()
    sol = s.select_solution(state, "energy_max_alpha_j")
    if sol is not None:
        assert sol.meets_constraints(s.cfg.det_min, s.cfg.fa_max)


def test_soa_state_is_self_consistent():
    """The resident arrays must agree with what a fresh recompute (cheap
    objectives, phenotype hashes) and the object view say."""
    calls = []
    s = make_search(calls)
    state = s.init_state()
    for _ in range(2):
        state = s.step(state)
    pop = state.pop
    np.testing.assert_array_equal(
        pop.cheap, s.backend.evaluate_batch(pop.enc, space=s.space))
    assert list(pop.phash) == pop.enc.batch_phenotype_hash(s.space)
    assert len(set(pop.phash)) == len(pop)  # dedup invariant
    # object view mirrors the arrays
    for i, c in enumerate(state.population):
        assert c.phash == pop.phash[i]
        np.testing.assert_array_equal(c.cheap, pop.cheap[i])
        if c.expensive is None:
            assert not pop.trained_mask[i]
        else:
            np.testing.assert_array_equal(c.expensive, pop.expensive[i])
    # trained members are all in the dormant-gene cache
    for h in pop.phash[pop.trained_mask]:
        assert h in state.evaluated_hashes


def test_kde_weights_prefer_sparse_regions():
    # dense cluster at origin + one isolated point: the isolated point must
    # receive the largest parent-sampling weight
    pts = np.vstack([np.random.default_rng(0).normal(0, 0.01, (20, 3)),
                     np.array([[10.0, 10.0, 10.0]])])
    w = inverse_density_weights(pts)
    assert np.argmax(w) == 20
    assert np.isclose(w.sum(), 1.0)


def test_preselection_size_and_bounds():
    rng = np.random.default_rng(0)
    pop = rng.normal(size=(12, 4))
    children = rng.normal(size=(30, 4))
    idx = preselect_children(rng, pop, children, 10)
    assert len(idx) == 10 and len(set(idx.tolist())) == 10
    assert idx.max() < 30
    few = preselect_children(rng, pop, children[:5], 10)
    assert len(few) == 5
