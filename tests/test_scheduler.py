"""Dynamic workload scheduler: retries, failure containment, stragglers."""
import threading
import time

from repro.core.scheduler import DynamicScheduler


def test_all_jobs_complete():
    sched = DynamicScheduler(n_workers=4, speculate=False)
    results = sched.run([lambda i=i: i * i for i in range(20)])
    assert [r.value for r in results] == [i * i for i in range(20)]
    assert all(r.ok for r in results)


def test_retry_on_transient_failure():
    attempts = {}
    lock = threading.Lock()

    def flaky(i):
        with lock:
            attempts[i] = attempts.get(i, 0) + 1
            if attempts[i] == 1 and i % 3 == 0:
                raise RuntimeError("transient node failure")
        return i

    sched = DynamicScheduler(n_workers=3, max_retries=2, speculate=False)
    results = sched.run([lambda i=i: flaky(i) for i in range(9)])
    assert all(r.ok for r in results)
    assert [r.value for r in results] == list(range(9))
    assert any(r.attempts > 1 for r in results)


def test_permanent_failure_reported_not_raised():
    def bad():
        raise ValueError("broken candidate")

    sched = DynamicScheduler(n_workers=2, max_retries=1, speculate=False)
    results = sched.run([bad, lambda: 42])
    assert not results[0].ok and "broken candidate" in results[0].error
    assert results[1].ok and results[1].value == 42


def test_speculation_waits_for_backlog_but_rescues_straggler():
    """Speculation is gated on 'no unfinished job waits for a worker'
    (checked atomically with the per-job state — the old racy qsize()
    proxy could postpone twins on transient queue observations).  Under a
    sustained backlog no worker is wasted on duplicates, yet the straggler
    still gets its twin once the backlog drains."""
    twin_ran = threading.Event()
    runs = {}
    lock = threading.Lock()

    def straggler():
        with lock:
            runs["straggler"] = runs.get("straggler", 0) + 1
            first = runs["straggler"] == 1
        if first:
            twin_ran.wait(timeout=10.0)  # hung until its twin completes
            return "slow"
        twin_ran.set()
        return "fast"

    def sleeper(i):
        def f():
            with lock:
                runs[i] = runs.get(i, 0) + 1
            time.sleep(0.3)
            return i
        return f

    jobs = [straggler] + [sleeper(i) for i in range(6)]
    sched = DynamicScheduler(n_workers=2, max_retries=0, timeout_s=0.3,
                             speculate=True)
    results = sched.run(jobs)
    # the released original may beat the twin to the result slot — first
    # result wins, either way the straggler was rescued
    assert results[0].ok and results[0].value in ("fast", "slow")
    assert [r.value for r in results[1:]] == list(range(6))
    # the backlog was never speculated on — only the straggler was
    assert all(runs[i] == 1 for i in range(6))
    assert runs["straggler"] == 2


def test_straggler_speculation():
    """A hung job is duplicated after timeout_s and the twin's result wins."""
    state = {"first": True}
    lock = threading.Lock()
    release = threading.Event()

    def hangs_once():
        with lock:
            first = state["first"]
            state["first"] = False
        if first:
            release.wait(timeout=2.0)  # simulated straggler
            return "slow"
        return "fast"

    sched = DynamicScheduler(n_workers=2, max_retries=0, timeout_s=0.3,
                             speculate=True)
    results = sched.run([hangs_once])
    release.set()
    assert results[0].ok
    assert results[0].value in ("fast", "slow")
    assert results[0].value == "fast"  # the speculative twin finished first
