"""Dynamic workload scheduler: retries, failure containment, stragglers."""
import threading
import time

from repro.core.scheduler import DynamicScheduler


def test_all_jobs_complete():
    sched = DynamicScheduler(n_workers=4, speculate=False)
    results = sched.run([lambda i=i: i * i for i in range(20)])
    assert [r.value for r in results] == [i * i for i in range(20)]
    assert all(r.ok for r in results)


def test_retry_on_transient_failure():
    attempts = {}
    lock = threading.Lock()

    def flaky(i):
        with lock:
            attempts[i] = attempts.get(i, 0) + 1
            if attempts[i] == 1 and i % 3 == 0:
                raise RuntimeError("transient node failure")
        return i

    sched = DynamicScheduler(n_workers=3, max_retries=2, speculate=False)
    results = sched.run([lambda i=i: flaky(i) for i in range(9)])
    assert all(r.ok for r in results)
    assert [r.value for r in results] == list(range(9))
    assert any(r.attempts > 1 for r in results)


def test_permanent_failure_reported_not_raised():
    def bad():
        raise ValueError("broken candidate")

    sched = DynamicScheduler(n_workers=2, max_retries=1, speculate=False)
    results = sched.run([bad, lambda: 42])
    assert not results[0].ok and "broken candidate" in results[0].error
    assert results[1].ok and results[1].value == 42


def test_speculation_waits_for_backlog_but_rescues_straggler():
    """Speculation is gated on 'no unfinished job waits for a worker'
    (checked atomically with the per-job state — the old racy qsize()
    proxy could postpone twins on transient queue observations).  Under a
    sustained backlog no worker is wasted on duplicates, yet the straggler
    still gets its twin once the backlog drains."""
    twin_ran = threading.Event()
    runs = {}
    lock = threading.Lock()

    def straggler():
        with lock:
            runs["straggler"] = runs.get("straggler", 0) + 1
            first = runs["straggler"] == 1
        if first:
            twin_ran.wait(timeout=10.0)  # hung until its twin completes
            return "slow"
        twin_ran.set()
        return "fast"

    def sleeper(i):
        def f():
            with lock:
                runs[i] = runs.get(i, 0) + 1
            time.sleep(0.3)
            return i
        return f

    jobs = [straggler] + [sleeper(i) for i in range(6)]
    sched = DynamicScheduler(n_workers=2, max_retries=0, timeout_s=0.3,
                             speculate=True)
    results = sched.run(jobs)
    # the released original may beat the twin to the result slot — first
    # result wins, either way the straggler was rescued
    assert results[0].ok and results[0].value in ("fast", "slow")
    assert [r.value for r in results[1:]] == list(range(6))
    # the backlog was never speculated on — only the straggler was
    assert all(runs[i] == 1 for i in range(6))
    assert runs["straggler"] == 2


def test_straggler_speculation():
    """A hung job is duplicated after timeout_s and the twin's result wins."""
    state = {"first": True}
    lock = threading.Lock()
    release = threading.Event()

    def hangs_once():
        with lock:
            first = state["first"]
            state["first"] = False
        if first:
            release.wait(timeout=2.0)  # simulated straggler
            return "slow"
        return "fast"

    sched = DynamicScheduler(n_workers=2, max_retries=0, timeout_s=0.3,
                             speculate=True)
    results = sched.run([hangs_once])
    release.set()
    assert results[0].ok
    assert results[0].value in ("fast", "slow")
    assert results[0].value == "fast"  # the speculative twin finished first


# ---------------------------------------------------------------------------
# Device affinity + asynchronous submission (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_workers_pinned_round_robin_and_jobs_receive_device():
    """With ``devices`` configured, worker w is pinned to
    ``devices[w % K]`` and jobs are invoked as ``job(device)``.  A barrier
    forces all four workers to take exactly one job each, so both devices
    must appear twice."""
    barrier = threading.Barrier(4)

    def job(device):
        barrier.wait(timeout=5.0)
        return device

    sched = DynamicScheduler(n_workers=4, speculate=False,
                             devices=["d0", "d1"])
    results = sched.run([job] * 4)
    assert all(r.ok for r in results)
    assert sorted(r.value for r in results) == ["d0", "d0", "d1", "d1"]
    assert all(r.device == r.value for r in results)


def test_no_devices_means_zero_arg_jobs():
    """Without affinity the call convention is unchanged: ``job()``."""
    sched = DynamicScheduler(n_workers=2, speculate=False)
    results = sched.run([lambda: "plain"])
    assert results[0].ok and results[0].value == "plain"
    assert results[0].device is None


def test_speculative_twin_lands_on_other_device():
    """A straggler's twin is banned from the straggling attempt's device:
    with one worker per device, the second attempt must land on the other
    accelerator."""
    release = threading.Event()
    runs = []
    lock = threading.Lock()

    def hangs_once(device):
        with lock:
            runs.append(device)
            first = len(runs) == 1
        if first:
            release.wait(timeout=10.0)
            return "slow"
        release.set()
        return "fast"

    sched = DynamicScheduler(n_workers=2, max_retries=0, timeout_s=0.3,
                             speculate=True, devices=["a", "b"])
    results = sched.run([hangs_once])
    release.set()
    assert results[0].ok
    assert len(runs) == 2 and runs[0] != runs[1]


def test_twin_ban_cannot_deadlock_on_single_device_group():
    """When every live worker shares the straggler's device the ban is
    unsatisfiable and must be waived — the twin still runs."""
    release = threading.Event()
    runs = []
    lock = threading.Lock()

    def hangs_once(device):
        with lock:
            runs.append(device)
            first = len(runs) == 1
        if first:
            release.wait(timeout=10.0)
            return "slow"
        release.set()
        return "fast"

    sched = DynamicScheduler(n_workers=2, max_retries=0, timeout_s=0.3,
                             speculate=True, devices=["only"])
    results = sched.run([hangs_once])
    release.set()
    assert results[0].ok
    assert runs == ["only", "only"]


def test_retry_keeps_affinity_but_no_ban():
    """A *failed* attempt re-dispatches unbanned — any device may retry it
    (the ban is a straggler heuristic, not a failure policy)."""
    attempts = []
    lock = threading.Lock()

    def flaky(device):
        with lock:
            attempts.append(device)
            n = len(attempts)
        if n == 1:
            raise RuntimeError("transient device fault")
        return device

    sched = DynamicScheduler(n_workers=2, max_retries=2, speculate=False,
                             devices=["a", "b"])
    r = sched.run([flaky])[0]
    assert r.ok and r.attempts == 2
    assert r.value in ("a", "b") and r.device == r.value


def test_submit_overlaps_host_work_then_wait_collects():
    """submit() returns immediately; the caller owns the gap until wait()."""
    gate = threading.Event()
    sched = DynamicScheduler(n_workers=2, speculate=False)
    run = sched.submit([lambda: (gate.wait(timeout=10.0), 1)[1]
                        for _ in range(2)])
    assert not run.done()          # jobs are blocked on the gate
    gate.set()                     # "host-side work" finished; release
    results = run.wait()
    assert run.done()
    assert [r.value for r in results] == [1, 1]
    assert [r.job_id for r in results] == [0, 1]  # sorted by job id


def test_on_result_hook_fires_once_per_job():
    seen = []
    lock = threading.Lock()

    def hook(r):
        with lock:
            seen.append((r.job_id, r.ok))

    sched = DynamicScheduler(n_workers=3, max_retries=1, speculate=False)
    jobs = [lambda i=i: i for i in range(4)]
    jobs.append(lambda: (_ for _ in ()).throw(ValueError("perma-broken")))
    sched.run(jobs, on_result=hook)
    assert sorted(seen) == [(0, True), (1, True), (2, True), (3, True),
                            (4, False)]


def test_empty_submission():
    sched = DynamicScheduler(n_workers=2)
    assert sched.run([]) == []
    run = sched.submit([])
    assert run.done() and run.wait() == []
