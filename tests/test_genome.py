"""Genome encoding: validity, dormant genes, mutation/crossover invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.genome import (
    Genome,
    crossover,
    decode_shapes,
    mutate,
    random_genome,
)
from repro.core.search_space import DEFAULT_SPACE

SP = DEFAULT_SPACE


@given(seed=st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_random_genome_always_valid(seed):
    g = random_genome(np.random.default_rng(seed), SP)
    assert g.is_valid(SP)
    assert SP.min_depth <= g.depth() <= SP.max_depth
    shapes = decode_shapes(g, SP)
    assert all(l >= 1 and c >= 1 for l, c in shapes)
    # head is always GAP + dense(n_classes)
    assert shapes[-1] == (1, SP.n_classes)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_forced_mutation_changes_phenotype(seed):
    rng = np.random.default_rng(seed)
    g = random_genome(rng, SP)
    m = mutate(g, rng, SP, force_active_change=True)
    assert m.is_valid(SP)
    if m is not g:  # mutate may give up after max_tries on rare genomes
        assert m.phenotype_hash(SP) != g.phenotype_hash(SP)


def test_dormant_gene_mutation_is_neutral():
    """Mutating only dormant (inactive) genes must keep the phenotype."""
    rng = np.random.default_rng(1)
    g = random_genome(rng, SP)
    active = set(g.active_nodes())
    dormant = [i for i in range(len(g.op_genes)) if i not in active]
    if not dormant:
        pytest.skip("genome with all nodes active")
    ops = list(g.op_genes)
    ops[dormant[0]] = (ops[dormant[0]] + 1) % SP.n_ops
    g2 = Genome(tuple(ops), g.conn_genes, g.out_gene, g.w_bits_gene,
                g.a_bits_gene, g.i_bits_gene, g.dec_gene)
    assert g2.phenotype_hash(SP) == g.phenotype_hash(SP)


def test_dormant_gene_can_reactivate():
    """A connection-gene mutation can re-express previously dormant genes."""
    rng = np.random.default_rng(2)
    for _ in range(200):
        g = random_genome(rng, SP)
        m = mutate(g, rng, SP, force_active_change=True)
        before = set(g.active_nodes())
        after = set(m.active_nodes())
        if after - before:
            return  # some node went from dormant to active
    pytest.fail("no reactivation observed in 200 mutations")


@given(s1=st.integers(0, 5000), s2=st.integers(0, 5000))
@settings(max_examples=30, deadline=None)
def test_crossover_valid(s1, s2):
    rng = np.random.default_rng(s1 + 7 * s2)
    a = random_genome(np.random.default_rng(s1), SP)
    b = random_genome(np.random.default_rng(s2), SP)
    c = crossover(a, b, rng, SP)
    assert c.is_valid(SP)


def test_phenotype_hash_depends_on_quant_and_decimation():
    rng = np.random.default_rng(3)
    g = random_genome(rng, SP)
    g2 = Genome(g.op_genes, g.conn_genes, g.out_gene,
                (g.w_bits_gene + 1) % len(SP.weight_bits),
                g.a_bits_gene, g.i_bits_gene, g.dec_gene)
    assert g.phenotype_hash(SP) != g2.phenotype_hash(SP)
