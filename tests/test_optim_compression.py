"""Optimizers + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import CompressionConfig, EFTopK, compress_grads
from repro.optim import adafactor, adamw, apply_updates, clip_by_global_norm


def _rosenbrock_ish(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum(
        (params["m"] @ params["m"].T - jnp.eye(4)) ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(1e-1), lambda: adafactor(1e-1)])
def test_optimizers_descend(make_opt):
    params = {"w": jnp.zeros((8,)), "m": jnp.eye(4) * 0.3}
    opt = make_opt()
    state = opt.init(params)
    loss0 = float(_rosenbrock_ish(params))
    for _ in range(60):
        grads = jax.grad(_rosenbrock_ish)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(_rosenbrock_ish(params)) < 0.2 * loss0


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((128, 256))}
    opt = adafactor(1e-2)
    state = opt.init(params)
    assert state.vr["big"].shape == (128,)
    assert state.vc["big"].shape == (256,)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(10) * 100)
    norm_after = float(jnp.linalg.norm(clipped["a"]))
    assert norm_after == pytest.approx(1.0, rel=1e-5)


def test_bf16_compression_roundtrip():
    grads = {"g": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                              jnp.float32)}
    out = compress_grads(grads, CompressionConfig(mode="bf16"))
    assert out["g"].dtype == jnp.float32
    assert float(jnp.abs(out["g"] - grads["g"]).max()) < 0.01


def test_ef_topk_error_feedback_conserves_mass():
    """sent + residual must equal grad + previous residual (no loss)."""
    ef = EFTopK(frac=0.1)
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(100,)),
                          jnp.float32)}
    res = ef.init(g)
    sent, res = ef.compress(g, res)
    np.testing.assert_allclose(np.asarray(sent["w"] + res["w"]),
                               np.asarray(g["w"]), rtol=1e-6)
    nnz = int(jnp.sum(sent["w"] != 0))
    assert nnz <= 15  # ~top 10 + ties
    # second step re-injects the residual
    sent2, res2 = ef.compress(g, res)
    np.testing.assert_allclose(np.asarray(sent2["w"] + res2["w"]),
                               np.asarray(g["w"] + res["w"]), rtol=1e-6)
