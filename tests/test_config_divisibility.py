"""Static validation: every full-size arch config shards evenly on both
production meshes — catches config/mesh mismatches without any compile."""
import jax
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import validate_divisibility
from repro.launch.mesh import rules_for
from repro.models.registry import build_model

MESHES = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("mesh_name", ["single", "multi"])
def test_param_shardings_divide(arch, mesh_name):
    cfg = get_config(arch)
    bundle = build_model(cfg)
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    rules = rules_for(arch, multi_pod=mesh_name == "multi",
                      global_batch=256)
    problems = validate_divisibility(shapes, bundle.specs(), rules,
                                     MESHES[mesh_name])
    assert not problems, problems


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cache_shardings_divide(arch):
    cfg = get_config(arch)
    bundle = build_model(cfg)
    cell = SHAPES["decode_32k"]
    cache = bundle.cache_shapes(cell)
    rules = rules_for(arch, multi_pod=False, global_batch=cell.global_batch)
    problems = validate_divisibility(cache, bundle.cache_specs(), rules,
                                     MESHES["single"])
    assert not problems, problems


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_batch_shardings_divide(arch, shape):
    cfg = get_config(arch)
    bundle = build_model(cfg)
    cell = SHAPES[shape]
    ok, _ = bundle.supports(cell)
    if not ok:
        pytest.skip("assignment skip rule")
    specs, axes = bundle.input_specs(cell)
    rules = rules_for(arch, multi_pod=True, global_batch=cell.global_batch)
    problems = validate_divisibility(specs, axes, rules, MESHES["multi"])
    assert not problems, problems
