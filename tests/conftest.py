import os
import sys

import numpy as np
import pytest

# tests run on the single real CPU device — the 512-device dry-run is
# exercised via subprocess (test_dryrun_subprocess.py), never in-process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def tiny_ecg():
    """Small synthetic ECG split shared across tests (generated once)."""
    from repro.data.ecg import make_ecg_dataset, train_val_split
    x, y = make_ecg_dataset(seed=0, n_samples=240, length=60000,
                            decimation=32)
    return train_val_split(x, y, seed=0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def make_auto_mesh():
    """jax.make_mesh with Auto axis types across jax versions.

    ``jax.sharding.AxisType`` only exists in newer jax; Auto is the default
    there too, so on older versions plain make_mesh is equivalent.
    (A fixture rather than an importable helper: pytest injects it under
    any --import-mode.)
    """
    import jax

    def _make(shape, axis_names):
        kwargs = {}
        if hasattr(jax.sharding, "AxisType"):
            kwargs["axis_types"] = \
                (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(shape, axis_names, **kwargs)

    return _make
