import os
import sys

import numpy as np
import pytest

# tests run on the single real CPU device — the 512-device dry-run is
# exercised via subprocess (test_dryrun_subprocess.py), never in-process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def tiny_ecg():
    """Small synthetic ECG split shared across tests (generated once)."""
    from repro.data.ecg import make_ecg_dataset, train_val_split
    x, y = make_ecg_dataset(seed=0, n_samples=240, length=60000,
                            decimation=32)
    return train_val_split(x, y, seed=0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
