"""Paged KV-cache serving (repro.serve.paged + EngineConfig(paged=True))
— DESIGN.md §15.

Same load-bearing invariant as test_serve.py — bit-identical greedy
parity — plus the paged-specific contracts: block-granular admission
beats worst-case dense slots at equal memory, pool exhaustion sheds
*explicitly* (``oom`` flag, reference-prefix output, ``shed_blocks``
counter, zero silent drops), and prefill splices under a full pool never
corrupt resident slots (the ``mode="drop"`` sentinel scatter).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.registry import build_model
from repro.serve import (
    BlockPool,
    EngineConfig,
    ReplicaRouter,
    RouterConfig,
    ServeEngine,
    ServeRequest,
    blocks_for,
    greedy_reference,
    longtail_workload,
)

CACHE_LEN = 48
BS = 8                      # block size used throughout
MAXB = CACHE_LEN // BS      # blocks per slot at full span


def _bundle(arch):
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _requests(cfg, lens_out, seed=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i,
                         prompt=rng.integers(0, cfg.vocab_size, pl).astype(
                             np.int32),
                         max_new=mn)
            for i, (pl, mn) in enumerate(lens_out)]


def _refs(bundle, params, reqs):
    dec = jax.jit(bundle.decode_step)
    return {r.rid: greedy_reference(bundle, params, r.prompt, r.max_new,
                                    CACHE_LEN, decode_jit=dec)
            for r in reqs}


def _paged_cfg(slots=6, n_blocks=None, pad_to=8, **kw):
    return EngineConfig(slots=slots, cache_len=CACHE_LEN, pad_to=pad_to,
                        paged=True, block_size=BS, n_blocks=n_blocks, **kw)


# --------------------------------------------------------------- BlockPool
def test_block_pool_alloc_free_roundtrip():
    pool = BlockPool(n_blocks=8, block_size=4, slots=3,
                     max_blocks_per_slot=4)
    assert pool.free_count == 8 and pool.used == 0
    assert pool.alloc(0, 3) and pool.held(0) == 3
    assert pool.alloc(1, 2) and pool.used == 5
    assert pool.peak_used == 5
    # all-or-nothing: 4 > 3 free fails and changes nothing
    assert not pool.alloc(2, 4)
    assert pool.free_count == 3 and pool.held(2) == 0
    assert pool.free_slot(0) == 3
    assert pool.free_count == 6
    assert pool.peak_used == 5          # peak survives frees
    # LIFO: freed blocks are reused first, deterministically
    first = pool.slot_blocks(1)
    assert pool.alloc(2, 1)
    assert pool.slot_blocks(1) == first


def test_block_pool_per_slot_cap():
    pool = BlockPool(n_blocks=16, block_size=4, slots=2,
                     max_blocks_per_slot=3)
    assert pool.alloc(0, 3)
    assert not pool.alloc(0, 1)         # at the per-slot span cap
    assert pool.ensure(0, 11)           # pos 11 needs 3 blocks: no-op
    assert not pool.ensure(0, 12)       # pos 12 needs a 4th block


def test_block_pool_table_sentinel():
    pool = BlockPool(n_blocks=6, block_size=4, slots=2,
                     max_blocks_per_slot=3)
    pool.alloc(0, 2)
    t = pool.table_array()
    assert t.shape == (2, 3) and t.dtype == np.int32
    assert t[0, 0] != 6 and t[0, 1] != 6
    assert t[0, 2] == 6 and (t[1] == 6).all()   # sentinel = n_blocks


def test_blocks_for_rounding():
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2
    assert blocks_for(0, 8) == 1        # even an empty prompt holds a block


# ------------------------------------------------------------ engine parity
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-7b"])
def test_paged_engine_bit_parity(arch):
    """Every request served through the paged engine matches the scalar
    greedy reference bit for bit (LM and hybrid families)."""
    cfg, bundle, params = _bundle(arch)
    # hybrid scalar decode needs prompts >= conv_kernel - 1
    reqs = _requests(cfg, [(5, 6), (12, 4), (31, 5), (8, 8), (4, 6),
                           (19, 4)], seed=1)
    refs = _refs(bundle, params, reqs)
    eng = ServeEngine(bundle, params, _paged_cfg(
        slots=4, n_blocks=18, pad_to=8 if bundle.prefill_pads else 1))
    done = eng.run(reqs)
    assert len(done) == len(reqs)
    assert not any(r.oom for r in done)
    for r in done:
        assert r.out == refs[r.rid], f"rid {r.rid} diverged"
    st = eng.stats()
    assert st["peak_blocks_used"] <= 18
    assert st["shed_blocks"] == 0
    assert all(r.blocks_held >= blocks_for(len(r.prompt), BS)
               for r in done)


def test_paged_admission_beats_dense_at_equal_memory():
    """Equal KV memory (same pooled token count): the paged engine admits
    strictly more concurrent sequences than worst-case dense slots on a
    short-prompt mix — the tentpole capacity win."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(4, 4)] * 12, seed=2)
    refs = _refs(bundle, params, reqs)

    dense = ServeEngine(bundle, params, EngineConfig(
        slots=2, cache_len=CACHE_LEN, pad_to=8))
    dense_done = dense.run([ServeRequest(rid=r.rid, prompt=r.prompt,
                                         max_new=r.max_new) for r in reqs])
    # paged pool = same 2 * CACHE_LEN tokens, spread over 12 slots
    paged = ServeEngine(bundle, params, _paged_cfg(
        slots=12, n_blocks=2 * CACHE_LEN // BS))
    paged_done = paged.run(reqs)

    assert all(r.out == refs[r.rid] for r in dense_done)
    assert all(r.out == refs[r.rid] for r in paged_done)
    assert not any(r.oom for r in paged_done)
    assert dense.stats()["peak_concurrency"] == 2
    assert paged.stats()["peak_concurrency"] >= \
        2 * dense.stats()["peak_concurrency"]


def test_paged_oom_shed_explicit_prefix_parity():
    """A pool too small for the admitted set's decode growth sheds the
    youngest admission explicitly: ``oom`` flagged, output a bit-exact
    *prefix* of the reference, ``shed_blocks`` counted, every request
    returned (zero silent drops)."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = _requests(cfg, [(7, 12)] * 6, seed=3)
    refs = _refs(bundle, params, reqs)
    # 6 requests x 1-block prompts all admit into 7 blocks, then growth
    # past 8 tokens wants a 2nd block each -> guaranteed exhaustion
    eng = ServeEngine(bundle, params, _paged_cfg(slots=6, n_blocks=7))
    done = eng.run(reqs)
    assert len(done) == len(reqs)
    shed = [r for r in done if r.oom]
    assert shed, "tiny pool must shed at least one request"
    assert eng.stats()["shed_blocks"] == len(shed)
    for r in done:
        if r.oom:
            assert r.done and r.out == refs[r.rid][:len(r.out)]
        else:
            assert r.out == refs[r.rid]


# --------------------------------------------------- admission edge cases
def test_submit_rejects_prompt_over_cache_len():
    """Over-long prompts raise — truncation would silently change the
    output (satellite: explicit rejection, not truncation)."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    eng = ServeEngine(bundle, params, _paged_cfg())
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(ServeRequest(
            rid=0, prompt=rng.integers(0, cfg.vocab_size,
                                       CACHE_LEN + 1).astype(np.int32),
            max_new=2))


def test_submit_rejects_prompt_over_pool_capacity():
    """A prompt whose block demand exceeds the whole pool can never be
    admitted — rejected explicitly at submit, engine and router alike."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    rng = np.random.default_rng(0)
    big = ServeRequest(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 3 * BS + 1).astype(np.int32), max_new=2)
    eng = ServeEngine(bundle, params, _paged_cfg(n_blocks=3))
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(big)
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=1, engine=_paged_cfg(n_blocks=3)))
    with pytest.raises(ValueError, match="blocks"):
        router.submit(ServeRequest(rid=1, prompt=big.prompt, max_new=2))


def test_splice_under_full_pool_preserves_resident_blocks():
    """Admitting into a pool that fills completely must leave the blocks
    already resident bit-identical — the ``mode="drop"`` sentinel scatter
    never strays outside the new request's own blocks (satellite)."""
    cfg, bundle, params = _bundle("qwen2-0.5b")
    rng = np.random.default_rng(4)
    # A spans 3 blocks; B will take the remaining 3 of a 6-block pool
    a = ServeRequest(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 2 * BS + 3).astype(np.int32), max_new=4)
    b = ServeRequest(rid=1, prompt=rng.integers(
        0, cfg.vocab_size, 2 * BS + 5).astype(np.int32), max_new=4)
    eng = ServeEngine(bundle, params, _paged_cfg(slots=4, n_blocks=6))
    eng.submit(a)
    eng.tick(0.0)                       # admit + prefill + 1 decode step
    a_blocks = jnp.asarray(eng.pool.slot_blocks(0))
    # A's first two blocks are fully written and will not be touched by
    # A's own later decode writes (those land in its 3rd block)
    frozen = np.asarray(eng.cache["k"][:, a_blocks[:2]])
    eng.submit(b)
    eng.tick(1.0)                       # B's splice fills the pool
    assert eng.pool.free_count == 0
    after = np.asarray(eng.cache["k"][:, a_blocks[:2]])
    assert np.array_equal(frozen, after)
    done = eng.drain()
    refs = _refs(bundle, params, [a, b])
    for r in done:
        assert r.out == refs[r.rid]


# ------------------------------------------------------------------ router
def test_router_paged_parity_and_block_stats():
    cfg, bundle, params = _bundle("qwen2-0.5b")
    reqs = longtail_workload(10, vocab_size=cfg.vocab_size, rate_per_s=0.0,
                             median_prompt=6, sigma=0.8,
                             max_prompt=CACHE_LEN - BS,
                             out_lens=(4, 6, 8), seed=5)
    refs = _refs(bundle, params, reqs)
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=2, engine=_paged_cfg(slots=5, n_blocks=20)))
    done = router.run([ServeRequest(rid=r.rid, prompt=r.prompt,
                                    max_new=r.max_new,
                                    arrival_s=r.arrival_s) for r in reqs])
    assert len(done) == len(reqs)
    for r in done:
        if not r.oom:
            assert r.out == refs[r.rid]
        assert r.blocks_held >= 1       # residency copied off the clone
    assert router.stats["shed_blocks"] == sum(r.oom for r in done)
    assert router.stats["peak_blocks_used"] <= 20
    assert router.stats["min_free_blocks"] is not None
    assert router.stats["min_free_blocks"] >= 0


# ----------------------------------------------------------------- loadgen
def test_longtail_workload_deterministic_and_bounded():
    cfg = reduced_config("qwen2-0.5b")
    a = longtail_workload(20, vocab_size=cfg.vocab_size, rate_per_s=5.0,
                          median_prompt=6, sigma=0.8, max_prompt=40,
                          seed=9)
    b = longtail_workload(20, vocab_size=cfg.vocab_size, rate_per_s=5.0,
                          median_prompt=6, sigma=0.8, max_prompt=40,
                          seed=9)
    assert all(np.array_equal(x.prompt, y.prompt) and
               x.arrival_s == y.arrival_s and x.max_new == y.max_new
               for x, y in zip(a, b))
    lens = [len(r.prompt) for r in a]
    assert min(lens) >= 1 and max(lens) <= 40
    assert len(set(lens)) > 3           # actually a mix, not one length
