"""Eq. (1)-(4) hardware-model properties (paper §IV)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.genome import random_genome
from repro.core.hw_model import (
    FPGA_ZU,
    TPU_V5E,
    estimate,
    latency_cycles,
    layer_costs_for,
    resolve_alphas,
    roofline,
    sample_runtime_cycles,
)
from repro.core.search_space import DEFAULT_SPACE


def _genome(seed):
    return random_genome(np.random.default_rng(seed), DEFAULT_SPACE)


@given(seed=st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_unrolling_never_slower_never_cheaper_power(seed):
    """Paper §IV: high alpha reduces runtime superlinearly but raises power."""
    g = _genome(seed)
    lo = estimate(g, strategy="min", profile=FPGA_ZU)
    hi = estimate(g, strategy="max", profile=FPGA_ZU)
    assert hi.t_total_s <= lo.t_total_s + 1e-12
    assert hi.throughput_sps >= lo.throughput_sps - 1e-9
    assert hi.p_total_w >= lo.p_total_w - 1e-9


@given(seed=st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_energy_is_power_times_time(seed):
    g = _genome(seed)
    for strat in ("min", "max"):
        e = estimate(g, strategy=strat, profile=FPGA_ZU)
        assert e.e_total_j == pytest.approx(e.t_total_s * e.p_total_w,
                                            rel=1e-9)
        assert e.e_wall_j > e.e_total_j  # board power adds on top


@given(seed=st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_sigma_recursion_monotone(seed):
    """sigma_i = max(l_i, sigma_{i-1}) must be non-decreasing along the
    pipeline, and the drain-inclusive runtime bounds the fill latency."""
    g = _genome(seed)
    costs = layer_costs_for(g)
    alphas = resolve_alphas(costs, "min", FPGA_ZU)
    t_fill, sigmas = latency_cycles(costs, alphas)
    assert all(b >= a - 1e-9 for a, b in zip(sigmas, sigmas[1:]))
    assert sample_runtime_cycles(costs, alphas) >= t_fill


@given(seed=st.integers(0, 2000))
@settings(max_examples=30, deadline=None)
def test_alpha_resolution_within_bounds(seed):
    g = _genome(seed)
    costs = layer_costs_for(g)
    for strat in ("min", "max"):
        alphas = resolve_alphas(costs, strat, FPGA_ZU)
        assert all(1 <= a <= c.alpha_max for a, c in zip(alphas, costs))
    total = sum(resolve_alphas(costs, "max", FPGA_ZU))
    assert total <= FPGA_ZU.alpha_cap


def test_profiles_scale_power():
    g = _genome(123)
    zu = estimate(g, strategy="max", profile=FPGA_ZU)
    tpu = estimate(g, strategy="max", profile=TPU_V5E)
    assert tpu.throughput_sps > zu.throughput_sps  # higher clock, more units


def test_roofline_terms():
    t = roofline(flops=1e15, bytes_hbm=1e12, bytes_collective=1e11,
                 chips=256)
    assert t.compute_s == pytest.approx(1e15 / (256 * 197e12))
    assert t.memory_s == pytest.approx(1e12 / (256 * 819e9))
    assert t.collective_s == pytest.approx(1e11 / (256 * 50e9))
    assert t.dominant in ("compute", "memory", "collective")
    assert 0 < t.roofline_fraction() <= 1.0
