"""Chaos suite for the fault-injection harness (DESIGN.md §13).

The load-bearing property is *determinism under failure*: with a seeded
:class:`FaultPlan` wired through the explicit inject points, a search that
suffers worker crashes, NaN candidates, corrupt checkpoints or preemption
recovers to a trajectory that is bit-identical (deterministic pipelines)
or structurally valid (async) versus the fault-free run.
"""
import json

import numpy as np
import pytest

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.faults import (
    DeviceLost,
    FaultPlan,
    FaultSpec,
    InjectedCrash,
    Preemption,
    crash_every,
    device_loss_every,
    nan_candidate_every,
    stall_every,
)
from repro.core.scheduler import DynamicScheduler
from repro.core.trainer import TrainResult


# --------------------------------------------------------------- harness


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="x", kind="meteor", every=1)
    with pytest.raises(ValueError, match="trigger"):
        FaultSpec(site="x", kind="crash")


def test_hit_counters_at_every_and_times():
    plan = FaultPlan([FaultSpec(site="a", kind="crash", at=(2,)),
                      FaultSpec(site="b", kind="nonfinite", every=3,
                                times=2)])
    # `at` fires on exactly the named 1-based hit
    assert [plan.check("a") is not None for _ in range(4)] == \
        [False, True, False, False]
    # `every` fires on multiples, capped by `times`
    fired_b = [plan.check("b") is not None for _ in range(12)]
    assert [i + 1 for i, f in enumerate(fired_b) if f] == [3, 6]
    assert plan.hits("a") == 4 and plan.hits("b") == 12
    log = plan.fired()
    assert [(e.site, e.hit) for e in log] == [("a", 2), ("b", 3), ("b", 6)]
    assert plan.fired(site="b", kind="nonfinite") == log[1:]


def test_when_predicate_gates_and_pure_when_fires_every_match():
    plan = FaultPlan([FaultSpec(site="s", kind="crash",
                                when=lambda c: c.get("job_id") == 7)])
    assert plan.check("s", job_id=3) is None
    assert plan.check("s", job_id=7) is not None
    assert plan.check("s", job_id=7) is not None  # no counter trigger: every
    assert len(plan.fired("s")) == 2              # accepted hit fires


def test_fire_actions_by_kind():
    plan = FaultPlan([FaultSpec(site="c", kind="crash", at=(1,)),
                      FaultSpec(site="d", kind="device_loss", at=(1,)),
                      FaultSpec(site="p", kind="preempt", at=(1,)),
                      FaultSpec(site="n", kind="nonfinite", at=(1,)),
                      FaultSpec(site="h", kind="hang", hang_s=0.0,
                                at=(1,))])
    with pytest.raises(InjectedCrash):
        plan.fire("c")
    with pytest.raises(DeviceLost):
        plan.fire("d")
    with pytest.raises(Preemption):        # a KeyboardInterrupt subclass
        plan.fire("p")
    assert issubclass(Preemption, KeyboardInterrupt)
    assert isinstance(DeviceLost("x"), InjectedCrash)
    spec = plan.fire("n")                  # data kind: returned, not raised
    assert spec is not None and spec.kind == "nonfinite"
    assert plan.fire("h").kind == "hang"   # slept 0s, returned
    assert plan.fire("c") is None          # at=(1,) spent


def test_corrupt_file_is_deterministic(tmp_path):
    blob = bytes(range(200))
    for mode in ("truncate", "garbage"):
        out = []
        for trial in range(2):
            p = tmp_path / f"{mode}{trial}.bin"
            p.write_bytes(blob)
            FaultPlan(seed=11).corrupt_file(str(p), mode=mode)
            out.append(p.read_bytes())
        assert out[0] == out[1] and out[0] != blob
        assert out[0][:100] == blob[:100]  # first half survives
    with pytest.raises(ValueError, match="corruption mode"):
        FaultPlan().corrupt_file(str(tmp_path / "truncate0.bin"),
                                 mode="nibble")


# ------------------------------------------------------------- scheduler


def test_scheduler_retries_injected_crashes_to_completion():
    """Every 3rd job's first attempt crashes; retries with backoff finish
    the batch with values identical to a fault-free run."""
    plan = FaultPlan([crash_every(3)])
    sched = DynamicScheduler(n_workers=3, max_retries=2, speculate=False,
                             backoff_base_s=0.001, faults=plan)
    jobs = [lambda i=i: i * i for i in range(12)]
    run = sched.submit(jobs)
    res = run.wait()
    assert [r.job_id for r in res] == list(range(12))
    assert all(r.ok for r in res)
    assert [r.value for r in res] == [i * i for i in range(12)]
    crashed = {e.ctx["job_id"] for e in plan.fired(kind="crash")}
    assert crashed == {2, 5, 8, 11}
    for r in res:
        assert r.attempts == (2 if r.job_id in crashed else 1)
    assert run.stats["retries"] == 4 and run.stats["backoff_s"] > 0.0


def test_device_loss_quarantines_and_rebalances():
    """One DeviceLost retires its device instantly: its worker exits and
    every job lands on the surviving device."""
    plan = FaultPlan([FaultSpec(site="scheduler.job", kind="device_loss",
                                when=lambda c: c["device"] == "dev:0",
                                times=1)])
    sched = DynamicScheduler(n_workers=2, max_retries=2, speculate=False,
                             devices=["dev:0", "dev:1"],
                             backoff_base_s=0.001, faults=plan)
    run = sched.submit([lambda device=None, i=i: i for i in range(8)])
    res = run.wait()
    assert all(r.ok for r in res) and len(res) == 8
    assert run.quarantined == ["dev:0"]
    assert run.stats["quarantined"] == 1
    assert {r.device for r in res} == {"dev:1"}  # rebalanced onto survivor


def test_last_live_device_is_never_quarantined():
    """DeviceLost on every first attempt, but with a single device the
    scheduler must keep it: partial progress beats none."""
    plan = FaultPlan([FaultSpec(site="scheduler.job", kind="device_loss",
                                when=lambda c: c["attempt"] == 1)])
    sched = DynamicScheduler(n_workers=2, max_retries=2, speculate=False,
                             devices=["dev:0"], backoff_base_s=0.001,
                             faults=plan)
    res = sched.run([lambda device=None, i=i: i for i in range(6)])
    assert all(r.ok for r in res) and len(res) == 6
    assert all(r.attempts == 2 for r in res)


def test_device_loss_every_drill_helper():
    """device_loss_every mirrors crash_every (job-keyed, so deterministic
    under any worker interleaving) but retires the device instead of just
    failing the attempt."""
    plan = FaultPlan([device_loss_every(5, times=1)])
    sched = DynamicScheduler(n_workers=2, max_retries=2, speculate=False,
                             devices=["dev:0", "dev:1"],
                             backoff_base_s=0.001, faults=plan)
    run = sched.submit([lambda device=None, i=i: i for i in range(8)])
    res = run.wait()
    assert all(r.ok for r in res) and len(res) == 8
    events = plan.fired(kind="device_loss")
    assert [e.ctx["job_id"] for e in events] == [4]   # every 5th job
    assert run.stats["quarantined"] == 1              # device retired


def test_stall_every_drill_helper():
    """stall_every schedules counter-keyed stalls: the clock-owning caller
    receives the spec (check, never fire) and advances its own time."""
    spec = stall_every(3, 2.5)
    assert (spec.site, spec.kind, spec.hang_s) == ("serve.decode", "stall",
                                                   2.5)
    plan = FaultPlan([stall_every(3, 2.5, site="serve.replica", times=2)])
    hits = [plan.check("serve.replica", replica=0, tick=t, step=t)
            for t in range(9)]
    assert [i + 1 for i, s in enumerate(hits) if s is not None] == [3, 6]
    assert all(s.hang_s == 2.5 for s in hits if s is not None)


# ------------------------------------------------------- search-level chaos


def _det_batch_trainer():
    def train(genomes, device=None):
        out = []
        for g in genomes:
            det = min(0.99, 0.70 + 0.05 * g.depth())
            out.append(TrainResult(
                detection_rate=det,
                false_alarm_rate=max(0.0, 0.3 - 0.04 * g.depth()),
                val_loss=0.2, steps=0))
        return out
    return train


def _search(pipeline="off", seed=3, faults=None, log=None, **kw):
    kw.setdefault("generations", 4)
    cfg = NASConfig(children_per_gen=10, n_accept=4,
                    init_population=8, population_cap=16, n_workers=2,
                    seed=seed, pipeline=pipeline, **kw)
    return EvolutionarySearch(cfg, None, None,
                              batch_train_fn=_det_batch_trainer(),
                              log=log or (lambda *_: None), faults=faults)


def _assert_same_trajectory(a, b):
    assert a.generation == b.generation
    assert list(a.pop.phash) == list(b.pop.phash)
    np.testing.assert_array_equal(a.pop.cheap, b.pop.cheap)
    np.testing.assert_array_equal(a.pop.expensive, b.pop.expensive)
    np.testing.assert_array_equal(a.pop.born, b.pop.born)
    assert set(a.evaluated_hashes) == set(b.evaluated_hashes)
    for h in a.evaluated_hashes:
        np.testing.assert_array_equal(a.evaluated_hashes[h],
                                      b.evaluated_hashes[h])
    for ra, rb in zip(a.history, b.history):
        for k in ("generation", "children", "trained", "population",
                  "front_size", "feasible", "best_primary"):
            assert ra[k] == rb[k] or (
                np.isnan(ra[k]) and np.isnan(rb[k])), k


def test_search_is_bit_identical_under_crash_and_retry():
    """The acceptance drill: a worker crash every 3rd job, retried by the
    scheduler, must not perturb a single bit of the search trajectory."""
    ref = _search().run()
    plan = FaultPlan([crash_every(3)])
    faulted = _search(faults=plan).run()
    assert plan.fired("scheduler.job", kind="crash")  # faults really fired
    _assert_same_trajectory(ref, faulted)


def test_nan_candidate_quarantined_bucket_mates_survive():
    """One injected non-finite training result: that candidate lands at the
    schema-pessimistic row while every bucket-mate keeps the exact values
    of the fault-free run."""
    ref = _search().init_state()
    plan = FaultPlan([nan_candidate_every(5, times=1)])
    lines = []
    state = _search(faults=plan, log=lambda *a: lines.append(
        " ".join(str(x) for x in a))).init_state()
    events = plan.fired("trainer.result", kind="nonfinite")
    assert len(events) == 1
    bad = events[0].ctx["phash"]
    assert any("diverged" in ln and "quarantined" in ln for ln in lines)
    assert list(state.pop.phash) == list(ref.pop.phash)
    s = _search()
    worst = s._exp_worst
    for i, h in enumerate(state.pop.phash):
        if str(h) == bad:
            np.testing.assert_array_equal(state.pop.expensive[i], worst)
        else:
            np.testing.assert_array_equal(state.pop.expensive[i],
                                          ref.pop.expensive[i])
    # the pessimistic row also reached the dormant-gene cache (the
    # candidate is never retrained, like any permanently failed one)
    np.testing.assert_array_equal(state.evaluated_hashes[bad], worst)


def test_checkpoint_corruption_falls_back_to_rotated_prev(tmp_path):
    """An injected torn write on the final checkpoint: load_state warns,
    falls back to `<path>.prev`, and the resumed search finishes
    bit-identically to the uninterrupted one."""
    path = str(tmp_path / "ckpt.json")
    # saves: init (hit 1), gen1 (2), gen2 (3 -> corrupted on disk)
    plan = FaultPlan([FaultSpec(site="ckpt.save", kind="corrupt", at=(3,))])
    final = _search(generations=2, faults=plan).run_resumable(path)
    assert final.generation == 2
    with pytest.raises(json.JSONDecodeError):
        json.load(open(path))               # the write really is torn
    lines = []
    restored = _search(generations=2,
                       log=lambda *a: lines.append(
                           " ".join(str(x) for x in a))).load_state(path)
    assert any("corrupt" in ln and ".prev" in ln for ln in lines)
    assert restored.generation == 1         # one generation lost, not all
    resumed = _search(generations=2).run_resumable(path)
    _assert_same_trajectory(final, resumed)


def test_corrupt_checkpoint_without_prev_still_raises(tmp_path):
    path = str(tmp_path / "ckpt.json")
    with open(path, "w") as f:
        f.write('{"generation": 1, "hist')    # torn write, no rotation yet
    with pytest.raises(json.JSONDecodeError):
        _search().load_state(path)


def test_both_checkpoints_torn_raises_clean_error(tmp_path):
    """The double fault: `<path>` AND `<path>.prev` both torn.  The caller
    gets one clean RuntimeError naming both files and both parse errors —
    never a raw traceback from mid-parse of the fallback."""
    path = str(tmp_path / "ckpt.json")
    with open(path, "w") as f:
        f.write('{"generation": 2, "hist')            # torn current
    with open(path + ".prev", "w") as f:
        f.write('{"generation": 1, "population": [')  # torn previous
    with pytest.raises(RuntimeError,
                       match="both checkpoints are corrupt") as exc:
        _search().load_state(path)
    msg = str(exc.value)
    assert path in msg and path + ".prev" in msg
    assert "JSONDecodeError" in msg
    # the underlying parse error is chained for debugging, not surfaced raw
    assert isinstance(exc.value.__cause__, json.JSONDecodeError)


def test_two_consecutive_torn_save_cycles_fall_back(tmp_path):
    """Two run→torn-final-save cycles in a row: each cycle's rotation keeps
    one good generation behind the torn write, so each load falls back
    cleanly and the twice-resumed search is bit-identical to an
    uninterrupted one."""
    path = str(tmp_path / "ckpt.json")
    # cycle 1: saves init(1), gen1(2), gen2(3 -> torn)
    plan1 = FaultPlan([FaultSpec(site="ckpt.save", kind="corrupt", at=(3,))])
    _search(generations=2, faults=plan1).run_resumable(path)
    lines = []
    log = lambda *a: lines.append(" ".join(str(x) for x in a))  # noqa: E731
    assert _search(generations=2, log=log).load_state(path).generation == 1
    assert any("corrupt" in ln and ".prev" in ln for ln in lines)
    # cycle 2: resume from the fallback cut and run to generation 3; the
    # resumed run saves gen2(1), gen3(2 -> torn again)
    plan2 = FaultPlan([FaultSpec(site="ckpt.save", kind="corrupt", at=(2,))])
    _search(generations=3, faults=plan2).run_resumable(path)
    with pytest.raises(json.JSONDecodeError):
        json.load(open(path))                 # the final write really tore
    lines2 = []
    log2 = lambda *a: lines2.append(" ".join(str(x) for x in a))  # noqa: E731
    restored = _search(generations=3, log=log2).load_state(path)
    assert any("corrupt" in ln and ".prev" in ln for ln in lines2)
    assert restored.generation == 2           # cycle 2's good rotation
    # a third resume completes the search bit-identically
    ref = _search(generations=3).run_resumable(str(tmp_path / "ref.json"))
    final = _search(generations=3).run_resumable(path)
    _assert_same_trajectory(ref, final)


def test_graceful_preemption_resumes_bit_identically(tmp_path):
    """Injected SIGTERM at generation 2: run_resumable persists the last
    consistent state, re-raises, and a fresh process completes the search
    bit-identically to one that was never preempted."""
    ref = _search().run_resumable(str(tmp_path / "ref.json"))
    path = str(tmp_path / "ckpt.json")
    plan = FaultPlan([FaultSpec(site="search.generation", kind="preempt",
                                when=lambda c: c["generation"] == 2,
                                times=1)])
    with pytest.raises(KeyboardInterrupt):
        _search(faults=plan).run_resumable(path)
    mid = _search().load_state(path)
    assert mid.generation == 2              # the last completed generation
    resumed = _search().run_resumable(path)
    _assert_same_trajectory(ref, resumed)


def test_async_preemption_resumes_to_valid_front(tmp_path):
    """Preempting the async pipeline mid-flight: the checkpoint holds the
    last consistent drained cut; resuming completes to target with every
    structural invariant intact (async trades bit-parity for overlap)."""
    from repro.core.pareto import pareto_front
    path = str(tmp_path / "ckpt.json")
    plan = FaultPlan([FaultSpec(site="search.generation", kind="preempt",
                                when=lambda c: c["generation"] >= 2,
                                times=1)])
    with pytest.raises(KeyboardInterrupt):
        _search(pipeline="async", faults=plan).run_resumable(path)
    mid = _search(pipeline="async").load_state(path)
    assert 2 <= mid.generation < 4
    assert mid.pop.trained_mask.all()       # the cut is consistent
    final = _search(pipeline="async").run_resumable(path)
    assert final.generation == 4
    assert len(set(final.pop.phash)) == len(final.pop)
    assert final.pop.trained_mask.all()
    objs = np.stack([c.objective_vector() for c in final.population])
    assert len(pareto_front(objs)) >= 1
    assert all(r.get("pipeline") == "async" for r in final.history)


# ------------------------------------------------- router chaos (§14)


def _serve_setup():
    import jax
    from repro.configs import reduced_config
    from repro.models.registry import build_model
    cfg = reduced_config("qwen2-0.5b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def test_router_chaos_parity_under_replica_loss_and_stall():
    """THE acceptance drill (ISSUE 9): a seeded FaultPlan kills one replica
    mid-decode (device_loss → quarantine + failover) and silently stalls
    another (heartbeat → evict + restart).  Every admitted request must
    come back bit-identical to the fault-free greedy reference; requests
    shed at the bounded queue are explicitly rejected with counts
    asserted; zero silent drops."""
    from repro.serve import (EngineConfig, ReplicaRouter, RouterConfig,
                             ServeRequest, greedy_reference)
    cfg, bundle, params = _serve_setup()
    rng = np.random.default_rng(0)
    reqs = []
    arrivals = [0.0, 0.0, 0.0, 0.0, 2.0, 3.0, 5.0, 8.0]
    for i, arr in enumerate(arrivals):
        reqs.append(ServeRequest(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 4 + i % 5).astype(
                np.int32),
            max_new=4 + i % 4, arrival_s=arr))
    refs = {r.rid: greedy_reference(bundle, params, r.prompt, r.max_new, 48)
            for r in reqs}
    plan = FaultPlan([
        # kill replica 0 mid-decode: instant quarantine, in-flight work
        # fails over to replica 1 and re-decodes from the prompt
        FaultSpec(site="serve.replica", kind="device_loss",
                  when=lambda c: c["replica"] == 0 and c["tick"] == 3),
        # silently stall replica 1: only the decode-step heartbeat may
        # notice (dispatch never sees the injected state)
        FaultSpec(site="serve.replica", kind="stall", hang_s=6.0, times=1,
                  when=lambda c: c["replica"] == 1 and c["tick"] == 5),
    ], seed=0)
    rcfg = RouterConfig(replicas=2, max_queue=3, heartbeat_misses=2,
                        engine=EngineConfig(slots=2, cache_len=48, pad_to=4,
                                            max_prefill_batch=2))
    router = ReplicaRouter(bundle, params, rcfg, faults=plan)
    done = router.run(list(reqs))
    s = router.stats
    # zero silent drops: every request back exactly once, admitted+shed=all
    assert [r.rid for r in done] == list(range(len(reqs)))
    assert s["admitted"] + s["shed_queue"] + s["shed_deadline"] == len(reqs)
    # the burst of 4 over max_queue=3 shed one up front; losing half the
    # capacity mid-run backs the queue up and sheds more — all explicit
    shed = [r for r in done if r.rejected]
    assert len(shed) == s["shed_queue"] >= 1
    assert 3 not in {r.rid for r in done if not r.rejected}  # burst overflow
    assert all(not r.out and not r.done for r in shed)
    # both faults really fired
    assert plan.fired("serve.replica", kind="device_loss")
    assert plan.fired("serve.replica", kind="stall")
    # the dead replica was quarantined (and stays dead); the stalled one
    # was caught by the heartbeat, evicted and restarted
    assert s["quarantined"] == [0]
    assert not router.replicas[0].live and router.replicas[1].live
    assert s["failovers"] >= 1 and s["restarts"] >= 1
    # bit-identical parity for every admitted request: the fault-free
    # greedy reference is the oracle (failover re-decodes from the prompt,
    # greedy decode is deterministic, so partial work lost with replica 0
    # is reproduced exactly on replica 1)
    for r in done:
        if r.rejected:
            continue
        assert not r.expired
        assert r.out == refs[r.rid], r.rid


def test_router_dispatch_fault_redispatches():
    """A crash at the router.dispatch hand-off itself: the chosen replica
    is failed and restarted, the request is requeued, and everything still
    completes bit-identically."""
    from repro.serve import (EngineConfig, ReplicaRouter, RouterConfig,
                             ServeRequest, greedy_reference)
    cfg, bundle, params = _serve_setup()
    rng = np.random.default_rng(1)
    reqs = [ServeRequest(rid=i,
                         prompt=rng.integers(0, cfg.vocab_size, 5).astype(
                             np.int32),
                         max_new=4, arrival_s=0.0) for i in range(4)]
    refs = {r.rid: greedy_reference(bundle, params, r.prompt, r.max_new, 48)
            for r in reqs}
    plan = FaultPlan([FaultSpec(site="router.dispatch", kind="crash",
                                at=(1,))])
    router = ReplicaRouter(bundle, params, RouterConfig(
        replicas=2, engine=EngineConfig(slots=2, cache_len=48, pad_to=4,
                                        max_prefill_batch=2)), faults=plan)
    done = router.run(reqs)
    assert len(done) == 4
    for r in done:
        assert not r.rejected and not r.expired
        assert r.out == refs[r.rid]
    assert plan.fired("router.dispatch", kind="crash")
    assert router.stats["restarts"] == 1      # failed at hand-off, restarted
    assert router.stats["quarantined"] == []  # one strike, not a streak


def test_async_checkpoints_only_at_drain_barriers(tmp_path):
    """Every checkpoint an async run writes is a drained cut: fully
    trained, generation a multiple of the barrier stride."""
    path = str(tmp_path / "ckpt.json")
    seen = []
    s = _search(pipeline="async", lookahead=1, ckpt_every=2)
    orig = s.save_state

    def spy(state, p):
        seen.append(state.generation)
        assert state.pop.trained_mask.all()
        orig(state, p)

    s.save_state = spy
    s.run_resumable(path)
    assert seen[0] == 0                     # the post-init persist
    assert seen[1:] == [2, 4]               # barrier stride, then final
