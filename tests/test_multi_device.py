"""Device-affine orchestration on a (simulated) multi-device host.

Runs in a subprocess with 4 forced host devices — the main pytest process
must keep a single device (see conftest), and
``--xla_force_host_platform_device_count`` only takes effect before jax
initializes.  The child asserts the full §11 contract: device resolution,
bucket spreading, per-device busy accounting, affinity-on pipeline parity,
and device-invariant bucketed training.  See multi_device_check.py.
"""
import os
import subprocess
import sys


def test_device_affine_orchestration_subprocess():
    script = os.path.join(os.path.dirname(__file__), "multi_device_check.py")
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "MULTI_DEVICE_OK" in proc.stdout
