"""Pareto machinery: properties of non-dominated sorting and selection."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.pareto import (
    crowding_distance,
    dominates,
    environmental_selection,
    hypervolume_2d,
    non_dominated_sort,
    non_dominated_sort_reference,
    pareto_front,
)

points_st = hnp.arrays(np.float64, hnp.array_shapes(min_dims=2, max_dims=2,
                                                    min_side=1, max_side=40),
                       elements=st.floats(0, 100, allow_nan=False))


@given(points_st)
@settings(max_examples=60, deadline=None)
def test_fronts_partition_and_order(pts):
    fronts = non_dominated_sort(pts)
    all_idx = np.concatenate(fronts) if fronts else np.array([])
    assert sorted(all_idx.tolist()) == list(range(len(pts)))
    # front 0 contains no dominated point
    f0 = set(fronts[0].tolist())
    for i in f0:
        for j in range(len(pts)):
            assert not (j != i and dominates(pts[j], pts[i]))
    # each later front is dominated by someone in an earlier front
    for k in range(1, len(fronts)):
        for i in fronts[k]:
            assert any(dominates(pts[j], pts[i])
                       for f in fronts[:k] for j in f)


@given(points_st, st.integers(1, 20))
@settings(max_examples=40, deadline=None)
def test_environmental_selection_capacity_and_front0(pts, cap):
    keep = environmental_selection(pts, cap)
    assert len(keep) == min(cap, len(pts))
    assert len(set(keep.tolist())) == len(keep)
    # if capacity allows, all of front 0 is kept
    f0 = pareto_front(pts)
    if len(f0) <= cap:
        assert set(f0.tolist()) <= set(keep.tolist())


@given(points_st)
@settings(max_examples=60, deadline=None)
def test_vectorized_sort_matches_reference(pts):
    """The domination-matrix sort must reproduce the Deb reference exactly —
    same fronts, same ascending index order within each front."""
    ref = non_dominated_sort_reference(pts)
    vec = non_dominated_sort(pts)
    assert len(ref) == len(vec)
    for a, b in zip(ref, vec):
        np.testing.assert_array_equal(a, b)


def test_crowding_boundary_infinite():
    pts = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    cd = crowding_distance(pts)
    assert np.isinf(cd[0]) and np.isinf(cd[-1])
    assert np.all(cd[1:-1] < np.inf)


def test_hypervolume_monotone():
    ref = np.array([10.0, 10.0])
    a = np.array([[5.0, 5.0]])
    b = np.array([[5.0, 5.0], [2.0, 8.0]])
    assert hypervolume_2d(b, ref) >= hypervolume_2d(a, ref)
    assert hypervolume_2d(a, ref) == 25.0
