"""Benchmark harness: one function per paper table + kernel/roofline benches.

Prints ``name,us_per_call,derived`` CSV (harness contract).  Full-size runs:
``python -m benchmarks.run --full``; default sizes finish on the CPU box in
a few minutes.
"""
import argparse
import subprocess
import sys
import time


def _run_pipeline_bench(args) -> list:
    """The overlapped-pipeline bench needs 4 forced host devices, and
    ``--xla_force_host_platform_device_count`` only takes effect before jax
    initializes — by this point the in-process benches already did.  So it
    runs as a subprocess (the module stages its own XLA_FLAGS) and its CSV
    rows are folded back into ours."""
    cmd = [sys.executable, "-m", "benchmarks.pipeline_bench"]
    if args.full:
        cmd.append("--full")
    if args.json:
        cmd += ["--json", "BENCH_pipeline.json"]
    proc = subprocess.run(cmd, capture_output=True, text=True)
    print(proc.stderr, file=sys.stderr, end="")
    if proc.returncode != 0:
        raise RuntimeError(
            f"pipeline_bench failed (rc={proc.returncode}):\n{proc.stdout}")
    rows = []
    for line in proc.stdout.splitlines():
        if not line or line.startswith("#"):
            continue
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived.strip().strip('"')})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale NAS settings (hours)")
    ap.add_argument("--skip-nas", action="store_true",
                    help="only kernel + roofline benches")
    ap.add_argument("--json", action="store_true",
                    help="also write machine-readable per-bench results "
                         "(BENCH_<name>.json) for perf-trajectory tracking")
    args = ap.parse_args()

    rows = []
    t0 = time.time()

    from benchmarks import (
        fault_bench,
        kernel_bench,
        multi_platform_bench,
        nas_loop_bench,
        population_eval_bench,
        roofline_table,
        router_bench,
        serve_bench,
        train_bench,
    )
    kernel_rows = kernel_bench.run(log=lambda *a: print(*a, file=sys.stderr))
    rows += kernel_rows
    if args.json:
        kernel_bench.write_json(kernel_rows, "BENCH_kernels.json")
        print("# wrote BENCH_kernels.json", file=sys.stderr)
    rows += population_eval_bench.run(
        log=lambda *a: print(*a, file=sys.stderr))
    multi_platform_rows = multi_platform_bench.run(
        log=lambda *a: print(*a, file=sys.stderr), smoke=not args.full)
    rows += multi_platform_rows
    if args.json:
        multi_platform_bench.write_json(multi_platform_rows,
                                        "BENCH_multi_platform.json")
        print("# wrote BENCH_multi_platform.json", file=sys.stderr)
    nas_loop_rows = nas_loop_bench.run(
        log=lambda *a: print(*a, file=sys.stderr), smoke=not args.full)
    rows += nas_loop_rows
    if args.json:
        nas_loop_bench.write_json(nas_loop_rows, "BENCH_nas_loop.json")
        print("# wrote BENCH_nas_loop.json", file=sys.stderr)
    train_loop_rows = train_bench.run(
        log=lambda *a: print(*a, file=sys.stderr), smoke=not args.full)
    rows += train_loop_rows
    if args.json:
        train_bench.write_json(train_loop_rows, "BENCH_train_loop.json")
        print("# wrote BENCH_train_loop.json", file=sys.stderr)
    rows += _run_pipeline_bench(args)
    fault_rows, fault_summary = fault_bench.run(
        log=lambda *a: print(*a, file=sys.stderr), smoke=not args.full)
    rows += fault_rows
    if args.json:
        fault_bench.write_json(fault_rows, fault_summary,
                               "BENCH_faults.json")
        print("# wrote BENCH_faults.json", file=sys.stderr)
    serve_rows, serve_summary = serve_bench.run(
        log=lambda *a: print(*a, file=sys.stderr), smoke=not args.full,
        n_requests=64 if args.full else 32)
    rows += serve_rows
    if args.json:
        serve_bench.write_json(serve_rows, serve_summary, "BENCH_serve.json")
        print("# wrote BENCH_serve.json", file=sys.stderr)
    router_rows, router_summary = router_bench.run(
        log=lambda *a: print(*a, file=sys.stderr), smoke=not args.full)
    rows += router_rows
    if args.json:
        router_bench.write_json(router_rows, router_summary,
                                "BENCH_router.json")
        print("# wrote BENCH_router.json", file=sys.stderr)
    rows += roofline_table.run(log=lambda *a: print(*a, file=sys.stderr))
    roofline_table.write_markdown(log=lambda *a: print(*a, file=sys.stderr))

    if not args.skip_nas:
        from benchmarks import table1_objectives, table2_domains
        gens = 12 if args.full else 3
        samples = 1600 if args.full else 240
        steps = 300 if args.full else 60

        t = time.time()
        t1 = table1_objectives.run(generations=gens, samples=samples,
                                   train_steps=steps,
                                   log=lambda *a: print(*a, file=sys.stderr))
        for r in t1:
            rows.append({
                "name": f"table1:{r['nas_objective']}:{r['impl_strategy']}",
                "us_per_call": (time.time() - t) * 1e6 / max(len(t1), 1),
                "derived": (f"thr={r['throughput_sps']:.3g}sps "
                            f"P={r['p_total_w']:.2f}W "
                            f"E={r['e_total_uj']:.3g}uJ "
                            f"params={r['params']}"),
            })
        for claim, ok in table1_objectives.validate(t1).items():
            rows.append({"name": f"table1_claim:{claim}",
                         "us_per_call": 0.0, "derived": str(ok)})

        t = time.time()
        t2 = table2_domains.run(generations=gens, samples=samples,
                                train_steps=steps,
                                log=lambda *a: print(*a, file=sys.stderr))
        for r in t2:
            rows.append({
                "name": f"table2:{r['device'].split(' (')[0]}",
                "us_per_call": (time.time() - t) * 1e6 / max(len(t2), 1),
                "derived": (f"f={r['freq_mhz']:.0f}MHz batch={r['batch']} "
                            f"thr={r['throughput_sps']:.3g}sps "
                            f"P={r['p_total_w']:.2f}W "
                            f"E={r['e_total_j']:.3g}J"),
            })
        for claim, ok in table2_domains.validate(t2).items():
            rows.append({"name": f"table2_claim:{claim}",
                         "us_per_call": 0.0, "derived": str(ok)})

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    sys.stdout.flush()  # keep the CSV clean when stderr is merged via 2>&1
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
