"""Population evaluation throughput: scalar loop vs batched engine.

Measures genomes/sec of cheap-objective evaluation (all 7 analytic
objectives, min+max alpha) at population sizes {64, 512, 4096}:

* ``scalar`` — the per-genome reference loop (`cheap_objectives` per child),
  timed on a capped subsample and extrapolated (it is O(N) in python);
* ``batched`` — `cheap_objectives_batch` through the FPGAAnalyticBackend,
  timed end-to-end including the array encoding step.

Medians over several repetitions keep the speedup figure stable on noisy
boxes.  Acceptance target: >= 10x at population 512.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.genome import random_genome
from repro.core.objectives import cheap_objectives, cheap_objectives_batch
from repro.core.search_space import DEFAULT_SPACE

SIZES = (64, 512, 4096)
SCALAR_CAP = 128   # scalar loop sample size (timing extrapolates linearly)
REPEATS = 7


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(log=print) -> List[Dict]:
    rng = np.random.default_rng(0)
    log(f"[pop_eval] sampling {max(SIZES)} genomes ...")
    genomes = [random_genome(rng, DEFAULT_SPACE) for _ in range(max(SIZES))]
    rows: List[Dict] = []
    for n in SIZES:
        pop = genomes[:n]
        n_scalar = min(n, SCALAR_CAP)
        for _ in range(2):                                # warm-up
            cheap_objectives_batch(pop)
            [cheap_objectives(g) for g in pop[:8]]
        # paired measurements: scalar and batched sampled back-to-back so
        # machine-state drift (throttling, noisy neighbours) cancels in
        # the per-pair ratio
        t_b, t_s, ratios = [], [], []
        for _ in range(REPEATS):
            tb = _time(lambda: cheap_objectives_batch(pop))
            ts = _time(
                lambda: [cheap_objectives(g) for g in pop[:n_scalar]]) \
                / n_scalar * n
            t_b.append(tb)
            t_s.append(ts)
            ratios.append(ts / tb)
        t_batch = float(np.median(t_b))
        t_scalar = float(np.median(t_s))
        gps_b, gps_s = n / t_batch, n / t_scalar
        speedup = float(np.median(ratios))
        log(f"[pop_eval] n={n}: batched {gps_b:,.0f} g/s, "
            f"scalar {gps_s:,.0f} g/s, speedup {speedup:.1f}x")
        rows.append({
            "name": f"pop_eval_batched_{n}",
            "us_per_call": t_batch * 1e6,
            "derived": f"{gps_b:.0f}genomes/s speedup={speedup:.1f}x",
        })
        rows.append({
            "name": f"pop_eval_scalar_{n}",
            "us_per_call": t_scalar * 1e6,
            "derived": f"{gps_s:.0f}genomes/s",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
