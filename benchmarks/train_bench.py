"""Expensive-path throughput: bucketed vmap-stacked candidate training vs
the scalar per-candidate loop (DESIGN.md §9).

All children share one shape signature (same topology, per-candidate seeds
and quantization bit widths), so the batched side trains the whole
generation in a single vmapped `lax.scan` dispatch while the scalar side
pays per-step dispatch overhead per candidate.  Timings are steady-state:
both sides are warmed first (the signature compile cache amortizes across
generations in the real search).  Seeded parity between the batched and
scalar `TrainResult`s is asserted at the smallest size before anything is
timed — the speedup only counts if the numbers are the same numbers.

Acceptance target: >= 5x candidates/sec at 32 children (CPU smoke run).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.genome import Genome
from repro.core.search_space import SearchSpace
from repro.core.trainer import train_candidate
from repro.core.trainer_batch import train_candidates_batched

# the scalar side re-jits per candidate (~2s each), so the 128-child point
# runs only in --full; the acceptance criterion (>= 5x at 32) is in smoke
SIZES_SMOKE, SIZES_FULL = (8, 32), (8, 32, 128)
SMOKE_STEPS, FULL_STEPS = 16, 100
BATCH = 32
N_TR, N_VA = 192, 96
PARITY_SIZE = 8

# coarse decimation keeps candidate inputs short (60000/240 = 250 samples)
SPACE = SearchSpace(input_decimations=(240,))


def _shared_signature_children(n: int) -> List[Genome]:
    """``n`` children of one topology: distinct seeds do the differing; the
    quant genes cycle through all 8 precision combos (stacked as data, so
    the bucket stays whole)."""
    d = SPACE.max_depth
    # chain: conv c8 k3 s2 -> conv c4 k5 s4 (op table ids 28 and 20)
    op = (28, 20) + (0,) * (d - 2)
    conn = tuple(range(d))
    return [Genome(op_genes=op, conn_genes=conn, out_gene=2,
                   w_bits_gene=(i >> 2) & 1, a_bits_gene=(i >> 1) & 1,
                   i_bits_gene=i & 1, dec_gene=0) for i in range(n)]


def _dataset(seed: int = 0):
    rng = np.random.default_rng(seed)
    x_tr = rng.normal(size=(N_TR, 250, 2)).astype(np.float32)
    x_va = rng.normal(size=(N_VA, 250, 2)).astype(np.float32)
    y_tr = (np.arange(N_TR) % 2).astype(np.int32)
    y_va = (np.arange(N_VA) % 2).astype(np.int32)
    return (x_tr, y_tr), (x_va, y_va)


def run(log=print, smoke: bool = True) -> List[Dict]:
    steps = SMOKE_STEPS if smoke else FULL_STEPS
    sizes = SIZES_SMOKE if smoke else SIZES_FULL
    tr, va = _dataset()
    kw = dict(space=SPACE, steps=steps, batch_size=BATCH, lr=3e-3)

    def scalar(children):
        return [train_candidate(g, tr, va, seed=i, **kw)
                for i, g in enumerate(children)]

    def batched(children):
        return train_candidates_batched(children, tr, va,
                                        seeds=list(range(len(children))),
                                        **kw)

    # ---- seeded parity gate (smallest size, also warms the scalar jit)
    children = _shared_signature_children(PARITY_SIZE)
    res_s, res_b = scalar(children), batched(children)
    for k, (s, b) in enumerate(zip(res_s, res_b)):
        assert (s.detection_rate, s.false_alarm_rate) == \
            (b.detection_rate, b.false_alarm_rate), \
            f"parity: candidate {k} objectives diverged ({s} vs {b})"
        assert abs(s.val_loss - b.val_loss) < 5e-3, \
            f"parity: candidate {k} val_loss diverged ({s} vs {b})"
    log(f"[train_loop] parity ok at n={PARITY_SIZE} "
        f"(det/fa identical, max |dnll|="
        f"{max(abs(s.val_loss - b.val_loss) for s, b in zip(res_s, res_b)):.1e})")

    rows: List[Dict] = []
    for n in sizes:
        children = _shared_signature_children(n)
        batched(children)  # warm the vmapped compile at this bucket size
        t0 = time.perf_counter()
        batched(children)
        t_batched = time.perf_counter() - t0
        t0 = time.perf_counter()
        scalar(children)
        t_scalar = time.perf_counter() - t0
        cps_b, cps_s = n / t_batched, n / t_scalar
        speedup = t_scalar / t_batched
        log(f"[train_loop] n={n}: batched {cps_b:.1f} cand/s, "
            f"scalar {cps_s:.1f} cand/s, speedup {speedup:.1f}x "
            f"({steps} steps)")
        rows.append({"name": f"train_loop_batched_{n}",
                     "us_per_call": t_batched * 1e6 / n,
                     "derived": f"cands_per_sec={cps_b:.2f} "
                                f"speedup={speedup:.1f}x steps={steps}"})
        rows.append({"name": f"train_loop_scalar_{n}",
                     "us_per_call": t_scalar * 1e6 / n,
                     "derived": f"cands_per_sec={cps_s:.2f} steps={steps}"})
    return rows


def write_json(rows: List[Dict], path: str) -> None:
    """The machine-readable result format (single writer — run.py and the
    CLI below both route through this)."""
    with open(path, "w") as f:
        json.dump({"bench": "train_loop", "rows": rows}, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help=f"{FULL_STEPS} train steps (default: smoke, "
                         f"{SMOKE_STEPS})")
    ap.add_argument("--smoke", action="store_true",
                    help="explicit smoke mode (the default; kept for CI "
                         "command-line clarity)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as machine-readable JSON")
    args = ap.parse_args()
    rows = run(smoke=not args.full)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
