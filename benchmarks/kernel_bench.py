"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(the Pallas kernels target TPU; interpret mode timing is meaningless) plus
the analytic VMEM/MXU utilization of the kernels' BlockSpec tiling.

``--json`` (or ``benchmarks/run.py --json``) writes the rows to
BENCH_kernels.json for perf-trajectory tracking; there is no gate summary
— kernel wall times are absolute and machine-dependent, so the CI gate
only checks the file exists and parses.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn: Callable, *args, iters: int = 5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(log=print) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # conv1d: HALF's hot spot at the paper's ECG scale
    from repro.kernels.conv1d.ref import dwsep_conv1d_ref
    x = jnp.asarray(rng.normal(size=(8, 1875, 8)), jnp.float32)
    dw = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    pw = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    b = jnp.zeros((32,), jnp.float32)
    f = jax.jit(lambda *a: dwsep_conv1d_ref(*a))
    us = _time(f, x, dw, pw, b)
    macs = 8 * 1871 * (5 * 8 + 8 * 32)
    rows.append({"name": "conv1d_ref_ecg", "us_per_call": us,
                 "derived": f"{macs/us*1e-3:.2f}GMAC/s"})

    # chunked attention (the train/prefill lowering path)
    from repro.models.attention import chunked_attention
    q = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)), jnp.bfloat16)
    f = jax.jit(lambda *a: chunked_attention(*a, causal=True, chunk=256))
    us = _time(f, q, k, v)
    fl = 4 * 1024 * 1024 * 8 * 64
    rows.append({"name": "chunked_attention_1k", "us_per_call": us,
                 "derived": f"{fl/us*1e-6:.2f}GFLOP/s"})

    # SSD chunked scan
    from repro.models.mamba2 import ssd_chunked
    xs = jnp.asarray(rng.normal(size=(1, 2048, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 0.1, (1, 2048, 8)), jnp.float32)
    an = -jnp.asarray(rng.uniform(1, 8, (8,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(1, 2048, 1, 64)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(1, 2048, 1, 64)), jnp.float32)
    f = jax.jit(lambda *a: ssd_chunked(*a, 256)[0])
    us = _time(f, xs, dt, an, bm, cm)
    rows.append({"name": "ssd_chunked_2k", "us_per_call": us,
                 "derived": f"chunk=256"})

    # paged decode attention (DESIGN.md §15): dense-gather reference vs the
    # chunked fast path over a block-table pool — B=16 single-token rows,
    # mixed kv_len, 8-token blocks (the serve bench's paged geometry)
    from repro.kernels.decode_attention.ops import (
        paged_decode_attention)
    B, NB, BS, KVH, HD, REP = 16, 8, 8, 2, 64, 4
    P = B * NB
    qp = jnp.asarray(rng.normal(size=(B, KVH * REP, HD)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, BS, KVH, HD)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, BS, KVH, HD)), jnp.float32)
    tbl = jnp.asarray(
        rng.permutation(P)[:B * NB].reshape(B, NB), jnp.int32)
    kvl = jnp.asarray(rng.integers(1, NB * BS, (B,)), jnp.int32)
    for impl in ("ref", "chunked"):
        f = jax.jit(lambda q_, k_, v_, t_, l_, impl=impl:
                    paged_decode_attention(q_, k_, v_, t_, l_, impl=impl))
        us = _time(f, qp, kp, vp, tbl, kvl)
        fl = 4 * B * NB * BS * KVH * REP * HD
        rows.append({"name": f"paged_decode_{impl}_b{B}", "us_per_call": us,
                     "derived": f"{fl/us*1e-6:.2f}GFLOP/s "
                                f"blocks={NB}x{BS}"})

    # MoE grouped matmul reference
    from repro.kernels.moe_gmm.ref import gmm_ref
    xe = jnp.asarray(rng.normal(size=(8, 128, 256)), jnp.bfloat16)
    we = jnp.asarray(rng.normal(size=(8, 256, 512)), jnp.bfloat16)
    f = jax.jit(lambda *a: gmm_ref(*a))
    us = _time(f, xe, we)
    fl = 2 * 8 * 128 * 256 * 512
    rows.append({"name": "moe_gmm_ref", "us_per_call": us,
                 "derived": f"{fl/us*1e-6:.2f}GFLOP/s"})

    # kernel VMEM budgets (static analysis of the BlockSpec tiling)
    budgets = {
        "flash_attention(BQ=BK=512,hd=128)":
            (512 * 128 * 4 * 2 + 512 * 512 * 4 + 512 * 128 * 4 + 512 * 8),
        "ssd(Q=256,N=128,P=64)":
            (256 * 64 * 4 + 256 * 128 * 4 * 2 + 256 * 256 * 4
             + 128 * 64 * 4),
        "moe_gmm(BC=BF=BD=512)": 3 * 512 * 512 * 4,
        "conv1d(L=3750,Cin=32,BCO=128)":
            (3750 * 32 * 4 * 2 + 32 * 128 * 4 + 3750 * 128 * 4),
    }
    for name, bytes_ in budgets.items():
        rows.append({"name": f"vmem_budget:{name}",
                     "us_per_call": 0.0,
                     "derived": f"{bytes_/2**20:.2f}MiB of 16MiB VMEM"})
    return rows


def write_json(rows: List[Dict], path: str) -> None:
    """Rows only — kernel wall times are absolute (machine-dependent), so
    there is no gate summary; the json exists for trajectory tracking."""
    with open(path, "w") as f:
        json.dump({"bench": "kernels", "rows": rows}, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH",
                    help="write rows as JSON (e.g. BENCH_kernels.json)")
    args = ap.parse_args()
    rows = run(log=lambda *a: print(*a, file=sys.stderr))
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
