"""Table II reproduction: domain-optimized deployments.

Paper §VI-B: low-power (Pynq-Z1 @ 0.5 MHz, fully folded), low-energy
(Ultra96-class, max alpha) and high-throughput (ZCU102-class, max alpha,
larger batch/more instances) implementations of the NAS winners, plus the
embedded-GPU comparison point.

The platform profiles are the calibrated HardwareProfile set; the Jetson
row is reproduced from the paper's published measurements (we cannot run
TensorRT here) and is clearly marked as reference data.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.hw_model import (
    FPGA_PYNQ,
    FPGA_ZCU102,
    FPGA_ZU,
    estimate,
)
from repro.data.ecg import make_ecg_dataset, train_val_split

JETSON_REFERENCE = {
    "device": "Jetson AGX (paper Table II, reference)",
    "freq_mhz": 1377.0,
    "batch": 1024,
    "throughput_sps": 7.7e4,
    "p_total_w": 21.1,
    "e_total_j": 2.7e-4,
}


def run(generations: int = 4, samples: int = 320, train_steps: int = 100,
        seed: int = 0, log=print) -> List[Dict]:
    x, y = make_ecg_dataset(seed=seed, n_samples=samples, decimation=16)
    tr, va = train_val_split(x, y)

    cfg = NASConfig(generations=generations, children_per_gen=6, n_accept=3,
                    init_population=5, train_steps=train_steps,
                    train_batch=32, n_workers=2, seed=seed,
                    det_min=0.7, fa_max=0.3)
    search = EvolutionarySearch(cfg, tr, va, log=lambda *_: None)
    state = search.run()
    low_p = search.select_solution(state, "power_min_alpha_w") \
        or state.population[0]
    low_e = search.select_solution(state, "energy_max_alpha_j") \
        or state.population[0]
    # paper: the low-energy and high-throughput winners are the same model

    rows = []
    for device, profile, sol, strat, batch in (
            ("Pynq-Z1-class (low power)", FPGA_PYNQ, low_p, "min", 1),
            ("Ultra96-class (low energy)", FPGA_ZU, low_e, "max", 4),
            ("ZCU102-class (high throughput)", FPGA_ZCU102, low_e, "max",
             16),
    ):
        est = estimate(sol.genome, strategy=strat, profile=profile)
        rows.append({
            "device": device,
            "freq_mhz": profile.f_clk / 1e6,
            "batch": batch,
            "throughput_sps": est.throughput_sps * batch,
            "p_total_w": est.p_total_w * (1 + 0.08 * (batch - 1)),
            "e_total_j": (est.p_total_w * (1 + 0.08 * (batch - 1)))
            / (est.throughput_sps * batch),
        })
    rows.append(dict(JETSON_REFERENCE))
    return rows


def validate(rows: List[Dict]) -> Dict[str, bool]:
    by = {r["device"].split(" (")[0]: r for r in rows}
    claims = {}
    claims["lowpower_platform_has_lowest_power"] = (
        by["Pynq-Z1-class"]["p_total_w"]
        == min(r["p_total_w"] for r in rows))
    claims["zcu102_has_highest_throughput"] = (
        by["ZCU102-class"]["throughput_sps"]
        == max(r["throughput_sps"] for r in rows))
    claims["fpga_beats_jetson_energy"] = (
        min(by["Ultra96-class"]["e_total_j"],
            by["ZCU102-class"]["e_total_j"])
        < JETSON_REFERENCE["e_total_j"])
    claims["fpga_beats_jetson_throughput"] = (
        by["ZCU102-class"]["throughput_sps"]
        > JETSON_REFERENCE["throughput_sps"])
    return claims
