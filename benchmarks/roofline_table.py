"""Roofline table generator: formats the dry-run JSONL into the §Roofline
markdown table (one row per arch x shape x mesh) with dominant terms and
what-would-move-it-down notes."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

MOVE_NOTES = {
    ("memory", "train"): "shard the remat residual stack (sequence "
                         "parallelism) / larger microbatch count",
    ("memory", "prefill"): "fuse attention chunks (Pallas flash) to cut "
                           "score-tensor round-trips",
    ("memory", "decode"): "KV-cache layout: batch-major blocks so the "
                          "per-token gather is contiguous",
    ("collective", "train"): "shard_map MoE dispatch (all-to-all instead of "
                             "gather/scatter), bf16 TP all-reduces",
    ("collective", "prefill"): "sequence-shard KV; overlap all-gather with "
                               "per-layer compute",
    ("collective", "decode"): "replicate small weights; batch KV updates",
    ("compute", "train"): "cut attention recompute (custom-vjp flash), "
                          "skip fully-masked causal chunks",
    ("compute", "prefill"): "skip fully-masked causal chunks",
    ("compute", "decode"): "already compute-lean; raise batch",
}


def load(mesh: str) -> List[Dict]:
    # prefer the final (post-§Perf) sweep; fall back to the baseline sweep
    for name in (f"final_{mesh}.jsonl", f"dryrun_{mesh}.jsonl"):
        path = os.path.join(RESULTS, name)
        if os.path.exists(path):
            return [json.loads(l) for l in open(path)]
    return []


def run(log=print) -> List[Dict]:
    rows = []
    for mesh in ("single", "multi"):
        for r in load(mesh):
            if r["note"].startswith("SKIPPED"):
                rows.append({"name": f"roofline:{r['arch']}:{r['shape']}:"
                             f"{r['mesh']}", "us_per_call": 0.0,
                             "derived": "SKIP(long-context rule)"})
                continue
            if not r["ok"]:
                rows.append({"name": f"roofline:{r['arch']}:{r['shape']}:"
                             f"{r['mesh']}", "us_per_call": 0.0,
                             "derived": "FAILED"})
                continue
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            frac = r["compute_s"] / bound if bound else 0.0
            rows.append({
                "name": f"roofline:{r['arch']}:{r['shape']}:{r['mesh']}",
                "us_per_call": bound * 1e6,
                "derived": (f"dom={r['dominant']} frac={frac:.3f} "
                            f"useful={r['useful_fraction']:.3f} "
                            f"peak={r['peak_bytes']/2**30:.1f}GiB"),
            })
    return rows


def markdown_table(mesh: str) -> str:
    rows = load(mesh)
    out = ["| arch | shape | compute_s | memory_s (UB) | memory_s (LB) | "
           "collective_s | dominant | roofline frac | useful (6ND/HLO) | "
           "peak GiB/dev | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["note"].startswith("SKIPPED"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skip | — | — | — | {r['note'][9:90]} |")
            continue
        if not r["ok"]:
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | "
                       f"| | |")
            continue
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / bound if bound else 0.0
        note = MOVE_NOTES.get((r["dominant"], r["kind"]), "")
        mem_lb = r.get("bytes_dev_min", 0.0) / 819e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {mem_lb:.4f} | "
            f"{r['collective_s']:.4f} | "
            f"{r['dominant']} | {frac:.3f} | {r['useful_fraction']:.3f} | "
            f"{r['peak_bytes']/2**30:.2f} | {note} |")
    return "\n".join(out)


def write_markdown(log=print) -> None:
    for mesh in ("single", "multi"):
        if not load(mesh):
            continue
        path = os.path.join(RESULTS, f"roofline_{mesh}.md")
        with open(path, "w") as f:
            f.write(f"# §Roofline — {mesh}-pod mesh\n\n"
                    "memory UB = fusion-boundary upper bound; LB = "
                    "ideal-fusion lower bound (EXPERIMENTS.md).\n\n")
            f.write(markdown_table(mesh) + "\n")
        log(f"wrote {path}")
