"""Table I reproduction: NAS objective x implementation strategy.

Paper §VI-A: models searched for (low E, max alpha), (low E, min alpha) and
(low P, min alpha), each implemented with min- and max-alpha strategies; the
best number in each column must be the candidate whose NAS objective matches
the implementation strategy — the cross-layer claim.

The NAS runs are seeded and small (CPU box); the hardware numbers come from
the paper's Eqs. 1-4 with the FPGA_ZU calibration profile.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.genome import describe
from repro.core.hw_model import FPGA_ZU, estimate
from repro.core.objectives import CHEAP_NAMES
from repro.data.ecg import make_ecg_dataset, train_val_split


def run(generations: int = 4, samples: int = 320, train_steps: int = 100,
        seed: int = 0, log=print) -> List[Dict]:
    x, y = make_ecg_dataset(seed=seed, n_samples=samples, decimation=16)
    tr, va = train_val_split(x, y)

    # one search per NAS objective (the paper runs separate experiments)
    objectives = {
        "low_E_max_alpha": "energy_max_alpha_j",
        "low_E_min_alpha": "energy_min_alpha_j",
        "low_P_min_alpha": "power_min_alpha_w",
    }
    rows = []
    solutions = {}
    for tag, obj in objectives.items():
        cfg = NASConfig(generations=generations, children_per_gen=6,
                        n_accept=3, init_population=5,
                        train_steps=train_steps, train_batch=32,
                        n_workers=2, seed=seed, det_min=0.7, fa_max=0.3)
        search = EvolutionarySearch(cfg, tr, va, log=lambda *_: None)
        state = search.run()
        sol = search.select_solution(state, obj)
        if sol is None:  # fall back to best cheap value in population
            idx = CHEAP_NAMES.index(obj)
            sol = min(state.population, key=lambda c: c.cheap[idx])
        solutions[tag] = sol
        log(f"[table1] {tag}: depth={sol.genome.depth()} "
            f"params={int(sol.cheap[6])}")

    for impl in ("min", "max"):
        for tag, sol in solutions.items():
            est = estimate(sol.genome, strategy=impl, profile=FPGA_ZU)
            rows.append({
                "nas_objective": tag,
                "impl_strategy": f"{impl}_alpha",
                "throughput_sps": est.throughput_sps,
                "p_total_w": est.p_total_w,
                "e_total_uj": est.e_total_j * 1e6,
                "params": est.params,
                "depth": sol.genome.depth(),
            })
    return rows


def validate(rows: List[Dict]) -> Dict[str, bool]:
    """The paper's qualitative claims on Table I."""
    by = {(r["nas_objective"], r["impl_strategy"]): r for r in rows}
    claims = {}
    # claim 1: with min-alpha impl, the low-E/min-alpha model has the best
    # (lowest) energy among the three
    e_min = {t: by[(t, "min_alpha")]["e_total_uj"] for t, _ in
             [(r["nas_objective"], 0) for r in rows]}
    claims["minalpha_energy_winner_is_lowE_minalpha"] = (
        min(e_min, key=e_min.get) == "low_E_min_alpha")
    # claim 2: with min-alpha impl, the low-P model has the lowest power
    p_min = {t: by[(t, "min_alpha")]["p_total_w"] for t in e_min}
    claims["minalpha_power_winner_is_lowP"] = (
        min(p_min, key=p_min.get) == "low_P_min_alpha")
    # claim 3: with max-alpha impl, the low-E/max-alpha model has the best
    # energy
    e_max = {t: by[(t, "max_alpha")]["e_total_uj"] for t in e_min}
    claims["maxalpha_energy_winner_is_lowE_maxalpha"] = (
        min(e_max, key=e_max.get) == "low_E_max_alpha")
    # claim 4: unrolling raises power but cuts energy (for energy-searched)
    claims["unroll_raises_power_cuts_energy"] = (
        by[("low_E_max_alpha", "max_alpha")]["p_total_w"]
        > by[("low_E_max_alpha", "min_alpha")]["p_total_w"]
        and by[("low_E_max_alpha", "max_alpha")]["e_total_uj"]
        < by[("low_E_max_alpha", "min_alpha")]["e_total_uj"])
    return claims
