"""Multi-platform scoring throughput: one shared-context composite pass vs
K sequential single-backend passes.

``MultiPlatformBackend`` decodes/tabulates the population once and shares
the platform-independent Eq. 1-4 intermediates (fully-folded latency
recursion, α event table — DESIGN.md §10) across its members, so scoring K
platforms should cost far less than K independent ``evaluate_batch`` calls.
This bench measures genomes/sec at K = 1, 2, 4 backends and reports the
speedup of the composite over the sequential baseline, parity-gated: the
composite's column blocks must be bit-identical to each member evaluated
alone before any timing is trusted.

Acceptance target: >= 2x at K=4 (shared decode/tabulation + shared α event
table; the marginal per-platform cost is just the profile-specific
arithmetic).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.cost_backend import MultiPlatformBackend, get_backend
from repro.core.genome import PopulationEncoding, random_genome
from repro.core.search_space import DEFAULT_SPACE

SMOKE_POP, FULL_POP = 2048, 4096
REPEATS = 7
# member order: the two paper FPGA domains first, then the low-power FPGA
# and the TPU roofline — K=1/2/4 are prefixes of this list
MEMBERS = ("fpga_zu", "fpga_zcu102", "fpga_pynq", "tpu_roofline")
K_SWEEP = (1, 2, 4)
TARGET_AT_4 = 2.0


def _time(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(log=print, smoke: bool = True) -> List[Dict]:
    pop = SMOKE_POP if smoke else FULL_POP
    rng = np.random.default_rng(0)
    log(f"[multi_platform] sampling {pop} genomes ...")
    enc = PopulationEncoding.from_genomes(
        [random_genome(rng, DEFAULT_SPACE) for _ in range(pop)])

    rows: List[Dict] = []
    for k in K_SWEEP:
        members = MEMBERS[:k]
        singles = [get_backend(m) for m in members]
        multi = MultiPlatformBackend(members)

        # parity gate: composite blocks == members evaluated alone
        combined = multi.evaluate_batch(enc, space=DEFAULT_SPACE)
        for i, be in enumerate(singles):
            alone = be.evaluate_batch(enc, space=DEFAULT_SPACE)
            assert np.array_equal(combined[:, i * 7:(i + 1) * 7], alone), \
                f"parity failure for {members[i]}"

        def seq():
            for be in singles:
                be.evaluate_batch(enc, space=DEFAULT_SPACE)

        def shared():
            multi.evaluate_batch(enc, space=DEFAULT_SPACE)

        seq()      # warm-up both paths
        shared()
        # paired measurements so machine-state drift cancels in the ratio
        t_seq, t_multi, ratios = [], [], []
        for _ in range(REPEATS):
            ts = _time(seq)
            tm = _time(shared)
            t_seq.append(ts)
            t_multi.append(tm)
            ratios.append(ts / tm)
        tm = float(np.median(t_multi))
        ts = float(np.median(t_seq))
        speedup = float(np.median(ratios))
        gps = pop * k / tm          # platform-scorings per second
        log(f"[multi_platform] K={k} pop={pop}: shared {tm*1e3:.1f}ms "
            f"({gps:,.0f} genome-platforms/s), sequential {ts*1e3:.1f}ms, "
            f"speedup {speedup:.2f}x")
        rows.append({
            "name": f"multi_platform_k{k}_{pop}",
            "us_per_call": tm * 1e6,
            "derived": f"{gps:.0f}gp/s speedup={speedup:.2f}x",
            "k": k, "pop": pop, "speedup": speedup,
            "t_shared_s": tm, "t_sequential_s": ts,
        })

    at4 = next((r for r in rows if r["k"] == 4), None)
    if at4 is not None:
        ok = at4["speedup"] >= TARGET_AT_4
        log(f"[multi_platform] target >= {TARGET_AT_4}x at K=4: "
            f"{'OK' if ok else 'MISS'} ({at4['speedup']:.2f}x)")
        rows.append({"name": "multi_platform_target_2x_at_k4",
                     "us_per_call": 0.0, "derived": str(ok)})
    return rows


def write_json(rows: List[Dict], path: str) -> None:
    """The machine-readable result format (single writer — run.py and the
    CLI below both route through this)."""
    with open(path, "w") as f:
        json.dump({"bench": "multi_platform", "rows": rows}, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help=f"population {FULL_POP} (default: smoke, "
                         f"{SMOKE_POP})")
    ap.add_argument("--smoke", action="store_true",
                    help="explicit smoke mode (the default; kept for CI "
                         "command-line clarity)")
    ap.add_argument("--json", metavar="PATH",
                    help="write machine-readable results here")
    args = ap.parse_args()
    rows = run(smoke=not args.full)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
