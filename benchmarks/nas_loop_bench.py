"""Generation-step throughput: scalar (pre-SoA) loop vs the array-resident
evolutionary loop, training stubbed.

The scalar reference replicates the pre-refactor generation step exactly:
per-genome `mutate`/`crossover`/`is_valid`/`phenotype_hash` calls,
list-of-`Candidate` bookkeeping, the duplicated `cheap_matrix(population)`
recompute, and the O(N²) pure-Python `non_dominated_sort_reference`.  The
vectorized side is `EvolutionarySearch.step` over the struct-of-arrays
state (DESIGN.md §8).  Both start from the same materialized population, so
the measured ratio is the whole generation step's speedup.

Also asserts the cheap-objective call-count regression: one
`CostBackend.evaluate_batch` call per vectorized step (children only — the
population matrix is cached on the SoA state, never recomputed).

Acceptance target: >= 20x at population_cap=4096 (``--full``; the default
smoke size keeps CI fast).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core import selection as sel
from repro.core.evolution import EvolutionarySearch, NASConfig, NASState
from repro.core.genome import crossover, mutate
from repro.core.objectives import (
    Candidate,
    cheap_matrix,
    cheap_objectives_batch,
    objective_matrix,
)
from repro.core.pareto import crowding_distance, non_dominated_sort_reference
from repro.core.search_space import DEFAULT_SPACE
from repro.core.trainer import TrainResult

SMOKE_POP, FULL_POP = 256, 4096
N_ACCEPT = 8
VEC_REPEATS = 3

STUB_EXPENSIVE = np.asarray([0.05, 0.08])


def _stub_train(_g) -> TrainResult:
    return TrainResult(detection_rate=1.0 - STUB_EXPENSIVE[0],
                       false_alarm_rate=STUB_EXPENSIVE[1],
                       val_loss=0.1, steps=0)


class _CountingBackend:
    """Wraps a CostBackend, counting evaluate_batch calls (the regression
    assertion on the step's cheap-objective call count)."""

    def __init__(self, inner):
        self.inner = inner
        self.name = f"counting[{inner.name}]"
        self.calls = 0

    def evaluate_batch(self, enc, *, space=DEFAULT_SPACE):
        self.calls += 1
        return self.inner.evaluate_batch(enc, space=space)

    def evaluate(self, g, *, space=DEFAULT_SPACE):
        return self.inner.evaluate(g, space=space)


def _make_search(n: int) -> EvolutionarySearch:
    cfg = NASConfig(children_per_gen=max(32, n // 32), n_accept=N_ACCEPT,
                    population_cap=n, init_population=n, n_workers=2, seed=0)
    s = EvolutionarySearch(cfg, None, None, train_fn=_stub_train,
                           log=lambda *_: None)
    s.backend = _CountingBackend(s.backend)
    return s


def _make_state(search: EvolutionarySearch, n: int) -> NASState:
    """A capacity-sized, fully 'trained' population (no init-train pass)."""
    enc, hashes = search._sample_unique(n)
    pop = search._score(enc, hashes, generation=0)
    rng = np.random.default_rng(1234)  # spread-out expensive objectives so
    pop.expensive = np.stack([rng.uniform(0.0, 0.3, n),     # selection has
                              rng.uniform(0.0, 0.3, n)], axis=1)  # real work
    cache = {str(h): pop.expensive[i] for i, h in enumerate(pop.phash)}
    return NASState(pop=pop, generation=0, evaluated_hashes=cache,
                    history=[])


# ---------------------------------------------------------------------------
# The pre-refactor scalar generation step (executable reference)
# ---------------------------------------------------------------------------

def _environmental_selection_ref(points: np.ndarray, capacity: int
                                 ) -> np.ndarray:
    keep: List[int] = []
    for front in non_dominated_sort_reference(points):
        if len(keep) + len(front) <= capacity:
            keep.extend(front.tolist())
        else:
            need = capacity - len(keep)
            cd = crowding_distance(points[front])
            order = np.argsort(-cd, kind="stable")
            keep.extend(front[order[:need]].tolist())
            break
    return np.asarray(sorted(keep), dtype=np.int64)


def _scalar_step(population: List[Candidate], cfg: NASConfig,
                 rng: np.random.Generator, backend,
                 evaluated: Dict[str, np.ndarray]) -> List[Candidate]:
    space = DEFAULT_SPACE
    # ---- _make_children (per-genome operators, duplicated cheap_matrix)
    cheap = cheap_matrix(population)
    parents_idx = sel.sample_parents(rng, cheap, cfg.children_per_gen)
    child_genomes, child_hashes = [], []
    seen = {c.phash for c in population}
    for pi in parents_idx:
        parent = population[pi]
        if rng.random() < cfg.crossover_prob and len(population) > 1:
            mate = population[int(rng.integers(0, len(population)))]
            g = crossover(parent.genome, mate.genome, rng, space)
            g = mutate(g, rng, space, rate=cfg.mutation_rate,
                       force_active_change=False)
        else:
            g = mutate(parent.genome, rng, space, rate=cfg.mutation_rate,
                       force_active_change=True)
        if not g.is_valid(space):
            continue
        h = g.phenotype_hash(space)
        if h in seen:
            continue
        seen.add(h)
        child_genomes.append(g)
        child_hashes.append(h)
    children: List[Candidate] = []
    if child_genomes:
        child_cheap = cheap_objectives_batch(child_genomes, backend=backend,
                                             space=space)
        children = [Candidate(genome=g, cheap=child_cheap[i], phash=h,
                              generation=1)
                    for i, (g, h) in enumerate(zip(child_genomes,
                                                   child_hashes))]
    # ---- step body
    if children:
        pop_cheap = cheap_matrix(population)  # the pre-PR recompute
        acc_idx = sel.preselect_children(rng, pop_cheap,
                                         cheap_matrix(children), cfg.n_accept)
        accepted = [children[i] for i in acc_idx]
        for c in accepted:  # training stubbed
            c.expensive = evaluated.setdefault(c.phash, STUB_EXPENSIVE)
    else:
        accepted = []
    merged = population + accepted
    keep = _environmental_selection_ref(objective_matrix(merged),
                                        cfg.population_cap)
    return [merged[i] for i in keep]


# ---------------------------------------------------------------------------

def run(log=print, smoke: bool = True) -> List[Dict]:
    n = SMOKE_POP if smoke else FULL_POP
    search = _make_search(n)
    log(f"[nas_loop] building population n={n} ...")
    state = _make_state(search, n)
    scalar_pop = state.pop.to_candidates()
    scalar_cache = dict(state.evaluated_hashes)

    # ---- vectorized generation steps (median of successive steps)
    search.step(state)  # warm-up
    t_vec, children_seen = [], []
    for _ in range(VEC_REPEATS):
        search.backend.calls = 0
        t0 = time.perf_counter()
        search.step(state)
        t_vec.append(time.perf_counter() - t0)
        children_seen.append(state.history[-1]["children"])
        assert search.backend.calls == 1, (
            f"regression: expected exactly 1 cheap-objective batch call per "
            f"step (children only; the population matrix is cached on the "
            f"SoA state), got {search.backend.calls}")
    t_vectorized = float(np.median(t_vec))

    # ---- the pre-refactor scalar step, from the same starting population
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    _scalar_step(scalar_pop, search.cfg, rng, search.backend.inner,
                 scalar_cache)
    t_scalar = time.perf_counter() - t0

    speedup = t_scalar / t_vectorized
    log(f"[nas_loop] n={n}: vectorized {t_vectorized * 1e3:.1f}ms/step, "
        f"scalar {t_scalar * 1e3:.1f}ms/step, speedup {speedup:.1f}x "
        f"(children/step ~{int(np.median(children_seen))})")
    # per-phase wall-time split of the last step (recorded by the search
    # itself, DESIGN.md §11) — the observability surface the overlap
    # pipeline is tuned against
    split = state.history[-1]["timings"]
    split_row = {
        "name": f"nas_step_timings_{n}",
        "us_per_call": sum(split.values()) * 1e6,
        "derived": " ".join(f"{k}={v * 1e3:.2f}ms"
                            for k, v in split.items()),
    }
    log(f"[nas_loop] step split: {split_row['derived']}")
    return [
        split_row,
        {"name": f"nas_step_vectorized_{n}",
         "us_per_call": t_vectorized * 1e6,
         "derived": f"speedup={speedup:.1f}x "
                    f"children={int(np.median(children_seen))} "
                    f"cheap_evals_per_step=1"},
        {"name": f"nas_step_scalar_{n}",
         "us_per_call": t_scalar * 1e6,
         "derived": "pre-SoA reference loop"},
    ]


def write_json(rows: List[Dict], path: str) -> None:
    """The machine-readable result format (single writer — run.py and the
    CLI below both route through this)."""
    with open(path, "w") as f:
        json.dump({"bench": "nas_loop", "rows": rows}, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help=f"population_cap={FULL_POP} (default: "
                         f"smoke, {SMOKE_POP})")
    ap.add_argument("--smoke", action="store_true",
                    help="explicit smoke mode (the default; kept for CI "
                         "command-line clarity)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as machine-readable JSON")
    args = ap.parse_args()
    rows = run(smoke=not args.full)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, args.json)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
