"""Serving bench: continuous-batching engine vs the wave-barrier baseline.

Workload: N concurrent requests with mixed prompt lengths (4–24) and
output budgets (4–24) over the reduced qwen2-0.5b zoo config.  Three runs:

1. **scalar reference** — every request decoded alone through the scalar
   path (``greedy_reference``); its tokens are the bit-parity oracle and
   its per-request wall time is the unloaded "ideal" latency;
2. **wave baseline** — :class:`repro.launch.serve.BatchedServer` at its
   shipped 4 slots: admission only between waves, every slot waits for the
   wave's slowest request;
3. **engine** — :class:`repro.serve.ServeEngine` at ``--slots`` slots:
   continuous admission, padding-bucketed prefill, one jitted decode step
   over all slots.  Run once as a burst (throughput, the speedup gate) and
   once under an open-loop Poisson arrival schedule (p50/p99 latency —
   arrivals don't wait for the server, so queueing delay is *in* the
   number).

Gate summary (checked by benchmarks/check_thresholds.py): greedy tokens of
both servers must match the scalar reference bit for bit, engine tok/s ≥
3x the wave baseline, and the Poisson p99 latency must stay within a
bounded multiple of the unloaded ideal (a relative threshold — absolute
times vary across runners, ratios don't).

The ``--paged`` section (on by default) benchmarks the paged KV cache
(DESIGN.md §15) at *equal KV memory*: a dense engine with
``DENSE_SLOTS`` worst-case slots vs a paged engine whose block pool
holds exactly the same number of cache tokens
(``DENSE_SLOTS * CACHE_LEN / PAGED_BLOCK`` blocks) but admits on actual
block demand.  On a long-tail prompt-length burst the paged engine must
reach ≥ 2x the dense peak concurrency (the admission-capacity gate),
with every admitted request bit-identical to the scalar reference and
every OOM shed explicit (``shed_blocks``), plus a bounded p99 under
open-loop long-tail load.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import reduced_config
from repro.launch.serve import BatchedServer
from repro.models.registry import build_model
from repro.serve import (
    EngineConfig,
    ServeEngine,
    ServeRequest,
    greedy_reference,
    latency_stats,
    longtail_workload,
    poisson_workload,
)

ARCH = "qwen2-0.5b"
CACHE_LEN = 64
WAVE_SLOTS = 4          # the shipped BatchedServer default — the baseline
PROMPT_LENS = (4, 8, 12, 16, 24)
OUT_LENS = (4, 8, 12, 16, 24)

# paged-vs-dense comparison at equal KV memory (DESIGN.md §15): the dense
# engine reserves DENSE_SLOTS * CACHE_LEN cache tokens up front; the paged
# pool holds exactly as many tokens in PAGED_BLOCK-sized blocks but can
# spread them over up to PAGED_SLOTS concurrent sequences
DENSE_SLOTS = 4
PAGED_BLOCK = 8
PAGED_SLOTS = 16
PAGED_BLOCKS = DENSE_SLOTS * CACHE_LEN // PAGED_BLOCK   # same token count


def _fresh(reqs: List[ServeRequest]) -> List[ServeRequest]:
    return [ServeRequest(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                         arrival_s=r.arrival_s) for r in reqs]


def _run_paged(bundle, params, cfg, dec, log, n_requests: int,
               rate_per_s: float, seed: int) -> Tuple[List[Dict], Dict]:
    """Paged-vs-dense admission capacity at equal KV memory on a
    long-tail prompt-length mix (the workload dense worst-case slots are
    worst at).  Returns extra rows + summary keys (``paged_*``)."""
    lt = longtail_workload(n_requests, vocab_size=cfg.vocab_size,
                           rate_per_s=0.0, median_prompt=6, sigma=0.8,
                           max_prompt=CACHE_LEN - 16,
                           out_lens=(4, 8, 12, 16), seed=seed + 1)
    log(f"[serve] paged workload: {n_requests} long-tail requests, "
        f"prompts {min(len(r.prompt) for r in lt)}-"
        f"{max(len(r.prompt) for r in lt)}")

    # scalar reference: parity oracle + unloaded ideal for this mix
    for r in lt:   # warm the per-prompt-length prefill compiles
        greedy_reference(bundle, params, r.prompt, 1, CACHE_LEN,
                         decode_jit=dec)
    ref: Dict[int, List[int]] = {}
    ideal: List[float] = []
    for r in lt:
        t = time.perf_counter()
        ref[r.rid] = greedy_reference(bundle, params, r.prompt, r.max_new,
                                      CACHE_LEN, decode_jit=dec)
        ideal.append(time.perf_counter() - t)
    ideal_mean = float(np.mean(ideal))

    # dense engine at the equal-memory slot count
    dense = ServeEngine(bundle, params, EngineConfig(
        slots=DENSE_SLOTS, cache_len=CACHE_LEN, pad_to=8,
        max_prefill_batch=8))
    dense.run(_fresh(lt))              # warm
    t0 = time.perf_counter()
    dense_done = dense.run(_fresh(lt))
    t_dense = time.perf_counter() - t0
    dense_stats = dense.stats()
    dense_tokens = sum(len(r.out) for r in dense_done)
    dense_parity = all(r.out == ref[r.rid] for r in dense_done)
    log(f"[serve] dense equal-mem ({DENSE_SLOTS} slots x {CACHE_LEN}): "
        f"{dense_tokens / t_dense:.1f} tok/s, "
        f"peak_concurrency={dense_stats['peak_concurrency']}, "
        f"parity={dense_parity}")

    # paged engine: same cache tokens, block-granular admission
    paged = ServeEngine(bundle, params, EngineConfig(
        slots=PAGED_SLOTS, cache_len=CACHE_LEN, pad_to=8,
        max_prefill_batch=8, paged=True, block_size=PAGED_BLOCK,
        n_blocks=PAGED_BLOCKS))
    paged.run(_fresh(lt))              # warm
    t0 = time.perf_counter()
    paged_done = paged.run(_fresh(lt))
    t_paged = time.perf_counter() - t0
    paged_stats = paged.stats()
    paged_tokens = sum(len(r.out) for r in paged_done)
    served = [r for r in paged_done if not r.oom]
    # every request comes back exactly once (zero silent drops); OOM sheds
    # are explicit and their prefix must still match the reference
    paged_parity = (len(paged_done) == len(lt)
                    and all(r.out == ref[r.rid] for r in served)
                    and all(r.out == ref[r.rid][:len(r.out)]
                            for r in paged_done if r.oom))
    ratio = (paged_stats["peak_concurrency"]
             / max(dense_stats["peak_concurrency"], 1))
    log(f"[serve] paged ({PAGED_BLOCKS} blocks x {PAGED_BLOCK}, "
        f"{PAGED_SLOTS} slots): {paged_tokens / t_paged:.1f} tok/s, "
        f"peak_concurrency={paged_stats['peak_concurrency']} "
        f"({ratio:.2f}x dense), shed_blocks={paged_stats['shed_blocks']}, "
        f"peak_blocks={paged_stats['peak_blocks_used']}/{PAGED_BLOCKS}, "
        f"parity={paged_parity}")

    # open-loop long-tail latency through the paged engine
    lt_open = longtail_workload(n_requests, vocab_size=cfg.vocab_size,
                                rate_per_s=rate_per_s, median_prompt=6,
                                sigma=0.8, max_prompt=CACHE_LEN - 16,
                                out_lens=(4, 8, 12, 16), seed=seed + 1)
    open_done = paged.run(_fresh(lt_open), realtime=True)
    ostats = latency_stats([r for r in open_done if not r.oom],
                           makespan_s=max(r.t_done for r in open_done))
    p99_slowdown = ostats["p99_latency_s"] / ideal_mean if ideal_mean \
        else 0.0
    log(f"[serve] paged open-loop (rate={rate_per_s}/s): "
        f"p50={ostats['p50_latency_s'] * 1e3:.1f}ms "
        f"p99={ostats['p99_latency_s'] * 1e3:.1f}ms "
        f"({p99_slowdown:.1f}x unloaded ideal)")

    rows = [
        {"name": f"serve_dense_equalmem_{DENSE_SLOTS}slots",
         "us_per_call": t_dense * 1e6 / max(dense_tokens, 1),
         "derived": f"tok_per_s={dense_tokens / t_dense:.1f} "
                    f"peak_concurrency={dense_stats['peak_concurrency']} "
                    f"parity={dense_parity}"},
        {"name": f"serve_paged_{PAGED_BLOCKS}blocks",
         "us_per_call": t_paged * 1e6 / max(paged_tokens, 1),
         "derived": f"tok_per_s={paged_tokens / t_paged:.1f} "
                    f"peak_concurrency={paged_stats['peak_concurrency']} "
                    f"ratio={ratio:.2f}x "
                    f"shed_blocks={paged_stats['shed_blocks']} "
                    f"parity={paged_parity}"},
        {"name": "serve_paged_longtail_open",
         "us_per_call": ostats["p99_latency_s"] * 1e6,
         "derived": f"p50_ms={ostats['p50_latency_s'] * 1e3:.1f} "
                    f"p99_ms={ostats['p99_latency_s'] * 1e3:.1f} "
                    f"p99_slowdown={p99_slowdown:.1f}x"},
    ]
    summary = {
        "paged_parity_ok": bool(paged_parity and dense_parity),
        "paged_concurrency_ratio": float(ratio),
        "paged_peak_concurrency": int(paged_stats["peak_concurrency"]),
        "dense_peak_concurrency": int(dense_stats["peak_concurrency"]),
        "paged_shed_blocks": int(paged_stats["shed_blocks"]),
        "paged_peak_blocks_used": int(paged_stats["peak_blocks_used"]),
        "paged_p99_slowdown_vs_ideal": float(p99_slowdown),
        "paged_block_size": PAGED_BLOCK,
        "paged_n_blocks": PAGED_BLOCKS,
    }
    return rows, summary


def run(log=print, smoke: bool = True, n_requests: int = 32,
        slots: int = 32, rate_per_s: float = 60.0,
        seed: int = 0, paged: bool = True) -> Tuple[List[Dict], Dict]:
    cfg = reduced_config(ARCH)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    reqs = poisson_workload(n_requests, vocab_size=cfg.vocab_size,
                            rate_per_s=rate_per_s,
                            prompt_lens=PROMPT_LENS, out_lens=OUT_LENS,
                            seed=seed)
    total_budget = sum(r.max_new for r in reqs)
    log(f"[serve] workload: {n_requests} requests, {total_budget} token "
        f"budget, prompts {min(len(r.prompt) for r in reqs)}-"
        f"{max(len(r.prompt) for r in reqs)}")

    # -- scalar reference: parity oracle + unloaded ideal latency ---------
    dec = jax.jit(bundle.decode_step)
    ref_tokens: Dict[int, List[int]] = {}
    for r in reqs:   # warm the per-prompt-length prefill compiles
        greedy_reference(bundle, params, r.prompt, 1, CACHE_LEN,
                         decode_jit=dec)
    ideal: Dict[int, float] = {}
    t0 = time.perf_counter()
    for r in reqs:
        t = time.perf_counter()
        ref_tokens[r.rid] = greedy_reference(bundle, params, r.prompt,
                                             r.max_new, CACHE_LEN,
                                             decode_jit=dec)
        ideal[r.rid] = time.perf_counter() - t
    t_scalar = time.perf_counter() - t0
    ideal_mean = float(np.mean(list(ideal.values())))
    log(f"[serve] scalar reference: {total_budget / t_scalar:.1f} tok/s, "
        f"ideal latency {ideal_mean * 1e3:.1f} ms/request")

    # -- wave-barrier baseline (shipped defaults) -------------------------
    wave = BatchedServer(bundle, params, slots=WAVE_SLOTS,
                         cache_len=CACHE_LEN)
    wave.run(_fresh(reqs)[:WAVE_SLOTS], log=lambda *_: None)  # warm
    wave_reqs = _fresh(reqs)
    t0 = time.perf_counter()
    wave_done = wave.run(wave_reqs, log=lambda *_: None)
    t_wave = time.perf_counter() - t0
    wave_tokens = sum(len(r.out) for r in wave_done)
    tok_s_wave = wave_tokens / t_wave
    wave_parity = all(r.out == ref_tokens[r.rid] for r in wave_done)
    log(f"[serve] wave baseline ({WAVE_SLOTS} slots): {tok_s_wave:.1f} "
        f"tok/s, parity={wave_parity}")

    # -- engine: burst throughput -----------------------------------------
    engine = ServeEngine(bundle, params, EngineConfig(
        slots=slots, cache_len=CACHE_LEN, pad_to=8, max_prefill_batch=8))
    burst = _fresh(reqs)
    for r in burst:
        r.arrival_s = 0.0
    engine.run(_fresh(burst))          # warm (compile all buckets)
    t0 = time.perf_counter()
    burst_done = engine.run(burst)
    t_burst = time.perf_counter() - t0
    burst_tokens = sum(len(r.out) for r in burst_done)
    tok_s_engine = burst_tokens / t_burst
    engine_parity = all(r.out == ref_tokens[r.rid] for r in burst_done)
    speedup = tok_s_engine / tok_s_wave
    log(f"[serve] engine burst ({slots} slots): {tok_s_engine:.1f} tok/s "
        f"({speedup:.2f}x wave), parity={engine_parity}, "
        f"{engine.prefill_calls} prefill dispatches, "
        f"{engine.decode_steps} decode steps")

    # -- engine: open-loop Poisson latency --------------------------------
    poisson_done = engine.run(_fresh(reqs), realtime=True)
    stats = latency_stats(poisson_done,
                          makespan_s=max(r.t_done for r in poisson_done))
    poisson_parity = all(r.out == ref_tokens[r.rid] for r in poisson_done)
    p99_slowdown = stats["p99_latency_s"] / ideal_mean if ideal_mean else 0.0
    log(f"[serve] engine poisson (rate={rate_per_s}/s): "
        f"p50={stats['p50_latency_s'] * 1e3:.1f}ms "
        f"p99={stats['p99_latency_s'] * 1e3:.1f}ms "
        f"({p99_slowdown:.1f}x unloaded ideal), parity={poisson_parity}")

    parity_ok = bool(wave_parity and engine_parity and poisson_parity)
    rows = [
        {"name": "serve_scalar_reference",
         "us_per_call": t_scalar * 1e6 / total_budget,
         "derived": f"tok_per_s={total_budget / t_scalar:.1f} "
                    f"ideal_ms={ideal_mean * 1e3:.2f}"},
        {"name": f"serve_wave_{WAVE_SLOTS}slots",
         "us_per_call": t_wave * 1e6 / wave_tokens,
         "derived": f"tok_per_s={tok_s_wave:.1f} parity={wave_parity}"},
        {"name": f"serve_engine_{slots}slots",
         "us_per_call": t_burst * 1e6 / burst_tokens,
         "derived": f"tok_per_s={tok_s_engine:.1f} "
                    f"speedup={speedup:.2f}x parity={engine_parity}"},
        {"name": "serve_engine_poisson",
         "us_per_call": stats["p99_latency_s"] * 1e6,
         "derived": f"p50_ms={stats['p50_latency_s'] * 1e3:.1f} "
                    f"p99_ms={stats['p99_latency_s'] * 1e3:.1f} "
                    f"p99_slowdown={p99_slowdown:.1f}x "
                    f"tok_per_s={stats['tok_per_s']:.1f}"},
    ]
    summary = {
        "parity_ok": parity_ok,
        "speedup_vs_wave": float(speedup),
        "tok_s_engine": float(tok_s_engine),
        "tok_s_wave": float(tok_s_wave),
        "p50_latency_ms": stats["p50_latency_s"] * 1e3,
        "p99_latency_ms": stats["p99_latency_s"] * 1e3,
        "p99_slowdown_vs_ideal": float(p99_slowdown),
        "n_requests": n_requests,
        "slots": slots,
        "rate_per_s": rate_per_s,
    }
    if paged:
        prow, psum = _run_paged(bundle, params, cfg, dec, log, n_requests,
                                rate_per_s, seed)
        rows += prow
        summary.update(psum)
    return rows, summary


def write_json(rows: List[Dict], summary: Optional[Dict],
               path: str) -> None:
    payload = {"bench": "serve", "rows": rows}
    if summary is not None:
        payload["summary"] = summary
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="64 requests (default: 32)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=32)
    ap.add_argument("--rate", type=float, default=60.0,
                    help="Poisson arrival rate for the latency run")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + gate summary as JSON")
    ap.add_argument("--no-paged", dest="paged", action="store_false",
                    help="skip the paged-vs-dense equal-memory section")
    args = ap.parse_args()
    n = args.requests or (64 if args.full else 32)
    log = lambda *a: print(*a, file=sys.stderr)  # noqa: E731
    rows, summary = run(log=log, smoke=not args.full, n_requests=n,
                        slots=args.slots, rate_per_s=args.rate,
                        paged=args.paged)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, summary, args.json)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
