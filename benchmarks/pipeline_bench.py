"""Overlapped search pipeline: wall-time per generation, off vs pipelined.

Measures what DESIGN.md §11 buys: with device time and host time per
generation balanced, the synchronous loop pays ``host + device`` per
generation while the async pipeline pays ``max(host, device)`` — the
steady-state speedup approaches 2x.  The bench

1. **calibrates**: runs the synchronous loop with a zero-cost trainer to
   measure the pure host-side generation time, then sizes the simulated
   per-bucket device time to match it;
2. runs the same fixed-seed search under ``pipeline="off"``,
   ``"host_overlap"`` and ``"async"`` and reports wall-time per generation
   and the speedups;
3. **parity-gates**: ``off`` and ``host_overlap`` (and the zero-cost
   calibration run) must produce bit-identical final populations — the
   overlap is scheduling, never semantics.  A parity failure exits
   non-zero; the *speedup* floor is enforced separately by
   ``benchmarks/check_thresholds.py`` (relative gate, reframe-style).

Device time is **simulated by default**: each signature-bucket job sleeps a
calibrated interval, releasing the GIL exactly as a real XLA dispatch to an
accelerator would, and returns deterministic genome-derived results.  This
keeps the measured overlap honest on a single-core CI box, where real
concurrent *compute* cannot speed anything up.  ``--real`` swaps in the
real bucketed vmap trainer for multi-core hosts (reported, not gated).

The module forces ``--xla_force_host_platform_device_count=4`` before jax
initializes so the device-affine scheduler has 4 devices to shard buckets
across; run it as a subprocess (``python -m benchmarks.pipeline_bench``),
which is exactly how benchmarks/run.py wires it in.
"""
from __future__ import annotations

import os

_FORCE = "--xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" {_FORCE}=4").strip()

import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
from typing import Dict, List, Optional, Tuple  # noqa: E402

import numpy as np       # noqa: E402

from repro.core.evolution import EvolutionarySearch, NASConfig  # noqa: E402
from repro.core.trainer import TrainResult  # noqa: E402

GENERATIONS = 8
MODES = ("off", "host_overlap", "async")


def _deterministic_result(g) -> TrainResult:
    det = min(0.99, 0.70 + 0.05 * g.depth())
    return TrainResult(detection_rate=det,
                       false_alarm_rate=max(0.0, 0.30 - 0.04 * g.depth()),
                       val_loss=0.2, steps=0)


def _sim_trainer(sleep_s: float, seen_devices: set):
    """Deterministic stub trainer; ``sleep_s`` stands in for the bucket's
    XLA dispatch (a sleep releases the GIL exactly like device compute)."""
    def train(genomes, device=None):
        seen_devices.add(str(device))
        if sleep_s > 0.0:
            time.sleep(sleep_s)
        return [_deterministic_result(g) for g in genomes]
    return train


def _make_search(pipeline: str, sleep_s: float, seen_devices: set,
                 smoke: bool) -> EvolutionarySearch:
    cap = 1024 if smoke else 4096
    cfg = NASConfig(generations=GENERATIONS,
                    children_per_gen=cap // 2, n_accept=48,
                    init_population=32, population_cap=cap,
                    n_workers=8, seed=11, pipeline=pipeline,
                    device_affinity=True)
    return EvolutionarySearch(cfg, None, None,
                              batch_train_fn=_sim_trainer(sleep_s,
                                                          seen_devices),
                              log=lambda *_: None)


def _run_mode(pipeline: str, sleep_s: float, smoke: bool
              ) -> Tuple[object, float, set]:
    """Run one mode; returns (final state, loop wall time excluding the
    initial population's training, devices the buckets landed on).  The
    init cost is measured on a twin search (same seed => identical work)
    so every mode's number covers exactly its ``GENERATIONS`` steps."""
    seen: set = set()
    search = _make_search(pipeline, sleep_s, seen, smoke)
    t0 = time.perf_counter()
    state = search.run()
    total = time.perf_counter() - t0
    twin = _make_search(pipeline, sleep_s, set(), smoke)
    t0 = time.perf_counter()
    twin.init_state()
    init = time.perf_counter() - t0
    return state, max(1e-9, total - init), seen


def _assert_parity(a, b, label: str) -> None:
    ok = (list(a.pop.phash) == list(b.pop.phash)
          and np.array_equal(a.pop.cheap, b.pop.cheap)
          and np.array_equal(a.pop.expensive, b.pop.expensive))
    if not ok:
        raise SystemExit(f"PARITY FAILURE: {label} diverged from the "
                         f"synchronous trajectory — the overlapped "
                         f"pipeline changed semantics")


def run(log=print, smoke: bool = True) -> Tuple[List[Dict], Dict]:
    # ---- calibration: pure host-side generation time (zero device cost)
    cal_state, cal_wall, _ = _run_mode("off", 0.0, smoke)
    host_gen = cal_wall / GENERATIONS
    jobs = [r["train_jobs"] for r in cal_state.history if r["train_jobs"]]
    buckets_median = int(np.median(jobs)) if jobs else 0
    n_workers = max(8, len(jax_devices()))
    rounds = max(1, int(np.ceil(buckets_median / n_workers)))
    sleep_s = host_gen / rounds  # device time per generation ~= host time
    # short sleeps overshoot their nominal interval (timer granularity +
    # wakeup latency); measure the ratio and shrink the request so the
    # *actual* device time matches the host time
    t0 = time.perf_counter()
    for _ in range(5):
        time.sleep(sleep_s)
    overshoot = (time.perf_counter() - t0) / (5 * sleep_s)
    sleep_s /= max(1.0, overshoot)
    log(f"[pipeline] calibrated: host {host_gen * 1e3:.1f}ms/gen, "
        f"~{buckets_median} buckets/gen over {n_workers} workers, "
        f"sleep overshoot {overshoot:.2f}x "
        f"-> {sleep_s * 1e3:.1f}ms/bucket simulated device time")

    # ---- the three modes on the same seed + simulated device time.
    # Interleaved repeats, per-mode minimum wall: the box throttles under
    # sustained load and scheduler noise is additive, so the min is the
    # least-contaminated estimate of each mode's true cost (the trajectory
    # itself is deterministic — every repeat does identical work).
    states, walls, devices_seen = {}, {}, {}
    for _ in range(3):
        for mode in MODES:
            state, wall, seen = _run_mode(mode, sleep_s, smoke)
            states[mode] = state
            walls[mode] = min(walls.get(mode, np.inf), wall)
            devices_seen[mode] = seen
    for mode in MODES:
        log(f"[pipeline] {mode:13s}: "
            f"{walls[mode] / GENERATIONS * 1e3:7.1f}ms/gen "
            f"({len(devices_seen[mode])} devices)")

    # ---- gates: determinism first, speedup reported for the CI threshold
    _assert_parity(states["off"], cal_state, "zero-cost calibration run")
    _assert_parity(states["off"], states["host_overlap"], "host_overlap")
    speedup_async = walls["off"] / walls["async"]
    speedup_ho = walls["off"] / walls["host_overlap"]
    n_devices = len(jax_devices())
    log(f"[pipeline] speedup: async {speedup_async:.2f}x, "
        f"host_overlap {speedup_ho:.2f}x (parity OK, "
        f"{n_devices} devices, ~{buckets_median} buckets/gen)")

    rows = [{
        "name": f"pipeline_{mode}",
        "us_per_call": walls[mode] / GENERATIONS * 1e6,
        "derived": (f"speedup={walls['off'] / walls[mode]:.2f}x "
                    f"devices={len(devices_seen[mode])} "
                    f"buckets~{buckets_median}"),
    } for mode in MODES]
    summary = {
        "speedup_async": round(speedup_async, 3),
        "speedup_host_overlap": round(speedup_ho, 3),
        "parity_ok": True,     # _assert_parity raised otherwise
        "host_ms_per_gen": round(host_gen * 1e3, 2),
        "sim_device_ms_per_bucket": round(sleep_s * 1e3, 2),
        "n_devices": n_devices,
        "buckets_median": buckets_median,
        "generations": GENERATIONS,
    }
    return rows, summary


def run_real(log=print) -> List[Dict]:
    """Real bucketed vmap training instead of simulated device time — only
    meaningful on a host with spare cores; reported, never gated."""
    from repro.core.search_space import SearchSpace
    space = SearchSpace(input_decimations=(240,))
    rng = np.random.default_rng(7)
    tr = (rng.normal(size=(64, 250, 2)).astype(np.float32),
          (np.arange(64) % 2).astype(np.int32))
    va = (rng.normal(size=(48, 250, 2)).astype(np.float32),
          (np.arange(48) % 2).astype(np.int32))
    rows = []
    for mode in ("off", "async"):
        cfg = NASConfig(generations=3, children_per_gen=16, n_accept=8,
                        init_population=8, population_cap=32, n_workers=4,
                        seed=11, pipeline=mode, device_affinity=True,
                        train_steps=8, train_batch=16)
        s = EvolutionarySearch(cfg, tr, va, space=space,
                               log=lambda *_: None)
        t0 = time.perf_counter()
        s.run()
        wall = time.perf_counter() - t0
        log(f"[pipeline --real] {mode}: {wall / 3 * 1e3:.0f}ms/gen")
        rows.append({"name": f"pipeline_real_{mode}",
                     "us_per_call": wall / 3 * 1e6,
                     "derived": "real bucketed training"})
    return rows


def jax_devices():
    import jax
    return jax.local_devices()


def write_json(rows: List[Dict], summary: Optional[Dict],
               path: str) -> None:
    payload = {"bench": "pipeline", "rows": rows}
    if summary is not None:
        payload["summary"] = summary
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="population_cap=4096 (default: smoke, 1024)")
    ap.add_argument("--real", action="store_true",
                    help="real bucketed training instead of simulated "
                         "device time (multi-core hosts; not gated)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + gate summary as JSON")
    args = ap.parse_args()
    log = lambda *a: print(*a, file=sys.stderr)  # noqa: E731
    if args.real:
        rows, summary = run_real(log=log), None
    else:
        rows, summary = run(log=log, smoke=not args.full)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, summary, args.json)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
