"""Router bench: goodput + p99 under replica loss and overload (§14).

Four deterministic virtual-clock runs over the reduced qwen2-0.5b config
(one decode step = one virtual second, so every ratio below is
machine-independent — the CI gate never compares absolute wall times):

1. **router fault-free** — 2 engine replicas behind the ReplicaRouter,
   open-loop Poisson arrivals with a loose deadline: the goodput and p99
   baseline;
2. **router replica-loss** — same workload, a seeded ``device_loss``
   kills replica 0 mid-run: in-flight work fails over and re-decodes
   bit-identically; goodput must stay ≥ 0.6x the fault-free run (the CI
   gate: losing half the fleet costs less than half the goodput, because
   the survivor keeps its slots full);
3. **single engine, overload** — one engine under a heavy-tailed gamma
   burst (cv=3) past its capacity, bounded queue + tight deadlines: the
   degenerate deployment the router replaces;
4. **router, overload** — the same overload into 2 replicas: more
   goodput, and every dropped request is an *explicit* rejection (shed
   counts in the summary; zero silent drops — submitted == served +
   shed everywhere).

Parity: every completed request must match the scalar greedy reference
bit for bit (expired requests must be exact prefixes) in every run —
failover and hedging are not allowed to change a single token.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.faults import FaultPlan, FaultSpec
from repro.models.registry import build_model
from repro.serve import (
    EngineConfig,
    ReplicaRouter,
    RouterConfig,
    ServeEngine,
    ServeRequest,
    gamma_workload,
    greedy_reference,
    poisson_workload,
)

ARCH = "qwen2-0.5b"
CACHE_LEN = 64
SLOTS = 4              # per replica — the single-engine runs get the same
PROMPT_LENS = (4, 8, 12, 16)
OUT_LENS = (4, 6, 8)


def _fresh(reqs: List[ServeRequest],
           deadline_s: Optional[float] = None) -> List[ServeRequest]:
    return [ServeRequest(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                         arrival_s=r.arrival_s, deadline_s=deadline_s)
            for r in reqs]


def _parity(done: List[ServeRequest], refs: Dict[int, List[int]]) -> bool:
    for r in done:
        if r.rejected:
            if r.out:               # shed requests carry no tokens
                return False
        elif r.expired:
            if r.out != refs[r.rid][:len(r.out)]:
                return False
        elif r.out != refs[r.rid]:
            return False
    return True


def _goodput(done: List[ServeRequest]) -> Tuple[int, float, float]:
    """(completed, virtual makespan, p99 virtual latency) of one run."""
    ok = [r for r in done if r.done and not r.expired and not r.rejected]
    span = max((r.t_done for r in ok), default=0.0)
    p99 = float(np.percentile([r.latency_s for r in ok], 99)) if ok else 0.0
    return len(ok), span, p99


def run(log=print, smoke: bool = True, n_requests: Optional[int] = None,
        seed: int = 0) -> Tuple[List[Dict], Dict]:
    n = n_requests or (24 if smoke else 48)
    cfg = reduced_config(ARCH)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    steady = poisson_workload(n, vocab_size=cfg.vocab_size, rate_per_s=2.0,
                              prompt_lens=PROMPT_LENS, out_lens=OUT_LENS,
                              seed=seed)
    burst = gamma_workload(n, vocab_size=cfg.vocab_size, rate_per_s=8.0,
                           cv=3.0, prompt_lens=PROMPT_LENS,
                           out_lens=OUT_LENS, seed=seed + 1)
    for r in burst:
        r.rid += n          # disjoint rid space: refs are keyed by rid
    refs: Dict[int, List[int]] = {}
    dec = jax.jit(bundle.decode_step)
    for r in steady + burst:
        refs[r.rid] = greedy_reference(bundle, params, r.prompt, r.max_new,
                                       CACHE_LEN, decode_jit=dec)
    log(f"[router] workload: {n} steady (poisson 2/s) + {n} burst "
        f"(gamma 8/s cv=3), {SLOTS} slots/replica")

    ecfg = EngineConfig(slots=SLOTS, cache_len=CACHE_LEN, pad_to=8,
                        max_prefill_batch=SLOTS)

    # -- 1. router fault-free: the goodput/p99 baseline -------------------
    router = ReplicaRouter(bundle, params, RouterConfig(replicas=2,
                                                        engine=ecfg))
    router.run(_fresh(steady, 30.0))                     # warm compiles
    t0 = time.perf_counter()
    done_ff = router.run(_fresh(steady, 30.0))
    t_ff = time.perf_counter() - t0
    ok_ff, span_ff, p99_ff = _goodput(done_ff)
    good_ff = ok_ff / span_ff if span_ff else 0.0
    par_ff = _parity(done_ff, refs)
    log(f"[router] fault-free: {ok_ff}/{n} ok, makespan {span_ff:.0f}vs, "
        f"goodput {good_ff:.3f} req/vs, p99 {p99_ff:.0f}vs, "
        f"parity={par_ff}")

    # -- 2. router under replica loss -------------------------------------
    plan = FaultPlan([FaultSpec(site="serve.replica", kind="device_loss",
                                when=lambda c: c["replica"] == 0
                                and c["tick"] == 5)])
    router_loss = ReplicaRouter(bundle, params,
                                RouterConfig(replicas=2, engine=ecfg),
                                faults=plan)
    t0 = time.perf_counter()
    done_loss = router_loss.run(_fresh(steady, 30.0))
    t_loss = time.perf_counter() - t0
    ok_loss, span_loss, p99_loss = _goodput(done_loss)
    good_loss = ok_loss / span_loss if span_loss else 0.0
    par_loss = _parity(done_loss, refs)
    assert plan.fired("serve.replica", kind="device_loss")
    goodput_ratio = good_loss / good_ff if good_ff else 0.0
    p99_ratio = p99_loss / p99_ff if p99_ff else 0.0
    s_loss = router_loss.stats
    log(f"[router] replica-loss: {ok_loss}/{n} ok, goodput {good_loss:.3f} "
        f"req/vs ({goodput_ratio:.2f}x fault-free), p99 {p99_loss:.0f}vs "
        f"({p99_ratio:.2f}x), failovers={s_loss['failovers']}, "
        f"quarantined={s_loss['quarantined']}, parity={par_loss}")

    # -- 3. single engine under overload ----------------------------------
    single = ServeEngine(bundle, params, EngineConfig(
        slots=SLOTS, cache_len=CACHE_LEN, pad_to=8, max_prefill_batch=SLOTS,
        max_queue=6))
    t0 = time.perf_counter()
    done_single = single.run(_fresh(burst, 12.0))
    t_single = time.perf_counter() - t0
    ok_single, span_single, p99_single = _goodput(done_single)
    good_single = ok_single / span_single if span_single else 0.0
    shed_single = sum(r.rejected for r in done_single)
    par_single = _parity(done_single, refs)
    log(f"[router] single overload: {ok_single}/{n} ok, "
        f"{shed_single} shed, goodput {good_single:.3f} req/vs, "
        f"parity={par_single}")

    # -- 4. router under overload ------------------------------------------
    router_ov = ReplicaRouter(bundle, params, RouterConfig(
        replicas=2, engine=ecfg, max_queue=6))
    t0 = time.perf_counter()
    done_ov = router_ov.run(_fresh(burst, 12.0))
    t_ov = time.perf_counter() - t0
    ok_ov, span_ov, p99_ov = _goodput(done_ov)
    good_ov = ok_ov / span_ov if span_ov else 0.0
    s_ov = router_ov.stats
    shed_ov = s_ov["shed_queue"] + s_ov["shed_deadline"]
    par_ov = _parity(done_ov, refs)
    overload_ratio = good_ov / good_single if good_single else 0.0
    log(f"[router] router overload: {ok_ov}/{n} ok, {shed_ov} shed "
        f"(queue={s_ov['shed_queue']} deadline={s_ov['shed_deadline']}), "
        f"goodput {good_ov:.3f} req/vs ({overload_ratio:.2f}x single), "
        f"parity={par_ov}")

    # zero silent drops: every run returns every submitted request
    drops_ok = (len(done_ff) == n and len(done_loss) == n
                and len(done_single) == n and len(done_ov) == n)
    parity_ok = bool(par_ff and par_loss and par_single and par_ov
                     and drops_ok)

    rows = [
        {"name": "router_fault_free",
         "us_per_call": t_ff * 1e6 / max(ok_ff, 1),
         "derived": f"ok={ok_ff}/{n} goodput={good_ff:.3f}req/vs "
                    f"p99={p99_ff:.0f}vs parity={par_ff}"},
        {"name": "router_replica_loss",
         "us_per_call": t_loss * 1e6 / max(ok_loss, 1),
         "derived": f"ok={ok_loss}/{n} goodput_ratio={goodput_ratio:.2f}x "
                    f"p99_ratio={p99_ratio:.2f}x "
                    f"failovers={s_loss['failovers']} parity={par_loss}"},
        {"name": "single_engine_overload",
         "us_per_call": t_single * 1e6 / max(ok_single, 1),
         "derived": f"ok={ok_single}/{n} shed={shed_single} "
                    f"goodput={good_single:.3f}req/vs parity={par_single}"},
        {"name": "router_overload",
         "us_per_call": t_ov * 1e6 / max(ok_ov, 1),
         "derived": f"ok={ok_ov}/{n} shed={shed_ov} "
                    f"goodput_ratio_vs_single={overload_ratio:.2f}x "
                    f"parity={par_ov}"},
    ]
    summary = {
        "parity_ok": parity_ok,
        "goodput_ratio_replica_loss": float(goodput_ratio),
        "p99_ratio_replica_loss": float(p99_ratio),
        "goodput_ratio_overload_vs_single": float(overload_ratio),
        "shed_overload": int(shed_ov),
        "shed_single_overload": int(shed_single),
        "failovers": int(s_loss["failovers"]),
        "quarantined": list(s_loss["quarantined"]),
        "completed_fault_free": int(ok_ff),
        "completed_replica_loss": int(ok_loss),
        "n_requests": n,
        "slots_per_replica": SLOTS,
    }
    return rows, summary


def write_json(rows: List[Dict], summary: Optional[Dict],
               path: str) -> None:
    payload = {"bench": "router", "rows": rows}
    if summary is not None:
        payload["summary"] = summary
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="48 requests per workload (default: 24)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + gate summary as JSON")
    args = ap.parse_args()
    log = lambda *a: print(*a, file=sys.stderr)  # noqa: E731
    rows, summary = run(log=log, smoke=not args.full,
                        n_requests=args.requests)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, summary, args.json)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
