"""CI perf gate over machine-readable bench results (BENCH_*.json).

Reframe-style relative thresholds (SNIPPETS §1): the gate checks *ratios*
the bench computed against its own same-machine baseline (overlap speedup
vs. the synchronous loop), never absolute wall times — absolute numbers
vary wildly across CI runners, ratios don't.

Currently gates BENCH_pipeline.json (benchmarks/pipeline_bench.py):

* ``parity_ok`` must be true — the overlapped pipeline reproduced the
  synchronous trajectory bit for bit (a hard correctness gate);
* ``speedup_async >= --min-speedup`` (default 1.2 — the bench itself
  demonstrates ~1.6-1.9x on an idle box; the CI floor leaves headroom for
  noisy shared runners while still catching a real overlap regression).

Exit code 1 on any violation, so the build fails.
"""
from __future__ import annotations

import argparse
import json
import sys


def check_pipeline(path: str, min_speedup: float) -> list:
    with open(path) as f:
        payload = json.load(f)
    summary = payload.get("summary")
    if not summary:
        return [f"{path}: no gate summary (was the bench run with --real?)"]
    failures = []
    if not summary.get("parity_ok", False):
        failures.append(
            f"{path}: parity_ok={summary.get('parity_ok')} — the "
            f"overlapped pipeline diverged from the synchronous trajectory")
    speedup = summary.get("speedup_async", 0.0)
    if speedup < min_speedup:
        failures.append(
            f"{path}: speedup_async={speedup:.2f}x < floor "
            f"{min_speedup:.2f}x — overlap regression")
    print(f"[gate] {path}: parity_ok={summary.get('parity_ok')} "
          f"speedup_async={speedup:.2f}x "
          f"(floor {min_speedup:.2f}x) "
          f"host_overlap={summary.get('speedup_host_overlap', 0.0):.2f}x "
          f"devices={summary.get('n_devices')} "
          f"buckets~{summary.get('buckets_median')}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("pipeline_json", nargs="?",
                    default="BENCH_pipeline.json",
                    help="pipeline bench result (default: "
                         "BENCH_pipeline.json)")
    ap.add_argument("--min-speedup", type=float, default=1.2,
                    help="async overlap speedup floor (default 1.2)")
    args = ap.parse_args()
    failures = check_pipeline(args.pipeline_json, args.min_speedup)
    for f in failures:
        print(f"[gate] FAIL: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)
    print("[gate] all thresholds met")


if __name__ == "__main__":
    main()
