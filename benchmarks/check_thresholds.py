"""CI perf gate over machine-readable bench results (BENCH_*.json).

Reframe-style relative thresholds (SNIPPETS §1): the gate checks *ratios*
the bench computed against its own same-machine baseline (overlap speedup
vs. the synchronous loop), never absolute wall times — absolute numbers
vary wildly across CI runners, ratios don't.

Gates BENCH_pipeline.json (benchmarks/pipeline_bench.py):

* ``parity_ok`` must be true — the overlapped pipeline reproduced the
  synchronous trajectory bit for bit (a hard correctness gate);
* ``speedup_async >= --min-speedup`` (default 1.2 — the bench itself
  demonstrates ~1.6-1.9x on an idle box; the CI floor leaves headroom for
  noisy shared runners while still catching a real overlap regression).

Gates BENCH_serve.json (benchmarks/serve_bench.py):

* ``parity_ok`` must be true — greedy tokens from both the wave-barrier
  baseline and the continuous engine (burst AND Poisson runs) matched the
  scalar one-request reference bit for bit;
* ``speedup_vs_wave >= --min-serve-speedup`` (default 3.0, the ISSUE's
  acceptance floor; the bench shows ~10-16x on an idle box);
* ``p99_slowdown_vs_ideal <= --max-p99-slowdown`` (default 20.0): p99
  end-to-end latency under open-loop Poisson load, as a multiple of the
  mean *unloaded* scalar latency.  A ratio, not a wall time — the bench
  shows ~3x; the generous ceiling only catches pathological queueing
  (e.g. the engine degenerating to serial admission);
* paged KV cache (present when the bench ran its ``--paged`` section,
  DESIGN.md §15): ``paged_parity_ok`` must be true (every admitted
  request bit-identical to the scalar reference, OOM sheds explicit with
  reference-prefix outputs, zero silent drops),
  ``paged_concurrency_ratio >= --min-paged-concurrency`` (default 2.0,
  the ISSUE's acceptance floor: paged peak concurrency vs dense at equal
  KV memory on the long-tail mix), and
  ``paged_p99_slowdown_vs_ideal <= --max-paged-p99-slowdown``
  (default 20.0, same rationale as the dense ceiling).

Gates BENCH_faults.json (benchmarks/fault_bench.py):

* ``parity_ok`` must be true — the search that crashed every 3rd training
  job and recovered produced the fault-free run's final population bit
  for bit (recovery restores work, never changes it);
* ``slowdown_faulted <= --max-fault-slowdown`` (default 5.0): wall-time
  ratio of the crashed-and-recovered run to the fault-free run.  The
  bench shows ~3x with its deliberately tiny simulated buckets (retry
  backoff dominates there; with real multi-second training it is noise) —
  the ceiling catches recovery degenerating into retry storms or
  serialized backoff.

Gates BENCH_router.json (benchmarks/router_bench.py):

* ``parity_ok`` must be true — every request the replicated router
  completed (fault-free, replica-loss, overload) matched the scalar
  greedy reference bit for bit, every run returned every submitted
  request (zero silent drops);
* ``goodput_ratio_replica_loss >= --min-router-goodput`` (default 0.6,
  the ISSUE's acceptance floor): goodput with one of two replicas killed
  mid-run, as a fraction of the fault-free run.  Virtual-clock ratio —
  deterministic on any machine.

Baseline regression (``--against-baseline DIR --max-regression PCT``):
every gated json is also compared against the committed baseline copy in
DIR (benchmarks/baselines/).  Only the machine-relative *ratio* metrics
are compared — higher-is-better ratios may not drop more than PCT
percent below baseline, lower-is-better ratios may not rise more than
PCT percent above — absolute wall times are never compared.

Exit code 1 on any violation, so the build fails.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def check_pipeline(path: str, min_speedup: float) -> list:
    with open(path) as f:
        payload = json.load(f)
    summary = payload.get("summary")
    if not summary:
        return [f"{path}: no gate summary (was the bench run with --real?)"]
    failures = []
    if not summary.get("parity_ok", False):
        failures.append(
            f"{path}: parity_ok={summary.get('parity_ok')} — the "
            f"overlapped pipeline diverged from the synchronous trajectory")
    speedup = summary.get("speedup_async", 0.0)
    if speedup < min_speedup:
        failures.append(
            f"{path}: speedup_async={speedup:.2f}x < floor "
            f"{min_speedup:.2f}x — overlap regression")
    print(f"[gate] {path}: parity_ok={summary.get('parity_ok')} "
          f"speedup_async={speedup:.2f}x "
          f"(floor {min_speedup:.2f}x) "
          f"host_overlap={summary.get('speedup_host_overlap', 0.0):.2f}x "
          f"devices={summary.get('n_devices')} "
          f"buckets~{summary.get('buckets_median')}")
    return failures


def check_serve(path: str, min_speedup: float, max_p99_slowdown: float,
                min_paged_concurrency: float = 2.0,
                max_paged_p99_slowdown: float = 20.0) -> list:
    with open(path) as f:
        payload = json.load(f)
    summary = payload.get("summary")
    if not summary:
        return [f"{path}: no gate summary (serve_bench.py --json writes it)"]
    failures = []
    if not summary.get("parity_ok", False):
        failures.append(
            f"{path}: parity_ok={summary.get('parity_ok')} — served greedy "
            f"tokens diverged from the scalar reference")
    speedup = summary.get("speedup_vs_wave", 0.0)
    if speedup < min_speedup:
        failures.append(
            f"{path}: speedup_vs_wave={speedup:.2f}x < floor "
            f"{min_speedup:.2f}x — continuous-batching regression")
    slowdown = summary.get("p99_slowdown_vs_ideal", float("inf"))
    if slowdown > max_p99_slowdown:
        failures.append(
            f"{path}: p99_slowdown_vs_ideal={slowdown:.1f}x > ceiling "
            f"{max_p99_slowdown:.1f}x — pathological queueing under "
            f"Poisson load")
    print(f"[gate] {path}: parity_ok={summary.get('parity_ok')} "
          f"speedup_vs_wave={speedup:.2f}x (floor {min_speedup:.2f}x) "
          f"p99_slowdown={slowdown:.1f}x (ceiling {max_p99_slowdown:.1f}x) "
          f"p99={summary.get('p99_latency_ms', 0.0):.0f}ms "
          f"slots={summary.get('slots')}")
    if "paged_concurrency_ratio" in summary:
        if not summary.get("paged_parity_ok", False):
            failures.append(
                f"{path}: paged_parity_ok="
                f"{summary.get('paged_parity_ok')} — a paged request "
                f"diverged from the scalar reference, an OOM shed lost "
                f"its prefix, or a request was dropped silently")
        cratio = summary.get("paged_concurrency_ratio", 0.0)
        if cratio < min_paged_concurrency:
            failures.append(
                f"{path}: paged_concurrency_ratio={cratio:.2f}x < floor "
                f"{min_paged_concurrency:.2f}x — the paged pool is not "
                f"buying admission capacity over dense slots at equal "
                f"memory")
        pslow = summary.get("paged_p99_slowdown_vs_ideal", float("inf"))
        if pslow > max_paged_p99_slowdown:
            failures.append(
                f"{path}: paged_p99_slowdown_vs_ideal={pslow:.1f}x > "
                f"ceiling {max_paged_p99_slowdown:.1f}x — pathological "
                f"queueing through the paged engine")
        print(f"[gate] {path} (paged): "
              f"parity_ok={summary.get('paged_parity_ok')} "
              f"concurrency_ratio={cratio:.2f}x "
              f"(floor {min_paged_concurrency:.2f}x) "
              f"p99_slowdown={pslow:.1f}x "
              f"(ceiling {max_paged_p99_slowdown:.1f}x) "
              f"shed_blocks={summary.get('paged_shed_blocks')} "
              f"peak={summary.get('paged_peak_concurrency')}/"
              f"dense {summary.get('dense_peak_concurrency')}")
    return failures


def check_faults(path: str, max_slowdown: float) -> list:
    with open(path) as f:
        payload = json.load(f)
    summary = payload.get("summary")
    if not summary:
        return [f"{path}: no gate summary (fault_bench.py --json writes it)"]
    failures = []
    if not summary.get("parity_ok", False):
        failures.append(
            f"{path}: parity_ok={summary.get('parity_ok')} — the "
            f"crashed-and-recovered search diverged from the fault-free "
            f"trajectory")
    slowdown = summary.get("slowdown_faulted", float("inf"))
    if slowdown > max_slowdown:
        failures.append(
            f"{path}: slowdown_faulted={slowdown:.2f}x > ceiling "
            f"{max_slowdown:.2f}x — fault recovery is pathologically "
            f"expensive (retry storm / serialized backoff)")
    print(f"[gate] {path}: parity_ok={summary.get('parity_ok')} "
          f"slowdown_faulted={slowdown:.2f}x "
          f"(ceiling {max_slowdown:.2f}x) "
          f"crashes={summary.get('crashes')} "
          f"recovery={summary.get('recovery_ms_per_crash', 0.0):.0f}"
          f"ms/crash")
    return failures


def check_router(path: str, min_goodput: float) -> list:
    with open(path) as f:
        payload = json.load(f)
    summary = payload.get("summary")
    if not summary:
        return [f"{path}: no gate summary (router_bench.py --json writes "
                f"it)"]
    failures = []
    if not summary.get("parity_ok", False):
        failures.append(
            f"{path}: parity_ok={summary.get('parity_ok')} — a routed "
            f"request diverged from the scalar reference or a run "
            f"dropped a request silently")
    ratio = summary.get("goodput_ratio_replica_loss", 0.0)
    if ratio < min_goodput:
        failures.append(
            f"{path}: goodput_ratio_replica_loss={ratio:.2f}x < floor "
            f"{min_goodput:.2f}x — losing one of two replicas costs more "
            f"goodput than it should (failover/rebalance regression)")
    if summary.get("shed_overload", 0) <= 0:
        failures.append(
            f"{path}: shed_overload={summary.get('shed_overload')} — the "
            f"overload run shed nothing; admission control is not "
            f"engaging (or the workload no longer overloads)")
    print(f"[gate] {path}: parity_ok={summary.get('parity_ok')} "
          f"goodput_ratio_replica_loss={ratio:.2f}x "
          f"(floor {min_goodput:.2f}x) "
          f"p99_ratio={summary.get('p99_ratio_replica_loss', 0.0):.2f}x "
          f"overload_vs_single="
          f"{summary.get('goodput_ratio_overload_vs_single', 0.0):.2f}x "
          f"shed={summary.get('shed_overload')} "
          f"failovers={summary.get('failovers')}")
    return failures


# Machine-relative ratio metrics compared against the committed baseline:
# (metric, higher_is_better).  Absolute wall times are never compared, and
# neither are wall-clock-noisy ratios (realtime p99 multiples, retry
# backoff slowdowns) — those stay bounded by their absolute gates above.
# The router ratios run on the virtual clock and are exactly deterministic.
BASELINE_METRICS = {
    "pipeline": [("speedup_async", True)],
    "serve": [("speedup_vs_wave", True),
              ("paged_concurrency_ratio", True)],
    "faults": [],
    "router": [("goodput_ratio_replica_loss", True),
               ("goodput_ratio_overload_vs_single", True),
               ("p99_ratio_replica_loss", False)],
}


def check_against_baseline(path: str, baseline_dir: str,
                           max_regression_pct: float) -> list:
    """Compare one bench json's ratio metrics against the committed
    baseline copy of the same file.  A missing baseline file is a skip
    (new bench), not a failure; a missing metric in the baseline is
    skipped too (metric added since the baseline was cut).  The reverse —
    a metric the baseline has but the run lacks — is a failure: it means
    a bench section was silently disabled."""
    base_path = os.path.join(baseline_dir, os.path.basename(path))
    if not os.path.exists(base_path):
        print(f"[gate] {path}: no baseline at {base_path} — skipped")
        return []
    with open(path) as f:
        payload = json.load(f)
    summary = payload.get("summary") or {}
    bench = payload.get("bench")
    with open(base_path) as f:
        base = json.load(f).get("summary") or {}
    failures = []
    tol = max_regression_pct / 100.0
    for metric, higher_better in BASELINE_METRICS.get(bench, []):
        if metric not in base:
            continue  # metric added since the baseline was cut
        if metric not in summary:
            # the baseline expects this ratio but the run never produced
            # it — a silently-disabled bench section must read as red, not
            # as a skip
            failures.append(f"{path}: {metric} in baseline but missing "
                            f"from this run (bench section disabled?)")
            print(f"[gate] {path} vs baseline: {metric} MISSING "
                  f"(baseline {float(base[metric]):.3f}) FAIL")
            continue
        now, ref = float(summary[metric]), float(base[metric])
        if ref == 0.0:
            continue
        if higher_better:
            bound = ref * (1.0 - tol)
            bad = now < bound
            rel = (ref - now) / ref * 100.0
        else:
            bound = ref * (1.0 + tol)
            bad = now > bound
            rel = (now - ref) / ref * 100.0
        if bad:
            failures.append(
                f"{path}: {metric}={now:.3f} regressed {rel:.0f}% vs "
                f"baseline {ref:.3f} (allowed {max_regression_pct:.0f}%)")
        print(f"[gate] {path} vs baseline: {metric}={now:.3f} "
              f"(baseline {ref:.3f}, "
              f"{'floor' if higher_better else 'ceiling'} {bound:.3f})"
              f"{' FAIL' if bad else ''}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("pipeline_json", nargs="?",
                    default="BENCH_pipeline.json",
                    help="pipeline bench result (default: "
                         "BENCH_pipeline.json)")
    ap.add_argument("--serve-json", default=None,
                    help="serve bench result (e.g. BENCH_serve.json); "
                         "omit to skip the serving gate")
    ap.add_argument("--faults-json", default=None,
                    help="fault-recovery bench result (e.g. "
                         "BENCH_faults.json); omit to skip the fault gate")
    ap.add_argument("--router-json", default=None,
                    help="replicated-router bench result (e.g. "
                         "BENCH_router.json); omit to skip the router gate")
    ap.add_argument("--min-speedup", type=float, default=1.2,
                    help="async overlap speedup floor (default 1.2)")
    ap.add_argument("--min-serve-speedup", type=float, default=3.0,
                    help="continuous-batching tok/s floor vs the "
                         "wave-barrier baseline (default 3.0)")
    ap.add_argument("--max-p99-slowdown", type=float, default=20.0,
                    help="p99 Poisson latency ceiling as a multiple of "
                         "the unloaded scalar latency (default 20.0)")
    ap.add_argument("--min-paged-concurrency", type=float, default=2.0,
                    help="paged peak-concurrency floor vs dense at equal "
                         "KV memory (default 2.0, the acceptance floor)")
    ap.add_argument("--max-paged-p99-slowdown", type=float, default=20.0,
                    help="paged open-loop p99 ceiling as a multiple of "
                         "the unloaded scalar latency (default 20.0)")
    ap.add_argument("--max-fault-slowdown", type=float, default=5.0,
                    help="wall-time ceiling of the crash-and-recover run "
                         "as a multiple of the fault-free run "
                         "(default 5.0)")
    ap.add_argument("--min-router-goodput", type=float, default=0.6,
                    help="goodput floor under single-replica loss as a "
                         "fraction of the fault-free run (default 0.6)")
    ap.add_argument("--against-baseline", metavar="DIR", default=None,
                    help="also compare each gated json's ratio metrics "
                         "against the committed copy in DIR "
                         "(benchmarks/baselines/)")
    ap.add_argument("--max-regression", type=float, default=25.0,
                    help="allowed percent regression vs the baseline "
                         "ratios (default 25)")
    args = ap.parse_args()
    failures = check_pipeline(args.pipeline_json, args.min_speedup)
    if args.serve_json:
        failures += check_serve(args.serve_json, args.min_serve_speedup,
                                args.max_p99_slowdown,
                                args.min_paged_concurrency,
                                args.max_paged_p99_slowdown)
    if args.faults_json:
        failures += check_faults(args.faults_json, args.max_fault_slowdown)
    if args.router_json:
        failures += check_router(args.router_json, args.min_router_goodput)
    if args.against_baseline:
        for p in (args.pipeline_json, args.serve_json, args.faults_json,
                  args.router_json):
            if p:
                failures += check_against_baseline(p, args.against_baseline,
                                                   args.max_regression)
    for f in failures:
        print(f"[gate] FAIL: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)
    print("[gate] all thresholds met")


if __name__ == "__main__":
    main()
