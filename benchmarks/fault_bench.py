"""Fault-recovery overhead: the same search with and without injected
worker crashes (DESIGN.md §13).

Every 3rd training job's first attempt crashes (the canonical
crash-and-recover drill, :func:`repro.core.faults.crash_every`); the
scheduler retries it with exponential backoff.  The bench measures what
that recovery *costs*:

1. runs a fixed-seed search fault-free, then identically seeded with the
   crash plan wired in, and reports the wall-time ratio (``slowdown``);
2. **parity-gates**: both runs must produce bit-identical final
   populations — recovery restores the work, never changes it.  A parity
   failure exits non-zero; the slowdown ceiling is enforced by
   ``benchmarks/check_thresholds.py --faults-json`` (relative gate: a
   ratio against the same-machine fault-free run, never a wall time).

Device time is simulated (each signature-bucket job sleeps a fixed
interval, releasing the GIL like a real XLA dispatch) so the measured
overhead is the recovery machinery itself — retried bucket time plus
backoff — not compute noise.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.faults import FaultPlan, crash_every
from repro.core.trainer import TrainResult

GENERATIONS = 6
CRASH_EVERY = 3
SLEEP_S = 0.015  # simulated device time per signature bucket


def _sim_trainer(sleep_s: float):
    def train(genomes, device=None):
        if sleep_s > 0.0:
            time.sleep(sleep_s)
        out = []
        for g in genomes:
            det = min(0.99, 0.70 + 0.05 * g.depth())
            out.append(TrainResult(
                detection_rate=det,
                false_alarm_rate=max(0.0, 0.30 - 0.04 * g.depth()),
                val_loss=0.2, steps=0))
        return out
    return train


def _run_search(faults: Optional[FaultPlan], smoke: bool
                ) -> Tuple[object, float]:
    cfg = NASConfig(generations=GENERATIONS,
                    children_per_gen=16 if smoke else 48,
                    n_accept=8 if smoke else 24,
                    init_population=8, population_cap=64,
                    n_workers=4, seed=11, pipeline="off")
    s = EvolutionarySearch(cfg, None, None,
                           batch_train_fn=_sim_trainer(SLEEP_S),
                           log=lambda *_: None, faults=faults)
    t0 = time.perf_counter()
    state = s.run()
    return state, time.perf_counter() - t0


def run(log=print, smoke: bool = True) -> Tuple[List[Dict], Dict]:
    # interleaved repeats, per-variant minimum wall: scheduler noise is
    # additive, the trajectory is deterministic — the min is the cleanest
    # estimate of each variant's true cost
    states, walls, crashes = {}, {}, 0
    for _ in range(3):
        for name in ("fault_free", "faulted"):
            plan = FaultPlan([crash_every(CRASH_EVERY)]) \
                if name == "faulted" else None
            state, wall = _run_search(plan, smoke)
            states[name] = state
            walls[name] = min(walls.get(name, np.inf), wall)
            if plan is not None:
                crashes = len(plan.fired(kind="crash"))

    a, b = states["fault_free"], states["faulted"]
    parity_ok = (list(a.pop.phash) == list(b.pop.phash)
                 and np.array_equal(a.pop.cheap, b.pop.cheap)
                 and np.array_equal(a.pop.expensive, b.pop.expensive))
    if not parity_ok:
        raise SystemExit("PARITY FAILURE: the crashed-and-recovered search "
                         "diverged from the fault-free trajectory — "
                         "recovery changed semantics")
    slowdown = walls["faulted"] / walls["fault_free"]
    overhead_ms = (walls["faulted"] - walls["fault_free"]) * 1e3 \
        / max(crashes, 1)
    log(f"[faults] fault_free {walls['fault_free'] * 1e3:.0f}ms, "
        f"faulted {walls['faulted'] * 1e3:.0f}ms over {crashes} crashes "
        f"-> slowdown {slowdown:.2f}x, ~{overhead_ms:.0f}ms/crash "
        f"(parity OK)")

    rows = [{
        "name": f"faults_{name}",
        "us_per_call": walls[name] / GENERATIONS * 1e6,
        "derived": (f"slowdown={walls[name] / walls['fault_free']:.2f}x "
                    f"crashes={crashes if name == 'faulted' else 0}"),
    } for name in ("fault_free", "faulted")]
    summary = {
        "slowdown_faulted": round(slowdown, 3),
        "parity_ok": True,      # the SystemExit above fired otherwise
        "crashes": crashes,
        "recovery_ms_per_crash": round(overhead_ms, 1),
        "crash_every": CRASH_EVERY,
        "generations": GENERATIONS,
    }
    return rows, summary


def write_json(rows: List[Dict], summary: Optional[Dict],
               path: str) -> None:
    payload = {"bench": "faults", "rows": rows}
    if summary is not None:
        payload["summary"] = summary
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale generation width (default: smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="write rows + gate summary as JSON")
    args = ap.parse_args()
    log = lambda *a: print(*a, file=sys.stderr)  # noqa: E731
    rows, summary = run(log=log, smoke=not args.full)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
    if args.json:
        write_json(rows, summary, args.json)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
