"""End-to-end LM training driver with fault-tolerant checkpointing.

Trains a reduced-config model from the zoo (default: a ~10M-param qwen2
variant; ``--full-100m`` selects a ~100M config) on the synthetic token
pipeline, checkpointing and restart included.  The same loop, scaled through
launch/train.py, drives the production mesh.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import reduced_config
from repro.data.lm import LMDataConfig, data_iterator
from repro.models.registry import build_model
from repro.training.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--full-100m", action="store_true",
                    help="~100M-param config (slow on CPU)")
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    if args.full_100m:
        cfg = dataclasses.replace(
            cfg, d_model=512, n_layers=8, n_heads=8, n_kv_heads=4,
            d_ff=2048, vocab_size=50304, name=cfg.name + "-100m")
    bundle = build_model(cfg)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params≈{n_params/1e6:.1f}M")

    data_cfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=50,
                          ckpt_dir=args.ckpt_dir, log_every=10)
    out = train_loop(bundle,
                     lambda start: data_iterator(data_cfg, start),
                     loop_cfg, rng=jax.random.PRNGKey(0))
    print(f"final losses: {out['losses'][-3:]} restarts={out['restarts']}")


if __name__ == "__main__":
    main()
