"""Quickstart: HALF's hardware-aware NAS on the synthetic ECG task.

This is the paper's end-to-end flow at laptop scale: dataset -> evolutionary
hardware-aware NAS (cheap analytic objectives + trained detection rates) ->
Pareto frontier -> deployable compiled candidate (BN-folded, quantized,
with an unrolling plan and accumulator formats).

Run:  PYTHONPATH=src python examples/quickstart.py [--generations 6]
"""
import argparse
import time

import jax
import numpy as np

from repro.core.compile_model import compile_candidate
from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.genome import describe
from repro.core.trainer import init_candidate
from repro.data.ecg import make_ecg_dataset, train_val_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--generations", type=int, default=6)
    ap.add_argument("--samples", type=int, default=600)
    ap.add_argument("--train-steps", type=int, default=150)
    args = ap.parse_args()

    t0 = time.time()
    print("== generating synthetic Charité-style ECG dataset ==")
    x, y = make_ecg_dataset(seed=0, n_samples=args.samples, decimation=16)
    data_train, data_val = train_val_split(x, y)
    print(f"   {x.shape} in {time.time()-t0:.1f}s")

    cfg = NASConfig(
        generations=args.generations, children_per_gen=8, n_accept=4,
        init_population=6, train_steps=args.train_steps, train_batch=32,
        n_workers=2, seed=0,
    )
    search = EvolutionarySearch(cfg, data_train, data_val)
    state = search.run()

    print("\n== Pareto-frontier solutions per design goal (paper §VI-B) ==")
    for goal in ("low_energy", "low_power", "high_throughput"):
        sol = search.select_for_goal(state, goal)
        if sol is None:
            print(f"-- {goal}: no feasible candidate yet "
                  f"(needs more generations)")
            continue
        det = 1.0 - sol.expensive[0]
        print(f"\n-- best for {goal} "
              f"(detection={det:.3f}, false alarm={sol.expensive[1]:.3f}):")
        print(describe(sol.genome))

    sol = search.select_solution(state) or max(
        state.population, key=lambda c: -(c.expensive[0] if c.trained else 1))
    print("\n== compiling the selected candidate for deployment ==")
    specs = sol.genome.phenotype()
    params = init_candidate(jax.random.PRNGKey(0), specs)
    calib = jax.numpy.asarray(
        data_val[0][:32, ::data_val[0].shape[1] // sol.genome.input_length()]
        [:, :sol.genome.input_length()])
    compiled = compile_candidate(sol.genome, params, calib)
    print(compiled.report())
    print(f"\nestimates: min-alpha {compiled.estimate_min.throughput_sps:.0f}"
          f" samples/s @ {compiled.estimate_min.p_total_w:.2f} W | max-alpha "
          f"{compiled.estimate_max.throughput_sps:.0f} samples/s @ "
          f"{compiled.estimate_max.p_total_w:.2f} W")

    # -- serving the deployment artifact (repro.serve, DESIGN.md §12) --
    # One jitted deployment-mode forward answers batched requests; inputs
    # at full resolution are decimated to the genome's input length and
    # the batch is padded to a power of two so repeated serving reuses a
    # handful of compiled executables.  The full closed loop — winner
    # *trained to convergence* before compiling — is
    # examples/serve_winner.py.
    #
    # Token-level LM serving has a paged KV cache (DESIGN.md §15):
    # serve_winner(..., paged=True) records the preference on the handle
    # (the classifier forward itself is cache-free) and
    # launch/serve.py --engine --paged builds EngineConfig(paged=True) —
    # admission on free pool *blocks* rather than worst-case dense
    # slots, ~4x concurrency at equal memory on long-tail prompts.
    # Prefer dense slots (the default) when prompts uniformly fill
    # cache_len or an admitted request must never be OOM-shed.
    print("\n== serving batched requests through the compiled forward ==")
    from repro.core.trainer import forward
    from repro.serve import ServableWinner
    winner = ServableWinner(
        genome=sol.genome, compiled=compiled, goal=None,
        input_length=sol.genome.input_length(),
        train_meta={"detection_rate": float("nan"),
                    "false_alarm_rate": float("nan"), "val_loss": 0.0,
                    "steps": 0.0},
        _predict=jax.jit(lambda xb: forward(compiled.params, specs, xb,
                                            quant=None, train=False)))
    t = time.time()
    preds = winner.classify(data_val[0][:32])
    dt = time.time() - t
    t = time.time()
    winner.classify(data_val[0][32:64])
    dt_warm = time.time() - t
    print(f"   32-window batch: {dt*1e3:.0f} ms cold (compile), "
          f"{dt_warm*1e3:.0f} ms warm; classes={np.bincount(preds).tolist()}")

    # -- resilient serving (repro.serve.router, DESIGN.md §14) --
    # The always-on deployment fronts N replicas of the winner behind one
    # predict() that health-checks, fails over, and quarantines — here we
    # *inject* a crash on replica 0's first batch to show the failover is
    # invisible to the caller (same classes; repeated failures would
    # quarantine the replica).  The
    # token-level analogue for LM serving is ReplicaRouter
    # (launch/serve.py --router --replicas 2), chaos-tested in
    # tests/test_faults.py against a bit-identical greedy reference.
    print("\n== resilient serving: replicated winner with injected crash ==")
    from repro.core.faults import FaultPlan, FaultSpec
    from repro.serve import replicate_winner
    faults = FaultPlan([FaultSpec(site="router.dispatch", kind="crash",
                                  at=(1,))], seed=0)
    replicated = replicate_winner(winner, replicas=2, faults=faults)
    preds_rep = replicated.classify(data_val[0][:32])
    assert np.array_equal(preds, preds_rep)
    print(f"   crash injected on replica 0 -> failover; "
          f"stats={replicated.stats} (classes unchanged)")
    print(f"total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
