"""Example: batched LM serving with continuous slot reuse.

Thin wrapper over repro.launch.serve with a reduced zoo config — the same
BatchedServer the production driver uses.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] if len(sys.argv) > 1 else
         ["--arch", "qwen3-4b", "--requests", "6", "--max-new", "8"])
