"""HALF's cross-layer search over TPU implementation parameters.

Runs the paper's cheap-objective → Pareto-frontier → select loop on the
implementation genome (microbatches, q-blocking, MoE strategy, remat) for a
zoo architecture, and prints whether the analytic model reproduces the
hand-tuned §Perf configuration.

Run:  PYTHONPATH=src python examples/codesign_tpu.py --arch kimi-k2-1t-a32b
"""
import argparse

from repro.configs import ALL_ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.core.tpu_codesign import best_by_bound, enumerate_frontier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kimi-k2-1t-a32b", choices=ALL_ARCHS)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--max-act-gib", type=float, default=16.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cell = SHAPES[args.shape]
    mesh = {"data": 16, "model": 16}
    genomes, costs, front = enumerate_frontier(cfg, cell, mesh)
    print(f"{args.arch} x {args.shape}: {len(genomes)} implementation "
          f"points, frontier size {len(front)}")
    print(f"{'genome':28s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'coll_s':>10s} {'act_GiB':>8s} {'bound_s':>9s}")
    order = sorted(front, key=lambda i: costs[i].bound_s)
    for i in order[:10]:
        c = costs[i]
        print(f"{genomes[i].short():28s} {c.compute_s:10.3f} "
              f"{c.memory_s:10.3f} {c.collective_s:10.3f} "
              f"{c.act_gib:8.2f} {c.bound_s:9.3f}")
    g, c = best_by_bound(genomes, costs, front, args.max_act_gib)
    print(f"\nselected: {g.short()}  bound={c.bound_s:.3f}s")
    print(f"adopted §Perf config for comparison: "
          f"mb{cfg.microbatches}, moe={cfg.moe_impl}, remat={cfg.remat}")


if __name__ == "__main__":
    main()
