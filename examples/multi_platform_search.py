"""Multi-platform HALF search: one population, K platforms, three goals.

The paper's holistic claim, cross-platform edition (DESIGN.md §10): a single
evolutionary search scores every candidate against several hardware targets
at once (`MultiPlatformBackend`), keeps per-platform and cross-platform
Pareto fronts, and the same searched population is then steered to different
deployments by design-goal presets — low-energy, low-power,
high-throughput — without re-searching.

Run:  PYTHONPATH=src python examples/multi_platform_search.py [--generations 6]
"""
import argparse
import time

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.core.genome import describe
from repro.data.ecg import make_ecg_dataset, train_val_split

PLATFORMS = ["fpga_zu", "fpga_zcu102", "tpu_roofline"]
GOAL_PRESETS = ("low_energy", "low_power", "high_throughput")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--generations", type=int, default=6)
    ap.add_argument("--samples", type=int, default=600)
    ap.add_argument("--train-steps", type=int, default=150)
    args = ap.parse_args()

    t0 = time.time()
    print("== generating synthetic Charité-style ECG dataset ==")
    x, y = make_ecg_dataset(seed=0, n_samples=args.samples, decimation=16)
    data_train, data_val = train_val_split(x, y)

    cfg = NASConfig(
        generations=args.generations, children_per_gen=8, n_accept=4,
        init_population=6, train_steps=args.train_steps, train_batch=32,
        n_workers=2, seed=0,
        backends=PLATFORMS,          # one population, K platforms
    )
    search = EvolutionarySearch(cfg, data_train, data_val)
    print(f"== searching against {search.backend.name} "
          f"({len(search.schema)} cheap objectives) ==")
    state = search.run()

    print("\n== Pareto fronts (per platform + cross-platform) ==")
    for name, front in search.pareto_fronts(state).items():
        print(f"   {name:16s}: {len(front):3d} front members")

    print("\n== the same population, steered per design goal ==")
    for goal in GOAL_PRESETS:
        sol = search.select_for_goal(state, goal)
        if sol is None:
            print(f"-- {goal}: no feasible candidate yet "
                  f"(needs more generations)")
            continue
        det = 1.0 - sol.expensive[0]
        print(f"\n-- {goal} pick (detection={det:.3f}, "
              f"false alarm={sol.expensive[1]:.3f}):")
        # per-platform view of the pick's primary objective
        from repro.core.objective_schema import GOALS
        for platform in search.schema.platforms:
            col = search.schema.index(GOALS[goal].primary, platform=platform)
            print(f"   {platform:14s} {GOALS[goal].primary} = "
                  f"{sol.cheap[col]:.3e}")
        print(describe(sol.genome))

    print(f"\ntotal {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
