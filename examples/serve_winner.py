"""Closed loop: hardware-aware NAS -> goal winner -> compiled serving.

HALF's promise is *holistic* — the search result is not a report, it is a
deployable model.  This example runs the whole chain on the synthetic ECG
task:

1. evolutionary search (cheap analytic objectives + trained accuracy);
2. ``select_for_goal`` picks the best feasible candidate for a deployment
   goal (default: ``low_energy``);
3. ``serve_winner`` trains it to convergence and compiles the deployment
   artifact (BN-folded + quantized params, unrolling plan, accumulator
   formats);
4. the returned :class:`~repro.serve.ServableWinner` answers batched
   classification requests through one jitted deployment-mode forward —
   and its predictions are validated against held-out labels.

Run:  PYTHONPATH=src python examples/serve_winner.py [--goal low_power]
"""
import argparse
import time

import numpy as np

from repro.core.evolution import EvolutionarySearch, NASConfig
from repro.data.ecg import make_ecg_dataset, train_val_split
from repro.serve import serve_winner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--goal", default="low_energy",
                    choices=["low_energy", "low_power", "high_throughput",
                             "balanced"])
    ap.add_argument("--generations", type=int, default=4)
    ap.add_argument("--samples", type=int, default=600)
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--final-train-steps", type=int, default=400,
                    help="training budget for the served winner (more than "
                         "the search's per-candidate budget)")
    args = ap.parse_args()

    t0 = time.time()
    print("== synthetic Charité-style ECG dataset ==")
    x, y = make_ecg_dataset(seed=0, n_samples=args.samples, decimation=16)
    data_train, data_val = train_val_split(x, y)
    print(f"   {x.shape} in {time.time()-t0:.1f}s")

    print(f"\n== hardware-aware NAS ({args.generations} generations) ==")
    cfg = NASConfig(
        generations=args.generations, children_per_gen=8, n_accept=4,
        init_population=6, train_steps=args.train_steps, train_batch=32,
        n_workers=2, seed=0, goal=args.goal,
    )
    search = EvolutionarySearch(cfg, data_train, data_val)
    state = search.run()

    print(f"\n== deploying the {args.goal} winner ==")
    winner = serve_winner(search, state, args.goal,
                          data_train=data_train, data_val=data_val,
                          train_steps=args.final_train_steps,
                          train_batch=32)
    print(winner.report())

    print("\n== serving batched requests ==")
    x_va, y_va = data_val
    correct = served = 0
    for start in range(0, min(len(x_va), 128), 32):
        xb, yb = x_va[start:start + 32], y_va[start:start + 32]
        t = time.time()
        preds = winner.classify(xb)
        dt_ms = (time.time() - t) * 1e3
        correct += int((preds == yb).sum())
        served += len(yb)
        print(f"   batch of {len(yb):2d} in {dt_ms:6.1f} ms "
              f"({correct}/{served} correct so far)")
    print(f"\nserved {winner.batches_served} batches, "
          f"accuracy {correct / served:.3f} "
          f"(val det={winner.train_meta['detection_rate']:.3f} "
          f"fa={winner.train_meta['false_alarm_rate']:.3f})")
    print(f"total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
