"""Distributed runtime: logical-axis sharding rules, mesh helpers,
gradient compression, fault tolerance."""
from repro.distributed.sharding import (  # noqa: F401
    axis_rules,
    current_mesh,
    logical_constraint,
    named_sharding,
    spec_for,
    tree_shardings,
)
