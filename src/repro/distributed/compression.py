"""Gradient compression (beyond-paper, standard at 1000-node scale).

Within one SPMD step the gradient all-reduce is emitted by XLA and is not
interceptable from pjit-level code; compression therefore applies where the
framework *does* own the bytes:

* **bf16 gradient cast** — halves the accumulation buffers and, on real
  multi-slice deployments where the cross-pod reduce is DCN-mediated, halves
  that traffic (XLA reduces in the narrower type when given bf16 operands);
* **error-feedback top-k sparsification** — keeps a residual so dropped
  coordinates are re-injected next step (Stich et al. '18); used for the
  (simulated) cross-pod asynchronous sync path and exercised by tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    mode: str = "bf16"       # "bf16" | "topk" | "none"
    topk_frac: float = 0.01  # fraction of coordinates kept in topk mode


def compress_grads(grads: Any, cfg: CompressionConfig) -> Any:
    if cfg.mode == "none":
        return grads
    if cfg.mode == "bf16":
        return jax.tree_util.tree_map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    raise ValueError(f"compress_grads only handles stateless modes, "
                     f"got {cfg.mode!r}; use EFTopK for topk")


class EFTopK:
    """Error-feedback top-k: ``compress`` returns the sparsified gradient and
    the updated residual state (a pytree matching the grads)."""

    def __init__(self, frac: float = 0.01):
        self.frac = frac

    def init(self, grads: Any) -> Any:
        return jax.tree_util.tree_map(jnp.zeros_like, grads)

    def compress(self, grads: Any, residual: Any) -> Tuple[Any, Any]:
        frac = self.frac

        def one(g, r):
            acc = g + r
            flat = acc.reshape(-1)
            k = max(1, int(flat.size * frac))
            thresh_val = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            mask = jnp.abs(acc) >= thresh_val
            sent = jnp.where(mask, acc, 0.0)
            return sent, acc - sent

        pairs = jax.tree_util.tree_map(one, grads, residual)
        is_t = lambda t: isinstance(t, tuple)
        sent = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_t)
        res = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_t)
        return sent, res
