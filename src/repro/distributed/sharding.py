"""Logical-axis sharding: the bridge from model code to the physical mesh.

Model code annotates parameters and activations with *logical* axis names
("embed", "heads", "batch", ...).  The launcher installs a rule set mapping
logical names to physical mesh axes ("pod", "data", "model") for the current
run; everything composes through an ambient context so model code never
mentions physical axes.  With no rules installed (unit tests on CPU) every
annotation is a no-op.

Default mapping (DESIGN.md §5):

* ``batch``  -> ("pod", "data")   — data parallelism
* ``embed``  -> "data"            — FSDP weight sharding (all-gather per layer)
* ``heads`` / ``kv_heads`` / ``mlp`` / ``vocab`` -> "model" — tensor parallelism
* ``experts`` -> "model"          — expert parallelism
* ``layers`` / ``seq`` -> None    — unsharded by default (seq-parallel is a
  per-cell override used by the perf pass)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Physical = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def default_rules(multi_pod: bool = False) -> Dict[str, Physical]:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch_axes,
        "seq": None,
        "embed": "data",          # FSDP axis of every weight matrix
        "embed_unsharded": None,
        "heads": "model",         # TP over the flattened h*hd projection dim
        # kv projections replicate across TP ranks (kv_heads < 16 for every
        # assigned arch); KV *caches* shard their head_dim axis instead.
        "kv_heads": None,
        "head_dim": "model",
        "mlp": "model",
        "expert_mlp": None,
        "experts": "model",       # expert parallelism
        "vocab": "model",
        "layers": None,
        "layer_groups": None,
    }


@contextlib.contextmanager
def axis_rules(rules: Optional[Dict[str, Physical]], mesh: Optional[Mesh]):
    """Install (rules, mesh) for the enclosed region."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (rules, mesh)
    try:
        yield
    finally:
        _state.ctx = prev


def current_rules() -> Optional[Dict[str, Physical]]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[1] if ctx else None


def _resolve(axis: Optional[str], rules: Dict[str, Physical],
             mesh: Mesh, taken: set) -> Physical:
    """Map one logical axis; drop physical axes already used or absent."""
    if axis is None:
        return None
    phys = rules.get(axis)
    if phys is None:
        return None
    if isinstance(phys, str):
        phys = (phys,)
    usable = tuple(a for a in phys if a in mesh.axis_names and a not in taken)
    taken.update(usable)
    if not usable:
        return None
    return usable if len(usable) > 1 else usable[0]


def spec_for(logical_axes: Sequence[Optional[str]],
             rules: Optional[Dict[str, Physical]] = None,
             mesh: Optional[Mesh] = None) -> P:
    rules = rules if rules is not None else current_rules()
    mesh = mesh if mesh is not None else current_mesh()
    if rules is None or mesh is None:
        return P()
    taken: set = set()
    return P(*[_resolve(a, rules, mesh, taken) for a in logical_axes])


def named_sharding(logical_axes: Sequence[Optional[str]],
                   rules=None, mesh=None) -> Optional[NamedSharding]:
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(logical_axes, rules, mesh))


def logical_constraint(x: jax.Array, *logical_axes: Optional[str]
                       ) -> jax.Array:
    """`with_sharding_constraint` by logical names; no-op without rules."""
    rules, mesh = current_rules(), current_mesh()
    if rules is None or mesh is None:
        return x
    # trailing axes not named are unsharded
    axes = list(logical_axes) + [None] * (x.ndim - len(logical_axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, rules, mesh)))


def shardings_like(template: Any, spec_tree: Any, rules=None, mesh=None
                   ) -> Any:
    """NamedShardings for ``template``'s structure from a parallel tree of
    logical-axis tuples (tuples are leaves of ``spec_tree``)."""
    mesh = mesh if mesh is not None else current_mesh()
    rules = rules if rules is not None else current_rules()
    treedef = jax.tree_util.tree_structure(template)
    spec_leaves = treedef.flatten_up_to(spec_tree)
    shard_leaves = [
        NamedSharding(mesh, spec_for(s if s is not None else (), rules, mesh))
        for s in spec_leaves]
    return jax.tree_util.tree_unflatten(treedef, shard_leaves)


def validate_divisibility(template: Any, spec_tree: Any, rules,
                          mesh_shape: Dict[str, int]) -> list:
    """Static launch-time check: every sharded dim must divide evenly.

    Returns a list of human-readable violations (empty == valid).  Works on
    ShapeDtypeStructs + logical specs with no devices required, so configs
    are validated before any compile is attempted.
    """
    problems = []
    treedef = jax.tree_util.tree_structure(template)
    leaves = treedef.flatten_up_to(spec_tree)
    shapes = jax.tree_util.tree_leaves(template)
    names = [_path_str_safe(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(template)[0]]
    for name, sds, axes in zip(names, shapes, leaves):
        if axes is None:
            continue
        taken: set = set()
        for dim, logical in enumerate(axes):
            if logical is None or dim >= len(sds.shape):
                continue
            phys = rules.get(logical)
            if phys is None:
                continue
            if isinstance(phys, str):
                phys = (phys,)
            usable = [a for a in phys
                      if a in mesh_shape and a not in taken]
            taken.update(usable)
            total = 1
            for a in usable:
                total *= mesh_shape[a]
            if total > 1 and sds.shape[dim] % total:
                problems.append(
                    f"{name}: dim {dim} ({logical}) size {sds.shape[dim]} "
                    f"not divisible by {total} ({usable})")
    return problems


def _path_str_safe(path) -> str:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return ".".join(out)


def tree_shardings(spec_tree: Any, rules=None, mesh=None) -> Any:
    """Map a tree of logical-axis tuples to NamedShardings (for jit
    in_shardings/out_shardings)."""
    mesh = mesh if mesh is not None else current_mesh()
    rules = rules if rules is not None else current_rules()

    def one(axes):
        if axes is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for(axes, rules, mesh))

    return jax.tree_util.tree_map(
        one, spec_tree, is_leaf=lambda s: isinstance(s, tuple) or s is None)
