"""Qwen2-0.5B [arXiv:2407.10671; hf:Qwen/Qwen2-0.5B].

24L, d_model 896, 14 heads (GQA kv=2), d_ff 4864, vocab 151936.
QKV bias, tied embeddings, rope theta 1e6.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    norm="rmsnorm", act="swiglu",
    remat="full", microbatches=2,
)
