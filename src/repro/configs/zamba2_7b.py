"""Zamba2-7B [arXiv:2411.15242; hf:Zyphra/Zamba2-7B] — simplified.

81 Mamba-2 layers, d_model 3584, ssm_state 64; a SHARED attention+MLP block
(32 heads, MHA kv=32, d_ff 14336) applied after every 6 SSM layers
(13 applications + 3 tail layers).  vocab 32000.
Simplifications documented in models/hybrid.py and DESIGN.md §4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    ssm_chunk=256, conv_kernel=4,
    attn_period=6,
    norm="rmsnorm", act="swiglu",
    remat="full", microbatches=4,
)
