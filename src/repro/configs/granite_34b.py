"""Granite-34B-Code [arXiv:2405.04324; hf:ibm-granite/granite-34b-code].

88L, d_model 6144, 48 heads, MQA (kv=1), d_ff 24576, vocab 49152.
llama-style blocks per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    norm="rmsnorm", act="swiglu",
    remat="full", microbatches=16,
)
