"""Assigned input-shape cells (one set, paired with every LM-family arch)."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# fixed encoder length for enc-dec decode cells (whisper: 30 s ≈ 1500 frames
# at the stub frontend's post-conv rate; capped for cache-only cells)
ENCDEC_DECODE_ENC_LEN = 1500
