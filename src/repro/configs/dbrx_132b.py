"""DBRX (132B total) [hf:databricks/dbrx-base].

40L, d_model 6144, 48 heads (GQA kv=8), vocab 100352.
Fine-grained MoE: 16 experts, top-4, per-expert d_ff 10752.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    n_experts=16, experts_per_token=4, moe_d_ff=10752,
    rope_theta=5e5,
    norm="rmsnorm", act="swiglu",
    remat="full", microbatches=8,
    moe_impl="ep_a2a",
)
