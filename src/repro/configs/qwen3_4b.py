"""Qwen3-4B [hf:Qwen/Qwen3-4B; config per assignment].

36L, d_model 2560, 32 heads (GQA kv=8), head_dim 128 (decoupled from
d_model), d_ff 9728, vocab 151936.  qk_norm per head, no QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    qk_norm=True, rope_theta=1e6,
    norm="rmsnorm", act="swiglu",
    remat="full", microbatches=4,
)
