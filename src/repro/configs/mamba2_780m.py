"""Mamba2-780m [arXiv:2405.21060; hf:state-spaces/mamba2-780m].

48L, d_model 1536 (attention-free), vocab 50280, ssm_state 128.
d_inner = 2*d_model = 3072, head_dim 64 -> 48 SSD heads, 1 B/C group.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    ssm_chunk=256, conv_kernel=4,
    attn_period=0,
    norm="rmsnorm",
    remat="full", microbatches=2,
)
