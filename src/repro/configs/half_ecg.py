"""HALF's own architecture family (the paper's case study).

Not one fixed config: the topology comes from the NAS genome.  This module
exposes the paper's search-space defaults and the three Table-I reference
objectives for the benchmark harness.
"""
from repro.core.search_space import DEFAULT_SPACE

SPACE = DEFAULT_SPACE
TABLE1_OBJECTIVES = ("energy_max_alpha_j", "energy_min_alpha_j",
                     "power_min_alpha_w")
