"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B].

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936.
M-RoPE (3-section rotary over t/h/w position ids); the vision frontend is a
STUB — input_specs() provides precomputed patch embeddings per assignment.
head_dim 128; mrope sections (16,24,24) over the rotary half-dim.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6, tie_embeddings=True,
    frontend="vision",
    norm="rmsnorm", act="swiglu",
    remat="full", microbatches=2,
)
