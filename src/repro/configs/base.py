"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM configs;
family-specific fields are zero/None when unused.  Every assigned arch gets a
``configs/<id>.py`` exporting ``CONFIG`` built from the published numbers
(sources cited in the file).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention details ---------------------------------------------
    qk_norm: bool = False        # qwen3
    qkv_bias: bool = False       # qwen2
    rope_theta: float = 1e4
    mrope: bool = False          # qwen2-vl M-RoPE (3-section rotary)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w halves
    attn_chunk: int = 512        # KV block size of the chunked reference

    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0            # per-expert hidden width
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "sort"       # sort (pjit) | ep_a2a (shard_map a2a EP)

    # --- SSM (mamba2 / SSD) ----------------------------------------------
    ssm_state: int = 0           # N
    ssm_head_dim: int = 64       # P
    ssm_expand: int = 2          # d_inner = expand * d_model
    ssm_groups: int = 1          # B/C groups G
    ssm_chunk: int = 256         # SSD chunk length Q
    conv_kernel: int = 4

    # --- hybrid (zamba2) ---------------------------------------------------
    attn_period: int = 0         # shared attn block every `attn_period` SSM layers

    # --- enc-dec (whisper) --------------------------------------------------
    n_dec_layers: int = 0        # encoder gets n_layers
    dec_ratio: int = 8           # train/prefill decoder len = seq // dec_ratio

    # --- frontend stubs -----------------------------------------------------
    frontend: str = "none"       # none | audio | vision  (stub embeddings)

    # --- numerics / norms ----------------------------------------------------
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"      # activation/param dtype
    norm_eps: float = 1e-5

    # --- training-time knobs (per-arch defaults; launcher may override) ------
    remat: str = "full"          # full | dots | none
    chunked_loss: bool = False   # fused chunked unembed+xent (§Perf C2' —
                                 # numerically equivalent; OFF by default:
                                 # on the CPU-backend metrics the plain path
                                 # measured better; re-evaluate on TPU)
    microbatches: int = 1        # gradient-accumulation splits of global batch
    optimizer: str = "adamw"     # adamw | adafactor
    fsdp_axes: Tuple[str, ...] = ("data",)   # axes params are FSDP-sharded over
    grad_acc_dtype: str = "float32"  # microbatch grad accumulator dtype

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:            # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (assignment rule)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = 0
        if self.family in ("dense", "moe", "vlm", "hybrid"):
            # attention stack
            if self.family == "hybrid":
                n_attn = 1  # shared block counted once
                n_ssm = self.n_layers
            else:
                n_attn = self.n_layers
                n_ssm = 0
            attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    + self.n_heads * hd * d)
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
            n += n_attn * (attn + 2 * d)  # + norms
            if self.family == "moe":
                expert = 3 * d * self.moe_d_ff
                mlp = (self.n_experts + self.n_shared_experts) * expert \
                    + d * self.n_experts
                n += self.n_layers * (mlp + d)
            elif self.family == "hybrid":
                n += n_attn * 3 * d * self.d_ff  # shared MLP
                n += n_ssm * self._ssm_block_params()
            else:
                mults = 3 if self.act == "swiglu" else 2
                n += self.n_layers * (mults * d * self.d_ff + d)
        elif self.family == "ssm":
            n += self.n_layers * (self._ssm_block_params() + d)
        elif self.family == "encdec":
            attn = 4 * d * self.n_heads * hd
            mults = 3 if self.act == "swiglu" else 2
            enc = self.n_layers * (attn + mults * d * self.d_ff + 2 * d)
            dec = self.n_dec_layers * (2 * attn + mults * d * self.d_ff + 3 * d)
            n += enc + dec
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        n += d  # final norm
        return n

    def _ssm_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, ns, h = self.ssm_groups, self.ssm_state, self.ssm_heads
        in_proj = d * (2 * di + 2 * g * ns + h)
        conv = self.conv_kernel * (di + 2 * g * ns)
        extra = 3 * h  # A_log, D, dt_bias
        out_proj = di * d
        return in_proj + conv + extra + out_proj + di  # + gated norm

    def active_param_count(self) -> int:
        """Active params per token (== param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        total = self.param_count()
        inactive_experts = self.n_experts - self.experts_per_token
        per_expert = 3 * self.d_model * self.moe_d_ff
        return total - self.n_layers * inactive_experts * per_expert
