"""Kimi K2 — trillion-parameter MoE [arXiv:2501.kimi2 per assignment].

61L, d_model 7168, 64 heads (GQA kv=8), vocab 163840.
MoE: 384 experts, top-8, per-expert d_ff 2048, +1 shared expert
(DeepSeek-style).  Adafactor + bf16 params: AdamW state (12 B/param) cannot
fit 512 x 16 GB for 1T params (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    n_experts=384, experts_per_token=8, moe_d_ff=2048,
    n_shared_experts=1,
    rope_theta=5e4,
    norm="rmsnorm", act="swiglu",
    remat="full", microbatches=4,  # B3: halves FSDP weight AG/RS rounds
    optimizer="adafactor",
    grad_acc_dtype="bfloat16",  # f32 accumulators would add 4 TB
    fsdp_axes=("pod", "data"),
    moe_impl="ep_a2a",
)
