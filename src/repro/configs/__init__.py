"""Config registry: ``get_config(arch_id)`` + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeCell  # noqa: F401

ARCH_MODULES: Dict[str, str] = {
    "qwen2-0.5b": "qwen2_0_5b",
    "qwen3-4b": "qwen3_4b",
    "granite-34b": "granite_34b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "mamba2-780m": "mamba2_780m",
    "zamba2-7b": "zamba2_7b",
    "whisper-tiny": "whisper_tiny",
}

ALL_ARCHS: List[str] = list(ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ALL_ARCHS}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.CONFIG


def reduced_config(arch: str, dtype: str = "float32") -> ModelConfig:
    """Same-family reduced config for CPU smoke tests: few layers, narrow
    widths, tiny vocab — exercises every code path the full config uses."""
    cfg = get_config(arch)
    n_groups = 2 if cfg.attn_period else 0
    changes = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, (n_groups * cfg.attn_period + 1)
                     if cfg.attn_period else 3),
        n_dec_layers=min(cfg.n_dec_layers, 2),
        d_model=128,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        n_kv_heads=0,
        head_dim=32 if cfg.head_dim else 0,
        d_ff=192 if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        attn_chunk=64,
        dtype=dtype,
        remat="none",
        microbatches=1,
        mrope_sections=(4, 6, 6) if cfg.mrope else cfg.mrope_sections,
    )
    if cfg.n_heads:
        # preserve the GQA ratio class: MQA stays MQA, MHA stays MHA
        if cfg.n_kv_heads == 1:
            changes["n_kv_heads"] = 1
        elif cfg.n_kv_heads == cfg.n_heads:
            changes["n_kv_heads"] = changes["n_heads"]
        else:
            changes["n_kv_heads"] = max(changes["n_heads"] // 2, 1)
    return dataclasses.replace(cfg, **changes)
