"""Whisper-tiny [arXiv:2212.04356; hf:openai/whisper-tiny].

Encoder 4L + decoder 4L, d_model 384, 6 heads (MHA kv=6), d_ff 1536,
vocab 51865.  Conv frontend is a STUB: input_specs() provides post-conv
frame embeddings.  GELU MLP, LayerNorm, sinusoidal positions, tied decoder
embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_dec_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    frontend="audio", dec_ratio=8,
    norm="layernorm", act="gelu", tie_embeddings=True,
    remat="none", microbatches=1,
)
