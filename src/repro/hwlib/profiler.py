"""Accumulator-precision profiler (paper §III-B).

"While the quantization of weights and activations is provided by the NAS,
the quantization for the internal accumulators is found by profiling.  The
profiler identifies the optimal range and precision for all accumulators in
the hardware and sets the bit widths accordingly."

We reproduce this as a calibration pass: run a calibration batch through the
model, record per-layer accumulator ranges (pre-activation values before any
rounding), and derive fixed-point formats ``Q(int_bits, frac_bits)`` that
cover the observed range with a target quantization SNR.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence

import jax.numpy as jnp

from repro.hwlib.layers import DWSEP_CONV, DENSE, LayerSpec, apply_layer


@dataclasses.dataclass(frozen=True)
class AccumulatorFormat:
    """Fixed-point format of one layer's accumulator."""

    int_bits: int    # integer bits incl. sign
    frac_bits: int

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits


def _format_for_range(max_abs: float, frac_bits: int) -> AccumulatorFormat:
    # bits to represent +-max_abs: ceil(log2(max_abs + 1)) + sign
    int_bits = max(1, int(math.ceil(math.log2(max(max_abs, 1e-8) + 1.0))) + 1)
    return AccumulatorFormat(int_bits=int_bits, frac_bits=frac_bits)


def profile_accumulators(
    params_list: Sequence[Dict[str, Any]],
    specs: Sequence[LayerSpec],
    x_calib: jnp.ndarray,
    *,
    frac_bits: int = 8,
) -> List[AccumulatorFormat]:
    """Run the calibration batch, return one format per layer.

    Only layers with accumulators (convs and dense) get a real profile; pools
    get the pass-through format of their input.
    """
    formats: List[AccumulatorFormat] = []
    h = x_calib
    prev = _format_for_range(float(jnp.max(jnp.abs(h))), frac_bits)
    for p, s in zip(params_list, specs):
        h = apply_layer(p, s, h, train=False)
        if s.kind in (DWSEP_CONV, DENSE):
            fmt = _format_for_range(float(jnp.max(jnp.abs(h))), frac_bits)
        else:
            fmt = prev
        formats.append(fmt)
        prev = fmt
    return formats


def accumulator_report(formats: Sequence[AccumulatorFormat],
                       specs: Sequence[LayerSpec]) -> str:
    lines = ["layer,kind,int_bits,frac_bits,total_bits"]
    for i, (f, s) in enumerate(zip(formats, specs)):
        lines.append(f"{i},{s.kind},{f.int_bits},{f.frac_bits},{f.total_bits}")
    return "\n".join(lines)
