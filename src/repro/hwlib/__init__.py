"""Parametrizable layer library — the TPU analogue of HALF's HLS hardware library.

Each layer couples a JAX forward implementation with an analytic cost model
(MACs/output, pipeline fill depth, parameter count) so the NAS can score
candidates without compiling them.  See DESIGN.md §2 for the FPGA→TPU mapping.
"""
from repro.hwlib.layers import (  # noqa: F401
    LayerCost,
    LayerCostArrays,
    LayerSpec,
    OpCostTable,
    apply_layer,
    batch_layer_costs,
    init_layer,
    layer_cost,
    out_shape,
)
