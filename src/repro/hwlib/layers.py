"""Layer library: forward implementations paired with analytic cost models.

Layout convention: activations are ``(batch, length, channels)`` float32 (the
NAS trains small candidates) with optional fake quantization applied around
each layer (see :mod:`repro.hwlib.quant`).

The cost model mirrors the paper's hardware library semantics (§IV/§V):

* ``n_in``  — number of input values needed before the layer can emit its
  first output (pipeline fill; kernel size for convolutions).
* ``l``     — cycles to produce one output *position* at unrolling factor
  α = 1 (== MACs per output position, one MAC unit).
* unrolling α divides ``l`` (spatial parallelism over the dot products),
  bounded by ``alpha_max`` = MACs per output position.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# Layer kinds understood by the library.
DWSEP_CONV = "dwsep_conv"  # depthwise-separable 1D convolution (+BN+ReLU)
MAXPOOL = "maxpool"        # 1D max pooling, window == stride
GLOBALPOOL = "globalpool"  # global average pooling over length
DENSE = "dense"            # fully connected head


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """A fully parametrized layer instance (one gene's phenotype)."""

    kind: str
    out_channels: int = 0   # dw-sep conv / dense
    kernel_size: int = 1    # dw-sep conv
    stride: int = 1         # dw-sep conv / maxpool
    use_bn: bool = True     # dw-sep conv only

    def short(self) -> str:
        if self.kind == DWSEP_CONV:
            return f"dw{self.kernel_size}s{self.stride}c{self.out_channels}"
        if self.kind == MAXPOOL:
            return f"mp{self.stride}"
        if self.kind == GLOBALPOOL:
            return "gap"
        return f"fc{self.out_channels}"

    def signature(self) -> Tuple:
        """The static fields that determine this layer's compiled kernel:
        parameter shapes, slice strides and the BN branch all derive from
        these, so two layers with equal signatures trace to the same jaxpr
        (the per-candidate bucketing key of the batched trainer)."""
        return (self.kind, self.out_channels, self.kernel_size, self.stride,
                self.use_bn)


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Analytic per-layer quantities consumed by the Eq.1-4 models."""

    n_in: int           # values to fill the input buffer (Eq. 1: n_in,j)
    l_cycles: float     # latency (cycles) to produce one output position, α=1
    n_out: int          # number of output positions the layer produces
    macs_per_out: int   # MACs per output position (== alpha_max)
    total_macs: int     # n_out * macs_per_out
    params: int         # parameter count (weights + bias, BN folded)
    out_len: int
    out_channels: int

    @property
    def alpha_max(self) -> int:
        return max(1, self.macs_per_out)


# ---------------------------------------------------------------------------
# Shape / cost analysis (pure python — cheap objectives must not trace JAX)
# ---------------------------------------------------------------------------

def out_shape(spec: LayerSpec, in_len: int, in_ch: int) -> Tuple[int, int]:
    """(out_len, out_channels) for a layer applied to (in_len, in_ch)."""
    if spec.kind == DWSEP_CONV:
        if in_len < spec.kernel_size:
            raise ValueError(
                f"input length {in_len} < kernel {spec.kernel_size}")
        out_len = (in_len - spec.kernel_size) // spec.stride + 1
        return out_len, spec.out_channels
    if spec.kind == MAXPOOL:
        if in_len < spec.stride:
            raise ValueError(f"input length {in_len} < pool {spec.stride}")
        return in_len // spec.stride, in_ch
    if spec.kind == GLOBALPOOL:
        return 1, in_ch
    if spec.kind == DENSE:
        return 1, spec.out_channels
    raise ValueError(spec.kind)


def layer_cost(spec: LayerSpec, in_len: int, in_ch: int) -> LayerCost:
    out_len, out_ch = out_shape(spec, in_len, in_ch)
    if spec.kind == DWSEP_CONV:
        # depthwise: K MACs per channel, pointwise: C_in MACs per out channel.
        macs = spec.kernel_size * in_ch + in_ch * out_ch
        params = spec.kernel_size * in_ch + in_ch * out_ch + out_ch  # +bias
        n_in = spec.kernel_size
    elif spec.kind == MAXPOOL:
        macs = spec.stride * in_ch  # comparisons ~ MAC-equivalents
        params = 0
        n_in = spec.stride
    elif spec.kind == GLOBALPOOL:
        macs = in_len * in_ch  # running sum — counted once for its single out
        params = 0
        n_in = in_len
    else:  # DENSE
        macs = in_ch * out_ch
        params = in_ch * out_ch + out_ch
        n_in = in_ch
    return LayerCost(
        n_in=n_in,
        l_cycles=float(macs),
        n_out=out_len,
        macs_per_out=macs,
        total_macs=out_len * macs,
        params=params,
        out_len=out_len,
        out_channels=out_ch,
    )


# ---------------------------------------------------------------------------
# Batched (population-wide) cost tabulation — DESIGN.md §2
# ---------------------------------------------------------------------------

# Integer kind codes for vectorized dispatch (order is arbitrary but fixed).
KIND_CODES = {DWSEP_CONV: 0, MAXPOOL: 1, GLOBALPOOL: 2, DENSE: 3}


@dataclasses.dataclass(frozen=True)
class OpCostTable:
    """Static per-op cost coefficients of an op catalogue, as arrays.

    Indexed by op id.  Every :class:`LayerCost` quantity of every op kind is
    an affine function of the running input ``(length, channels)`` state::

        out_len  = (length - (ek_const + ek_is_len*length)) // es + 1
        out_ch   = oc_const + oc_is_ch * channels
        macs     = macs_c * channels + macs_lc * length * channels
        params   = p_const + p_ch * channels
        n_in     = ni_const + ni_is_len*length + ni_is_ch*channels

    so a population's costs tabulate as one gather per coefficient plus flat
    vectorized arithmetic — no per-kind branching in the hot loop.
    """

    kind: np.ndarray        # (n_ops,) int64 — KIND_CODES value
    ek_const: np.ndarray    # effective window: conv kernel / pool stride
    ek_is_len: np.ndarray   # 1 where the window is the whole input (gap/fc)
    es: np.ndarray          # output stride
    macs_c: np.ndarray      # MACs per output position, per input channel
    macs_lc: np.ndarray     # ... per input value (gap running sum)
    p_const: np.ndarray     # params independent of input channels (bias)
    p_ch: np.ndarray        # params per input channel
    ni_const: np.ndarray    # pipeline-fill values (Eq. 1 n_in), constant part
    ni_is_len: np.ndarray   # 1 where n_in == input length (gap)
    ni_is_ch: np.ndarray    # 1 where n_in == input channels (dense)
    oc_const: np.ndarray    # output channels, constant part (conv/dense)
    oc_is_ch: np.ndarray    # 1 where channels pass through (pool/gap)

    @classmethod
    def from_specs(cls, specs: Sequence[LayerSpec]) -> "OpCostTable":
        rows = []
        for s in specs:
            k, st, och = s.kernel_size, s.stride, s.out_channels
            code = KIND_CODES.get(s.kind)
            if s.kind == DWSEP_CONV:
                rows.append((code, k, 0, st, k + och, 0, och, k + och,
                             k, 0, 0, och, 0))
            elif s.kind == MAXPOOL:
                rows.append((code, st, 0, st, st, 0, 0, 0, st, 0, 0, 0, 1))
            elif s.kind == GLOBALPOOL:
                rows.append((code, 0, 1, 1, 0, 1, 0, 0, 0, 1, 0, 0, 1))
            elif s.kind == DENSE:
                rows.append((code, 0, 1, 1, och, 0, och, och, 0, 0, 1,
                             och, 0))
            else:
                raise ValueError(s.kind)
        cols = np.asarray(rows, np.int64).T
        return cls(*cols)


@dataclasses.dataclass(frozen=True)
class LayerCostArrays:
    """:class:`LayerCost` for a whole population, as ``(N, T)`` arrays.

    ``T`` is the padded phenotype length (max searchable depth + GAP + dense
    head); padded positions are masked out by ``valid`` and hold zeros.  All
    quantities match the scalar :func:`layer_cost` exactly on valid slots.
    """

    n_in: np.ndarray          # (N, T) int64
    l_cycles: np.ndarray      # (N, T) float64
    n_out: np.ndarray         # (N, T) int64
    macs_per_out: np.ndarray  # (N, T) int64
    total_macs: np.ndarray    # (N, T) int64
    params: np.ndarray        # (N, T) int64
    out_len: np.ndarray       # (N, T) int64
    out_channels: np.ndarray  # (N, T) int64
    valid: np.ndarray         # (N, T) bool
    n_layers: np.ndarray      # (N,)  int64 — valid layer count per genome

    @property
    def alpha_max(self) -> np.ndarray:
        return np.maximum(1, self.macs_per_out)

    @property
    def last_index(self) -> np.ndarray:
        """Column index of each genome's final (dense head) layer."""
        return self.n_layers - 1

    def __len__(self) -> int:
        return self.n_in.shape[0]


def batch_layer_costs(table: OpCostTable, ops: np.ndarray, valid: np.ndarray,
                      in_len: np.ndarray, in_ch: int = 2) -> LayerCostArrays:
    """Vectorized shape/cost propagation for a padded population.

    ``ops`` is ``(N, T)`` op ids into ``table`` (``-1``-padded), ``valid`` the
    matching mask, ``in_len`` the ``(N,)`` input lengths.  The layer axis is
    walked sequentially (T is tiny); each step is vectorized over the
    population.  Callers must pass pre-validated genomes: shapes are computed
    with the scalar rules but nothing raises on a degenerate layer.
    """
    n, t_pad = ops.shape
    safe = np.maximum(ops, 0)
    ek = table.ek_const[safe]
    ekl = table.ek_is_len[safe]
    es = table.es[safe]
    occ = table.oc_const[safe]
    occh = table.oc_is_ch[safe]
    # sequential part: only the (length, channels) trajectory is recurrent
    l_in = np.empty((n, t_pad), np.int64)
    c_in = np.empty((n, t_pad), np.int64)
    o_len = np.empty((n, t_pad), np.int64)
    length = in_len.astype(np.int64)
    ch = np.full(n, in_ch, np.int64)
    for t in range(t_pad):
        l_in[:, t] = length
        c_in[:, t] = ch
        out_len = (length - (ek[:, t] + ekl[:, t] * length)) // es[:, t] + 1
        out_ch = occ[:, t] + occh[:, t] * ch
        o_len[:, t] = out_len
        v = valid[:, t]
        length = np.where(v, out_len, length)
        ch = np.where(v, out_ch, ch)
    # flat part: every cost column is affine in the recorded trajectory
    vi = valid.astype(np.int64)
    o_len *= vi
    macs = (table.macs_c[safe] * c_in
            + table.macs_lc[safe] * l_in * c_in) * vi
    return LayerCostArrays(
        n_in=(table.ni_const[safe] + table.ni_is_len[safe] * l_in
              + table.ni_is_ch[safe] * c_in) * vi,
        l_cycles=macs.astype(np.float64),
        n_out=o_len,
        macs_per_out=macs,
        total_macs=o_len * macs,
        params=(table.p_const[safe] + table.p_ch[safe] * c_in) * vi,
        out_len=o_len,
        out_channels=(occ + occh * c_in) * vi,
        valid=valid,
        n_layers=valid.sum(axis=1).astype(np.int64),
    )


# ---------------------------------------------------------------------------
# Parameters & forward
# ---------------------------------------------------------------------------

def init_layer(rng: jax.Array, spec: LayerSpec, in_ch: int) -> Dict[str, Any]:
    """He-style init. Returns {} for parameter-free layers."""
    if spec.kind == DWSEP_CONV:
        k_dw, k_pw, _ = jax.random.split(rng, 3)
        fan_dw = spec.kernel_size
        fan_pw = in_ch
        params: Dict[str, Any] = {
            "dw": jax.random.normal(k_dw, (spec.kernel_size, in_ch),
                                    jnp.float32) * math.sqrt(2.0 / fan_dw),
            "pw": jax.random.normal(k_pw, (in_ch, spec.out_channels),
                                    jnp.float32) * math.sqrt(2.0 / fan_pw),
            "b": jnp.zeros((spec.out_channels,), jnp.float32),
        }
        if spec.use_bn:
            params["bn_scale"] = jnp.ones((spec.out_channels,), jnp.float32)
            params["bn_bias"] = jnp.zeros((spec.out_channels,), jnp.float32)
            # running stats are updated outside jit during training
            params["bn_mean"] = jnp.zeros((spec.out_channels,), jnp.float32)
            params["bn_var"] = jnp.ones((spec.out_channels,), jnp.float32)
        return params
    if spec.kind == DENSE:
        k_w, _ = jax.random.split(rng)
        return {
            "w": jax.random.normal(k_w, (in_ch, spec.out_channels),
                                   jnp.float32) * math.sqrt(1.0 / in_ch),
            "b": jnp.zeros((spec.out_channels,), jnp.float32),
        }
    return {}


def _depthwise_conv1d(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """x: (B, L, C), w: (K, C) -> (B, L_out, C). VALID padding."""
    k = w.shape[0]
    l_out = (x.shape[1] - k) // stride + 1
    # Gather K strided views and contract — compiles to K fused mul-adds,
    # matching the hardware library's shift-register formulation.
    acc = jnp.zeros((x.shape[0], l_out, x.shape[2]), x.dtype)
    for i in range(k):
        sl = jax.lax.slice_in_dim(x, i, i + (l_out - 1) * stride + 1, stride, 1)
        acc = acc + sl * w[i]
    return acc


def apply_layer(
    params: Dict[str, Any],
    spec: LayerSpec,
    x: jnp.ndarray,
    *,
    train: bool = False,
) -> jnp.ndarray:
    """Forward one layer. x: (B, L, C) except DENSE, which takes (B, C)."""
    if spec.kind == DWSEP_CONV:
        h = _depthwise_conv1d(x, params["dw"], spec.stride)
        h = jnp.einsum("blc,cd->bld", h, params["pw"]) + params["b"]
        # BN-folded params drop the bn_* keys: the spec may still say use_bn
        if spec.use_bn and "bn_scale" in params:
            if train:
                mean = jnp.mean(h, axis=(0, 1))
                var = jnp.var(h, axis=(0, 1))
            else:
                mean, var = params["bn_mean"], params["bn_var"]
            h = (h - mean) * jax.lax.rsqrt(var + 1e-5)
            h = h * params["bn_scale"] + params["bn_bias"]
        return jax.nn.relu(h)
    if spec.kind == MAXPOOL:
        s = spec.stride
        l_out = x.shape[1] // s
        h = x[:, : l_out * s].reshape(x.shape[0], l_out, s, x.shape[2])
        return jnp.max(h, axis=2)
    if spec.kind == GLOBALPOOL:
        return jnp.mean(x, axis=1)  # (B, C)
    if spec.kind == DENSE:
        return x @ params["w"] + params["b"]
    raise ValueError(spec.kind)
