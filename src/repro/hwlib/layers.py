"""Layer library: forward implementations paired with analytic cost models.

Layout convention: activations are ``(batch, length, channels)`` float32 (the
NAS trains small candidates) with optional fake quantization applied around
each layer (see :mod:`repro.hwlib.quant`).

The cost model mirrors the paper's hardware library semantics (§IV/§V):

* ``n_in``  — number of input values needed before the layer can emit its
  first output (pipeline fill; kernel size for convolutions).
* ``l``     — cycles to produce one output *position* at unrolling factor
  α = 1 (== MACs per output position, one MAC unit).
* unrolling α divides ``l`` (spatial parallelism over the dot products),
  bounded by ``alpha_max`` = MACs per output position.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# Layer kinds understood by the library.
DWSEP_CONV = "dwsep_conv"  # depthwise-separable 1D convolution (+BN+ReLU)
MAXPOOL = "maxpool"        # 1D max pooling, window == stride
GLOBALPOOL = "globalpool"  # global average pooling over length
DENSE = "dense"            # fully connected head


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """A fully parametrized layer instance (one gene's phenotype)."""

    kind: str
    out_channels: int = 0   # dw-sep conv / dense
    kernel_size: int = 1    # dw-sep conv
    stride: int = 1         # dw-sep conv / maxpool
    use_bn: bool = True     # dw-sep conv only

    def short(self) -> str:
        if self.kind == DWSEP_CONV:
            return f"dw{self.kernel_size}s{self.stride}c{self.out_channels}"
        if self.kind == MAXPOOL:
            return f"mp{self.stride}"
        if self.kind == GLOBALPOOL:
            return "gap"
        return f"fc{self.out_channels}"


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Analytic per-layer quantities consumed by the Eq.1-4 models."""

    n_in: int           # values to fill the input buffer (Eq. 1: n_in,j)
    l_cycles: float     # latency (cycles) to produce one output position, α=1
    n_out: int          # number of output positions the layer produces
    macs_per_out: int   # MACs per output position (== alpha_max)
    total_macs: int     # n_out * macs_per_out
    params: int         # parameter count (weights + bias, BN folded)
    out_len: int
    out_channels: int

    @property
    def alpha_max(self) -> int:
        return max(1, self.macs_per_out)


# ---------------------------------------------------------------------------
# Shape / cost analysis (pure python — cheap objectives must not trace JAX)
# ---------------------------------------------------------------------------

def out_shape(spec: LayerSpec, in_len: int, in_ch: int) -> Tuple[int, int]:
    """(out_len, out_channels) for a layer applied to (in_len, in_ch)."""
    if spec.kind == DWSEP_CONV:
        if in_len < spec.kernel_size:
            raise ValueError(
                f"input length {in_len} < kernel {spec.kernel_size}")
        out_len = (in_len - spec.kernel_size) // spec.stride + 1
        return out_len, spec.out_channels
    if spec.kind == MAXPOOL:
        if in_len < spec.stride:
            raise ValueError(f"input length {in_len} < pool {spec.stride}")
        return in_len // spec.stride, in_ch
    if spec.kind == GLOBALPOOL:
        return 1, in_ch
    if spec.kind == DENSE:
        return 1, spec.out_channels
    raise ValueError(spec.kind)


def layer_cost(spec: LayerSpec, in_len: int, in_ch: int) -> LayerCost:
    out_len, out_ch = out_shape(spec, in_len, in_ch)
    if spec.kind == DWSEP_CONV:
        # depthwise: K MACs per channel, pointwise: C_in MACs per out channel.
        macs = spec.kernel_size * in_ch + in_ch * out_ch
        params = spec.kernel_size * in_ch + in_ch * out_ch + out_ch  # +bias
        n_in = spec.kernel_size
    elif spec.kind == MAXPOOL:
        macs = spec.stride * in_ch  # comparisons ~ MAC-equivalents
        params = 0
        n_in = spec.stride
    elif spec.kind == GLOBALPOOL:
        macs = in_len * in_ch  # running sum — counted once for its single out
        params = 0
        n_in = in_len
    else:  # DENSE
        macs = in_ch * out_ch
        params = in_ch * out_ch + out_ch
        n_in = in_ch
    return LayerCost(
        n_in=n_in,
        l_cycles=float(macs),
        n_out=out_len,
        macs_per_out=macs,
        total_macs=out_len * macs,
        params=params,
        out_len=out_len,
        out_channels=out_ch,
    )


# ---------------------------------------------------------------------------
# Parameters & forward
# ---------------------------------------------------------------------------

def init_layer(rng: jax.Array, spec: LayerSpec, in_ch: int) -> Dict[str, Any]:
    """He-style init. Returns {} for parameter-free layers."""
    if spec.kind == DWSEP_CONV:
        k_dw, k_pw, _ = jax.random.split(rng, 3)
        fan_dw = spec.kernel_size
        fan_pw = in_ch
        params: Dict[str, Any] = {
            "dw": jax.random.normal(k_dw, (spec.kernel_size, in_ch),
                                    jnp.float32) * math.sqrt(2.0 / fan_dw),
            "pw": jax.random.normal(k_pw, (in_ch, spec.out_channels),
                                    jnp.float32) * math.sqrt(2.0 / fan_pw),
            "b": jnp.zeros((spec.out_channels,), jnp.float32),
        }
        if spec.use_bn:
            params["bn_scale"] = jnp.ones((spec.out_channels,), jnp.float32)
            params["bn_bias"] = jnp.zeros((spec.out_channels,), jnp.float32)
            # running stats are updated outside jit during training
            params["bn_mean"] = jnp.zeros((spec.out_channels,), jnp.float32)
            params["bn_var"] = jnp.ones((spec.out_channels,), jnp.float32)
        return params
    if spec.kind == DENSE:
        k_w, _ = jax.random.split(rng)
        return {
            "w": jax.random.normal(k_w, (in_ch, spec.out_channels),
                                   jnp.float32) * math.sqrt(1.0 / in_ch),
            "b": jnp.zeros((spec.out_channels,), jnp.float32),
        }
    return {}


def _depthwise_conv1d(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    """x: (B, L, C), w: (K, C) -> (B, L_out, C). VALID padding."""
    k = w.shape[0]
    l_out = (x.shape[1] - k) // stride + 1
    # Gather K strided views and contract — compiles to K fused mul-adds,
    # matching the hardware library's shift-register formulation.
    acc = jnp.zeros((x.shape[0], l_out, x.shape[2]), x.dtype)
    for i in range(k):
        sl = jax.lax.slice_in_dim(x, i, i + (l_out - 1) * stride + 1, stride, 1)
        acc = acc + sl * w[i]
    return acc


def apply_layer(
    params: Dict[str, Any],
    spec: LayerSpec,
    x: jnp.ndarray,
    *,
    train: bool = False,
) -> jnp.ndarray:
    """Forward one layer. x: (B, L, C) except DENSE, which takes (B, C)."""
    if spec.kind == DWSEP_CONV:
        h = _depthwise_conv1d(x, params["dw"], spec.stride)
        h = jnp.einsum("blc,cd->bld", h, params["pw"]) + params["b"]
        # BN-folded params drop the bn_* keys: the spec may still say use_bn
        if spec.use_bn and "bn_scale" in params:
            if train:
                mean = jnp.mean(h, axis=(0, 1))
                var = jnp.var(h, axis=(0, 1))
            else:
                mean, var = params["bn_mean"], params["bn_var"]
            h = (h - mean) * jax.lax.rsqrt(var + 1e-5)
            h = h * params["bn_scale"] + params["bn_bias"]
        return jax.nn.relu(h)
    if spec.kind == MAXPOOL:
        s = spec.stride
        l_out = x.shape[1] // s
        h = x[:, : l_out * s].reshape(x.shape[0], l_out, s, x.shape[2])
        return jnp.max(h, axis=2)
    if spec.kind == GLOBALPOOL:
        return jnp.mean(x, axis=1)  # (B, C)
    if spec.kind == DENSE:
        return x @ params["w"] + params["b"]
    raise ValueError(spec.kind)
