"""Quantization utilities: fake-quant, batchnorm folding.

The paper's NAS search space includes the quantization of inputs, weights and
feature maps (§III-A); accumulator precision is set post-hoc by the profiler
(§III-B, :mod:`repro.hwlib.profiler`).  We implement symmetric fixed-point
fake quantization with straight-through gradients, which is both trainable
(QAT) and directly interpretable as bit widths of the hardware datapath.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.hwlib.layers import DWSEP_CONV, LayerSpec


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Bit widths for the three fake-quantized tensor classes."""

    weight_bits: int = 8
    act_bits: int = 8
    input_bits: int = 8

    def short(self) -> str:
        return f"w{self.weight_bits}a{self.act_bits}i{self.input_bits}"


def fake_quant(x: jnp.ndarray, bits, *, per_channel_axis: int | None = None
               ) -> jnp.ndarray:
    """Symmetric fake quantization with a straight-through estimator.

    ``bits <= 0`` or ``bits >= 32`` disables quantization (identity).

    ``bits`` may be a Python int (static — the branch above resolves at
    trace time) or a traced scalar (the vmap-stacked batched trainer maps
    over per-candidate bit widths, DESIGN.md §9).  The traced path computes
    the same f32 values as the static one for the searchable widths and
    realises the disable rule with ``jnp.where``, so it stays vmap-clean.
    """
    if isinstance(bits, (int, np.integer)):
        if bits <= 0 or bits >= 32:
            return x
        qmax = 2.0 ** (int(bits) - 1) - 1.0
        disabled = None
    else:
        b = jnp.asarray(bits).astype(jnp.float32)
        qmax = 2.0 ** (b - 1.0) - 1.0
        disabled = (b <= 0.0) | (b >= 32.0)
    if per_channel_axis is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    else:
        axes = tuple(i for i in range(x.ndim) if i != per_channel_axis)
        scale = jnp.maximum(jnp.max(jnp.abs(x), axis=axes, keepdims=True),
                            1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale
    # straight-through: forward q, backward identity
    out = x + jax.lax.stop_gradient(q - x)
    if disabled is not None:
        out = jnp.where(disabled, x, out)
    return out


def quantize_layer_params(params: Dict[str, Any], spec: LayerSpec,
                          cfg: QuantConfig) -> Dict[str, Any]:
    """Apply weight fake-quant to a layer's parameter dict."""
    out = dict(params)
    for name in ("dw", "pw", "w"):
        if name in out:
            out[name] = fake_quant(out[name], cfg.weight_bits,
                                   per_channel_axis=out[name].ndim - 1)
    return out


def fold_batchnorm(params: Dict[str, Any], spec: LayerSpec) -> Dict[str, Any]:
    """Fold BN running stats into the pointwise conv weights + bias.

    Paper §III-A: "preprocessing and tuning techniques such as
    batchnorm-folding are applied to further compress the model" before the
    topology is handed to the implementation framework.  After folding the
    layer computes ``relu(dw/pw conv + b')`` with no BN at inference.
    """
    if spec.kind != DWSEP_CONV or "bn_scale" not in params:
        return params
    scale = params["bn_scale"] * jax.lax.rsqrt(params["bn_var"] + 1e-5)
    folded = {
        "dw": params["dw"],
        "pw": params["pw"] * scale[None, :],
        "b": (params["b"] - params["bn_mean"]) * scale + params["bn_bias"],
    }
    return folded


def fold_model(params_list, specs) -> list:
    """Fold BN for every layer of a decoded candidate."""
    return [fold_batchnorm(p, s) for p, s in zip(params_list, specs)]
