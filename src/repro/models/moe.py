"""Routed mixture-of-experts with sort-based, capacity-bounded dispatch.

Static-shape, jit/SPMD-safe dispatch (the standard TPU formulation):

1. top-k routing per token;
2. stable-sort the (token, expert) pairs by expert id;
3. position-in-segment (cumsum of per-expert counts) gives each pair a slot
   in a fixed ``(E, capacity, D)`` buffer — overflow tokens are dropped
   (their contribution falls back to the residual stream);
4. batched expert FFN: ``einsum('ecd,edf->ecf')`` — the contraction the
   Pallas ``moe_gmm`` kernel implements on TPU;
5. scatter-add results back, weighted by the (renormalized) router gates.

Expert weights carry the ``experts`` logical axis so expert parallelism maps
them over the ``model`` mesh axis.  An auxiliary load-balance loss (Switch
style) is returned for training.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models.common import KeyGen, dense_init


def init_moe(key: jax.Array, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    kg = KeyGen(key)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p = {
        "router": dense_init(kg(), (d, e), d),
        "gate": dense_init(kg(), (e, d, f), d),
        "up": dense_init(kg(), (e, d, f), d),
        "down": dense_init(kg(), (e, f, d), f),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["shared_gate"] = dense_init(kg(), (d, fs), d)
        p["shared_up"] = dense_init(kg(), (d, fs), d)
        p["shared_down"] = dense_init(kg(), (fs, d), fs)
    return p


def moe_specs(cfg: ModelConfig, prefix: Tuple = ()) -> Dict[str, Tuple]:
    p = {
        "router": prefix + ("embed", None),
        "gate": prefix + ("experts", "embed", "expert_mlp"),
        "up": prefix + ("experts", "embed", "expert_mlp"),
        "down": prefix + ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared_experts:
        p["shared_gate"] = prefix + ("embed", "mlp")
        p["shared_up"] = prefix + ("embed", "mlp")
        p["shared_down"] = prefix + ("mlp", "embed")
    return p


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = math.ceil(n_tokens * cfg.experts_per_token / cfg.n_experts
                    * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def moe_block(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    from repro.distributed.sharding import current_mesh, current_rules
    mesh, rules = current_mesh(), current_rules()
    if (cfg.moe_impl == "ep_a2a" and mesh is not None and rules is not None
            and x.shape[1] % mesh.shape.get(
                rules.get("experts") or "", 1) == 0):
        return moe_block_ep(p, x, cfg, mesh, rules)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)                # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    me = probs.mean(axis=0)                                 # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[experts.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------
    cap = expert_capacity(t, cfg)
    flat_e = experts.reshape(-1)                            # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    token_idx = order // k
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    seg_start = jnp.cumsum(counts) - counts                 # (E,)
    pos = jnp.arange(t * k, dtype=jnp.int32) - seg_start[sorted_e]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)

    xt = jnp.where(keep[:, None], xf[token_idx], 0)         # (T*k, D)
    buf = jnp.zeros((e, cap, d), x.dtype).at[sorted_e, pos_c].add(xt)

    # ---- expert FFN (the moe_gmm contraction) ---------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               p["gate"].astype(x.dtype))) \
        * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(x.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))

    # ---- combine ---------------------------------------------------------
    vals = out_buf[sorted_e, pos_c]                         # (T*k, D)
    gates_sorted = gates.reshape(-1)[order].astype(x.dtype)
    contrib = jnp.where(keep[:, None], vals * gates_sorted[:, None], 0)
    y = jnp.zeros((t, d), x.dtype).at[token_idx].add(contrib)

    if cfg.n_shared_experts:
        hs = jax.nn.silu(xf @ p["shared_gate"].astype(x.dtype)) \
            * (xf @ p["shared_up"].astype(x.dtype))
        y = y + hs @ p["shared_down"].astype(x.dtype)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (§Perf iteration B2 — beyond-paper)
# ---------------------------------------------------------------------------
#
# The pjit sort-based dispatch above is correct but the SPMD partitioner
# lowers its data-dependent scatter/gather as replicate + all-reduce of the
# full (T, D) token buffer PER LAYER (measured: 7.5 TB/device/step on
# kimi-k2 train_4k; constraining the buffers made it worse — see
# EXPERIMENTS.md §Perf B1).  This path does the textbook thing instead:
# tokens stay on their home shard, and two explicit all_to_all exchanges
# over the expert-parallel ("model") axis move only the routed activations:
#
#   route locally -> bucket by destination shard -> all_to_all ->
#   local per-expert capacity buffers -> expert FFN (gmm) ->
#   all_to_all back -> weighted combine.
#
# FSDP composes: expert weights arrive (E_loc, D/fsdp, F) and are
# all-gathered over the fsdp axis inside the block; the transpose of that
# gather is the reduce-scatter that FSDP backward requires.


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map without replication checking, across jax versions
    (jax.shard_map/check_vma is the new API; experimental/check_rep the old)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _dispatch_local(ids, n_buckets, capacity):
    """Stable-sort (row -> bucket) assignment with per-bucket capacity.

    Returns (order, bucket_of_sorted, slot_of_sorted, keep)."""
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    counts = jnp.zeros((n_buckets,), jnp.int32).at[ids].add(1)
    seg_start = jnp.cumsum(counts) - counts
    pos = jnp.arange(ids.shape[0], dtype=jnp.int32) - seg_start[sorted_ids]
    keep = pos < capacity
    return order, sorted_ids, jnp.where(keep, pos, 0), keep


def moe_block_ep(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig,
                 mesh, rules) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map + all_to_all. x: (B, S, D)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import spec_for

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    f = cfg.moe_d_ff

    def ax(name):
        v = rules.get(name)
        return v if v is None or isinstance(v, tuple) else (v,)

    batch_axes = tuple(a for a in (ax("batch") or ()) if a in mesh.axis_names)
    model_ax = (ax("experts") or (None,))[0]
    fsdp_axes = tuple(a for a in (ax("embed") or ())
                      if a in mesh.axis_names)
    n_model = mesh.shape[model_ax]
    n_fsdp = 1
    for a in fsdp_axes:
        n_fsdp *= mesh.shape[a]
    e_loc = e // n_model
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    # per-device token count: batch over data axes, seq over the model axis
    t_loc = (b // n_batch) * (s // n_model)
    c_send = -(-int(t_loc * k / n_model * cfg.capacity_factor) // 8) * 8
    c_loc = -(-int(n_model * c_send / e_loc * cfg.capacity_factor) // 8) * 8

    def body(xb, router_w, gate_w, up_w, down_w):
        # xb: (B_loc, S_loc, D); weights: (E_loc, D/fsdp, F)
        for a2 in fsdp_axes:     # FSDP: gather the expert weights
            gate_w = jax.lax.all_gather(gate_w, a2, axis=1, tiled=True)
            up_w = jax.lax.all_gather(up_w, a2, axis=1, tiled=True)
            down_w = jax.lax.all_gather(down_w, a2, axis=2, tiled=True)
        xf = xb.reshape(-1, d)                              # (T_loc, D)
        logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, experts = jax.lax.top_k(probs, k)            # (T_loc, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[experts.reshape(-1)].add(
            1.0 / (xf.shape[0] * k))
        aux_names = tuple(a for a in (batch_axes + (model_ax,)) if a)
        aux = e * jnp.sum(jax.lax.pmean(me, aux_names)
                          * jax.lax.pmean(ce, aux_names))

        flat_e = experts.reshape(-1)                        # (T_loc*k,)
        token_idx_all = jnp.arange(flat_e.shape[0]) // k
        dest = flat_e // e_loc                              # target shard
        order, dest_s, slot_s, keep_s = _dispatch_local(
            dest, n_model, c_send)
        tok_s = token_idx_all[order]
        send = jnp.zeros((n_model, c_send, d), xb.dtype).at[
            dest_s, slot_s].add(
            jnp.where(keep_s[:, None], xf[tok_s], 0))
        # metadata: local expert id (or -1 for empty slots)
        send_exp = jnp.full((n_model, c_send), -1, jnp.int32).at[
            dest_s, slot_s].max(jnp.where(keep_s, flat_e[order] % e_loc, -1))

        recv = jax.lax.all_to_all(send, model_ax, split_axis=0,
                                  concat_axis=0, tiled=False)
        recv_exp = jax.lax.all_to_all(send_exp[..., None], model_ax,
                                      split_axis=0, concat_axis=0,
                                      tiled=False)[..., 0]

        rx = recv.reshape(n_model * c_send, d)
        rexp = recv_exp.reshape(-1)
        valid = rexp >= 0
        rexp_c = jnp.where(valid, rexp, 0)
        order2, exp_s, slot2, keep2 = _dispatch_local(rexp_c, e_loc, c_loc)
        keep2 = keep2 & valid[order2]
        ebuf = jnp.zeros((e_loc, c_loc, d), xb.dtype).at[exp_s, slot2].add(
            jnp.where(keep2[:, None], rx[order2], 0))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf,
                                   gate_w.astype(xb.dtype))) \
            * jnp.einsum("ecd,edf->ecf", ebuf, up_w.astype(xb.dtype))
        obuf = jnp.einsum("ecf,efd->ecd", h, down_w.astype(xb.dtype))

        vals2 = obuf[exp_s, slot2]                          # (R, D)
        back_rows = jnp.zeros((n_model * c_send, d), xb.dtype).at[
            order2].add(jnp.where(keep2[:, None], vals2, 0))
        ret = jax.lax.all_to_all(back_rows.reshape(n_model, c_send, d),
                                 model_ax, split_axis=0, concat_axis=0,
                                 tiled=False)

        got = ret[dest_s, slot_s]                           # (T_loc*k, D)
        gates_s = gates.reshape(-1)[order].astype(xb.dtype)
        contrib = jnp.where(keep_s[:, None], got * gates_s[:, None], 0)
        y = jnp.zeros((t_loc, d), xb.dtype).at[tok_s].add(contrib)
        return y.reshape(xb.shape), aux

    x_spec = P(batch_axes or None, model_ax, None)
    w_spec = P(model_ax, fsdp_axes or None, None)
    w_spec_down = P(model_ax, None, fsdp_axes or None)
    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec_down),
        out_specs=(x_spec, P()),
    )(x, p["router"], p["gate"], p["up"], p["down"])

    if cfg.n_shared_experts:
        xf = x.reshape(-1, d)
        hs = jax.nn.silu(xf @ p["shared_gate"].astype(x.dtype)) \
            * (xf @ p["shared_up"].astype(x.dtype))
        y = y + (hs @ p["shared_down"].astype(x.dtype)).reshape(y.shape)
    return y, aux
