"""Model zoo substrate: layers, families, and the unified ModelBundle API."""
from repro.models.registry import build_model  # noqa: F401
