"""Mamba-2 block — SSD (state-space duality) with chunked scan
(arXiv:2405.21060).

Forward = in_proj → causal depthwise conv (x/B/C path) → SSD → gated RMSNorm
→ out_proj.  The SSD core runs one ``lax.scan`` over length-``Q`` chunks:
the intra-chunk part is the quadratic "attention-like" form, the inter-chunk
part carries the (B, H, N, P) state recurrence — O(L·Q) work, O(L) memory.
``repro/kernels/ssd`` implements the same chunk body as a Pallas kernel;
``repro/kernels/ssd/ref.py`` holds the naive per-step recurrence oracle both
are validated against.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_mamba2(key: jax.Array, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    kg = KeyGen(key)
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * g * n
    d_in_proj = 2 * di + 2 * g * n + h
    # dt bias: inverse softplus of dt ~ U[1e-3, 0.1]
    u = jax.random.uniform(kg(), (h,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(kg(), (d, d_in_proj), d),
        "conv_w": (jax.random.uniform(kg(), (cfg.conv_kernel, conv_ch),
                                      jnp.float32) - 0.5)
        * (2.0 / math.sqrt(cfg.conv_kernel * conv_ch)),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jax.random.uniform(kg(), (h,), jnp.float32,
                                            1.0, 16.0)),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(kg(), (di, d), di),
    }


def mamba2_specs(cfg: ModelConfig, prefix: Tuple = ()) -> Dict[str, Tuple]:
    return {
        "in_proj": prefix + ("embed", "heads"),
        "conv_w": prefix + (None, "heads"),
        "conv_b": prefix + ("heads",),
        "dt_bias": prefix + ("heads",),
        "A_log": prefix + ("heads",),
        "D": prefix + ("heads",),
        "norm_scale": prefix + ("heads",),
        "out_proj": prefix + ("heads", "embed"),
    }


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jnp.ndarray,        # (B, L, H, P) — inputs per head
    dt: jnp.ndarray,       # (B, L, H)    — positive step sizes
    a_neg: jnp.ndarray,    # (H,)         — A = -exp(A_log), negative
    b_mat: jnp.ndarray,    # (B, L, G, N)
    c_mat: jnp.ndarray,    # (B, L, G, N)
    chunk: int,
    h0: jnp.ndarray | None = None,  # (B, H, N, P) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # expand groups to heads
    bh = jnp.repeat(b_mat, rep, axis=2)    # (B, L', H, N)
    ch = jnp.repeat(c_mat, rep, axis=2)

    loga = dt * a_neg                      # (B, L', H) per-step log decay
    dtx = (x * dt[..., None]).astype(jnp.float32)

    def to_chunks(t):
        return t.reshape((bsz, nc) + (q,) + t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(dtx), to_chunks(loga.astype(jnp.float32)),
          to_chunks(bh.astype(jnp.float32)), to_chunks(ch.astype(jnp.float32)))

    state0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def body(state, inp):
        xc, lac, bc, cc = inp              # (B,Q,H,P), (B,Q,H), (B,Q,H,N) x2
        cum = jnp.cumsum(lac, axis=1)      # inclusive cumulative log decay
        # --- inter-chunk: contribution of the carried state -------------
        # y_inter[t] = exp(cum[t]) * C_t · state
        y_inter = jnp.einsum("bqhn,bhnp->bqhp", cc, state) \
            * jnp.exp(cum)[..., None]
        # --- intra-chunk (attention-like) --------------------------------
        # m[t,s] = (C_t·B_s) * exp(cum[t] - cum[s]) for s <= t
        scores = jnp.einsum("bqhn,bshn->bqsh", cc, bc)
        dd = cum[:, :, None, :] - cum[:, None, :, :]        # (B,Q,S,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        m = jnp.where(mask[None, :, :, None], scores * jnp.exp(dd), 0.0)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", m, xc)
        # --- state update -------------------------------------------------
        # state' = exp(total) * state + sum_s exp(total - cum[s]) B_s ⊗ x_s
        total = cum[:, -1, :]                                # (B,H)
        w = jnp.exp(total[:, None, :] - cum)                 # (B,Q,H)
        state_new = state * jnp.exp(total)[..., None, None] \
            + jnp.einsum("bqhn,bqhp,bqh->bhnp", bc, xc, w)
        return state_new, y_inter + y_intra

    state, ys = jax.lax.scan(body, state0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), state


def ssd_decode_step(
    x: jnp.ndarray,        # (B, 1, H, P)
    dt: jnp.ndarray,       # (B, 1, H)
    a_neg: jnp.ndarray,    # (H,)
    b_mat: jnp.ndarray,    # (B, 1, G, N)
    c_mat: jnp.ndarray,    # (B, 1, G, N)
    state: jnp.ndarray,    # (B, H, N, P)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrence for one token: h' = e^{dt·A} h + dt·B⊗x; y = C·h'."""
    g = b_mat.shape[2]
    rep = x.shape[2] // g
    bh = jnp.repeat(b_mat[:, 0], rep, axis=1).astype(jnp.float32)  # (B,H,N)
    ch = jnp.repeat(c_mat[:, 0], rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt[:, 0] * a_neg)[..., None, None]             # (B,H,1,1)
    dtx = (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)        # (B,H,P)
    state_new = state * decay + jnp.einsum("bhn,bhp->bhnp", bh, dtx)
    y = jnp.einsum("bhn,bhnp->bhp", ch, state_new)
    return y[:, None].astype(x.dtype), state_new


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray
                 ) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, L, C), w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    acc = jnp.zeros_like(x)
    for i in range(k):
        acc = acc + jax.lax.slice_in_dim(xp, i, i + x.shape[1], 1, 1) * w[i]
    return acc + b


def _split_proj(zxbcdt: jnp.ndarray, cfg: ModelConfig):
    di, g, n, h = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * g * n]
    dt = zxbcdt[..., di + di + 2 * g * n:]
    return z, xbc, dt


def mamba2_block(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig
                 ) -> jnp.ndarray:
    """Full-sequence forward. x: (B, L, D) -> (B, L, D)."""
    bsz, l, _ = x.shape
    di, g, n, h = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    xs = xbc[..., :di].reshape(bsz, l, h, cfg.ssm_head_dim)
    b_mat = xbc[..., di: di + g * n].reshape(bsz, l, g, n)
    c_mat = xbc[..., di + g * n:].reshape(bsz, l, g, n)
    dt_full = jax.nn.softplus(dt.astype(jnp.float32)
                              + p["dt_bias"])               # (B,L,H)
    a_neg = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs, dt_full, a_neg, b_mat, c_mat, cfg.ssm_chunk)
    y = y + xs * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, l, di)
    # gated RMSNorm (mamba2: norm(y * silu(z)))
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm_scale"]).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype)


def mamba2_decode(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,             # (B, 1, D)
    conv_state: jnp.ndarray,    # (B, K-1, conv_ch)
    ssm_state: jnp.ndarray,     # (B, H, N, P)
    cfg: ModelConfig,
):
    """One decode step. Returns (y, conv_state, ssm_state)."""
    bsz = x.shape[0]
    di, g, n, h = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads)
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    # rolling conv buffer: window = [conv_state ; xbc]
    win = jnp.concatenate([conv_state, xbc], axis=1)        # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", win,
                          p["conv_w"].astype(x.dtype)) \
        + p["conv_b"].astype(x.dtype)
    xbc_t = jax.nn.silu(conv_out)[:, None]                  # (B, 1, C)
    conv_state = win[:, 1:]
    xs = xbc_t[..., :di].reshape(bsz, 1, h, cfg.ssm_head_dim)
    b_mat = xbc_t[..., di: di + g * n].reshape(bsz, 1, g, n)
    c_mat = xbc_t[..., di + g * n:].reshape(bsz, 1, g, n)
    dt_full = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_decode_step(xs, dt_full, a_neg, b_mat, c_mat,
                                   ssm_state)
    y = y + xs * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, 1, di)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["norm_scale"]).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype), conv_state, ssm_state
