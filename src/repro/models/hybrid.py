"""Zamba2-style hybrid backbone: Mamba-2 layers + a SHARED attention block.

Structure (arXiv:2411.15242, simplified — see DESIGN.md §4): ``n_layers``
Mamba-2 blocks; after every ``attn_period`` of them, one *shared*
transformer block (attention + MLP, a single parameter set reused at every
application) is applied.  Weight sharing is respected everywhere: the shared
block's params are stored once, its KV caches are per-application
(stacked on a leading ``groups`` axis).

Simplifications vs. the released checkpoints (documented): no per-application
LoRA deltas on the shared block, and the shared block consumes the current
hidden state rather than concat(hidden, embedding).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models.attention import (
    attention_block,
    attention_decode,
    attention_decode_paged,
    attention_decode_slotted,
    attention_prefill,
    attention_specs,
    init_attention,
)
from repro.models.common import (
    KeyGen,
    apply_norm,
    cast_tree,
    embed_init,
    init_norm,
    norm_specs,
)
from repro.models.mamba2 import (
    init_mamba2,
    mamba2_block,
    mamba2_decode,
    mamba2_specs,
)
from repro.models.mlp import init_mlp, mlp_block, mlp_specs


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, group_size, n_tail) — groups end with a shared-block app."""
    period = cfg.attn_period or cfg.n_layers + 1
    n_groups = cfg.n_layers // period
    tail = cfg.n_layers - n_groups * period
    return n_groups, period, tail


# ---------------------------------------------------------------------------
# Init / specs
# ---------------------------------------------------------------------------


def _init_mamba_layer(key, cfg):
    kg = KeyGen(key)
    return {"norm": init_norm(cfg.norm, cfg.d_model),
            "mamba": init_mamba2(kg(), cfg)}


def init_hybrid(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    kg = KeyGen(key)
    n_groups, period, tail = _layout(cfg)
    init_one = lambda k: _init_mamba_layer(k, cfg)

    params: Dict[str, Any] = {
        "embed": embed_init(kg(), (cfg.vocab_size, cfg.d_model)),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
        "unembed": embed_init(kg(), (cfg.d_model, cfg.vocab_size)),
    }
    if n_groups:  # pure-SSM configs (attn_period=0) have no shared block
        group_keys = jax.random.split(kg(), n_groups * period)
        groups = jax.vmap(init_one)(group_keys)
        params["groups"] = jax.tree_util.tree_map(
            lambda x: x.reshape((n_groups, period) + x.shape[1:]), groups)
        params["shared"] = {
            "attn_norm": init_norm(cfg.norm, cfg.d_model),
            "attn": init_attention(kg(), cfg),
            "mlp_norm": init_norm(cfg.norm, cfg.d_model),
            "mlp": init_mlp(kg(), cfg),
        }
    if tail:
        tail_keys = jax.random.split(kg(), tail)
        params["tail"] = jax.vmap(init_one)(tail_keys)
    return cast_tree(params, jnp.dtype(cfg.dtype))


def hybrid_specs(cfg: ModelConfig) -> Dict[str, Any]:
    n_groups, period, tail = _layout(cfg)
    lp = {"norm": norm_specs(cfg.norm), "mamba": mamba2_specs(cfg)}
    as_tuple = lambda s: isinstance(s, tuple)
    specs: Dict[str, Any] = {
        "embed": ("vocab", "embed_unsharded"),
        "final_norm": norm_specs(cfg.norm),
        "unembed": ("embed_unsharded", "vocab"),
    }
    if n_groups:
        specs["groups"] = jax.tree_util.tree_map(
            lambda s: ("layer_groups", "layers") + s, lp, is_leaf=as_tuple)
        specs["shared"] = {
            "attn_norm": norm_specs(cfg.norm),
            "attn": attention_specs(cfg),
            "mlp_norm": norm_specs(cfg.norm),
            "mlp": mlp_specs(cfg),
        }
    if tail:
        specs["tail"] = jax.tree_util.tree_map(
            lambda s: ("layers",) + s, lp, is_leaf=as_tuple)
    return specs


# ---------------------------------------------------------------------------
# Forward (training)
# ---------------------------------------------------------------------------


def _mamba_layer_fwd(lp, x, cfg):
    return x + mamba2_block(
        lp["mamba"], apply_norm(cfg.norm, x, lp["norm"], cfg.norm_eps), cfg)


def _shared_block_fwd(sp, x, cfg, positions=None):
    h = x + attention_block(
        sp["attn"], apply_norm(cfg.norm, x, sp["attn_norm"], cfg.norm_eps),
        cfg, positions=positions, causal=True)
    return h + mlp_block(
        sp["mlp"], apply_norm(cfg.norm, h, sp["mlp_norm"], cfg.norm_eps), cfg)


def hybrid_unembed(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    logits = x @ params["unembed"].astype(x.dtype)
    return logical_constraint(logits, "batch", "seq", "vocab")


def hybrid_hidden(params: Dict[str, Any], cfg: ModelConfig,
                  *, tokens: jnp.ndarray,
                  positions: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = logical_constraint(x, "batch", "seq", None)

    def inner(x_, lp):
        out = _mamba_layer_fwd(lp, x_, cfg)
        return logical_constraint(out, "batch", "seq", None), None

    if "groups" in params:
        shared = params["shared"]

        def outer(x_, gp):
            x_, _ = jax.lax.scan(lambda c, lp: inner(c, lp), x_, gp)
            x_ = _shared_block_fwd(shared, x_, cfg, positions)
            return logical_constraint(x_, "batch", "seq", None), None

        body = jax.checkpoint(lambda c, gp: outer(c, gp)) \
            if cfg.remat != "none" else outer
        x, _ = jax.lax.scan(body, x, params["groups"])
    if "tail" in params:
        tail_body = jax.checkpoint(inner) if cfg.remat != "none" else inner
        x, _ = jax.lax.scan(tail_body, x, params["tail"])
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def hybrid_forward(params: Dict[str, Any], cfg: ModelConfig,
                   *, tokens: jnp.ndarray,
                   positions: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x, aux = hybrid_hidden(params, cfg, tokens=tokens, positions=positions)
    return hybrid_unembed(params, x, cfg), aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def init_hybrid_cache(cfg: ModelConfig, batch: int, cache_len: int):
    n_groups, period, tail = _layout(cfg)
    dt = jnp.dtype(cfg.dtype)
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    kv = (n_groups, batch, cache_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    cache = {
        "conv_tail": jnp.zeros((tail, batch, cfg.conv_kernel - 1, conv_ch),
                               dt),
        "ssm_tail": jnp.zeros((tail, batch, cfg.ssm_heads, cfg.ssm_state,
                               cfg.ssm_head_dim), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }
    if n_groups:
        cache.update({
            "conv": jnp.zeros((n_groups, period, batch, cfg.conv_kernel - 1,
                               conv_ch), dt),
            "ssm": jnp.zeros((n_groups, period, batch, cfg.ssm_heads,
                              cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
            "k": jnp.zeros(kv, dt),
            "v": jnp.zeros(kv, dt),
        })
    return cache


def hybrid_cache_specs(cfg: ModelConfig):
    n_groups, _, _ = _layout(cfg)
    specs = {
        "conv_tail": ("layers", "batch", None, "heads"),
        "ssm_tail": ("layers", "batch", "heads", None, None),
        "len": (),
    }
    if n_groups:
        specs.update({
            "conv": ("layer_groups", "layers", "batch", None, "heads"),
            "ssm": ("layer_groups", "layers", "batch", "heads", None, None),
            "k": ("layer_groups", "batch", None, "kv_heads", "head_dim"),
            "v": ("layer_groups", "batch", None, "kv_heads", "head_dim"),
        })
    return specs


def init_hybrid_slot_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Slot-cache layout: per-slot ``lens`` instead of the shared ``len``.
    Conv/SSM states are already per-row; only the shared block's KV cache
    and the RoPE position need the per-slot length."""
    cache = init_hybrid_cache(cfg, batch, cache_len)
    del cache["len"]
    cache["lens"] = jnp.zeros((batch,), jnp.int32)
    return cache


def hybrid_prefill_slotted(params, cfg: ModelConfig, *, tokens, lens,
                           cache_len: int):
    """Exact-length bucket prefill (SSM states fold every input token, so
    right-padding would corrupt them — the engine groups hybrid prompts by
    exact length; ``lens`` must equal the batch's shared sequence length)."""
    logits, cache = hybrid_prefill(params, cfg, tokens=tokens,
                                   cache_len=cache_len)
    del cache["len"]
    cache["lens"] = jnp.broadcast_to(
        jnp.asarray(tokens.shape[1], jnp.int32), (tokens.shape[0],))
    return logits, cache


def hybrid_decode_step_slotted(params, cache, tokens, active,
                               cfg: ModelConfig):
    """One decode token per slot with independent per-slot lengths.

    Mamba conv/SSM state updates are row-local, so inactive slots just
    churn dead state that the next prefill replaces wholesale; the shared
    attention block scatters/masks at each slot's own position."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    lens = cache["lens"]

    def mamba_step(x_, layer):
        lp, conv_s, ssm_s = layer
        h = apply_norm(cfg.norm, x_, lp["norm"], cfg.norm_eps)
        y, conv_s, ssm_s = mamba2_decode(lp["mamba"], h, conv_s, ssm_s, cfg)
        return x_ + y, (conv_s, ssm_s)

    new_cache = {"lens": lens + active.astype(jnp.int32)}
    if "groups" in params:
        shared = params["shared"]

        def group_step(x_, layer):
            gp, conv_s, ssm_s, kc, vc = layer
            x_, (conv_new, ssm_new) = jax.lax.scan(
                mamba_step, x_, (gp, conv_s, ssm_s))
            h = apply_norm(cfg.norm, x_, shared["attn_norm"], cfg.norm_eps)
            a, kc, vc = attention_decode_slotted(shared["attn"], h, kc, vc,
                                                 lens, cfg)
            x_ = x_ + a
            x_ = x_ + mlp_block(shared["mlp"],
                                apply_norm(cfg.norm, x_, shared["mlp_norm"],
                                           cfg.norm_eps), cfg)
            return x_, (conv_new, ssm_new, kc, vc)

        x, (conv_g, ssm_g, k_all, v_all) = jax.lax.scan(
            group_step, x,
            (params["groups"], cache["conv"], cache["ssm"],
             cache["k"], cache["v"]))
        new_cache.update({"conv": conv_g, "ssm": ssm_g,
                          "k": k_all, "v": v_all})
    conv_t, ssm_t = cache["conv_tail"], cache["ssm_tail"]
    if "tail" in params:
        x, (conv_t, ssm_t) = jax.lax.scan(
            mamba_step, x, (params["tail"], conv_t, ssm_t))
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["unembed"].astype(x.dtype))[:, 0]
    new_cache.update({"conv_tail": conv_t, "ssm_tail": ssm_t})
    return logits, new_cache


def init_hybrid_paged_cache(cfg: ModelConfig, slots: int, cache_len: int,
                            n_blocks: int, block_size: int):
    """Paged hybrid cache: only the shared block's KV moves into a global
    block pool (per layer group); conv/SSM states are O(1) per slot and
    stay dense per-row."""
    assert cache_len % block_size == 0, \
        "cache_len must be a block_size multiple"
    n_groups, _, _ = _layout(cfg)
    cache = init_hybrid_slot_cache(cfg, slots, cache_len)
    cache["tables"] = jnp.full((slots, cache_len // block_size), n_blocks,
                               jnp.int32)
    if n_groups:
        dt = jnp.dtype(cfg.dtype)
        kv = (n_groups, n_blocks, block_size, cfg.n_kv_heads,
              cfg.resolved_head_dim)
        cache["k"] = jnp.zeros(kv, dt)
        cache["v"] = jnp.zeros(kv, dt)
    return cache


def hybrid_paged_cache_specs(cfg: ModelConfig):
    n_groups, _, _ = _layout(cfg)
    specs = {
        "conv_tail": ("layers", "batch", None, "heads"),
        "ssm_tail": ("layers", "batch", "heads", None, None),
        "lens": ("batch",),
        "tables": ("batch", None),
    }
    if n_groups:
        kv = ("layer_groups", "blocks", "block", "kv_heads", "head_dim")
        specs.update({
            "conv": ("layer_groups", "layers", "batch", None, "heads"),
            "ssm": ("layer_groups", "layers", "batch", "heads", None, None),
            "k": kv,
            "v": kv,
        })
    return specs


def hybrid_prefill_paged(params, cfg: ModelConfig, *, tokens, lens):
    """Exact-length bucket prefill for the paged engine: K/V rows come back
    unpadded (cache_len = L) for the engine to scatter into pool blocks."""
    return hybrid_prefill_slotted(params, cfg, tokens=tokens, lens=lens,
                                  cache_len=tokens.shape[1])


def hybrid_decode_step_paged(params, cache, tokens, active,
                             cfg: ModelConfig):
    """One decode token per slot against the shared KV block pool.

    Conv/SSM states update densely per row exactly as in the slotted
    step; the shared attention block scatters/gathers through each slot's
    block table (inactive rows never write the pool)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    lens, tables = cache["lens"], cache["tables"]

    def mamba_step(x_, layer):
        lp, conv_s, ssm_s = layer
        h = apply_norm(cfg.norm, x_, lp["norm"], cfg.norm_eps)
        y, conv_s, ssm_s = mamba2_decode(lp["mamba"], h, conv_s, ssm_s, cfg)
        return x_ + y, (conv_s, ssm_s)

    new_cache = {"lens": lens + active.astype(jnp.int32), "tables": tables}
    if "groups" in params:
        shared = params["shared"]

        def group_step(x_, layer):
            gp, conv_s, ssm_s, kc, vc = layer
            x_, (conv_new, ssm_new) = jax.lax.scan(
                mamba_step, x_, (gp, conv_s, ssm_s))
            h = apply_norm(cfg.norm, x_, shared["attn_norm"], cfg.norm_eps)
            a, kc, vc = attention_decode_paged(shared["attn"], h, kc, vc,
                                               lens, tables, active, cfg)
            x_ = x_ + a
            x_ = x_ + mlp_block(shared["mlp"],
                                apply_norm(cfg.norm, x_, shared["mlp_norm"],
                                           cfg.norm_eps), cfg)
            return x_, (conv_new, ssm_new, kc, vc)

        x, (conv_g, ssm_g, k_all, v_all) = jax.lax.scan(
            group_step, x,
            (params["groups"], cache["conv"], cache["ssm"],
             cache["k"], cache["v"]))
        new_cache.update({"conv": conv_g, "ssm": ssm_g,
                          "k": k_all, "v": v_all})
    conv_t, ssm_t = cache["conv_tail"], cache["ssm_tail"]
    if "tail" in params:
        x, (conv_t, ssm_t) = jax.lax.scan(
            mamba_step, x, (params["tail"], conv_t, ssm_t))
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["unembed"].astype(x.dtype))[:, 0]
    new_cache.update({"conv_tail": conv_t, "ssm_tail": ssm_t})
    return logits, new_cache


def hybrid_decode_step(params, cache, tokens, cfg: ModelConfig):
    """One decode token through the full hybrid stack."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    pos = cache["len"]

    def mamba_step(x_, layer):
        lp, conv_s, ssm_s = layer
        h = apply_norm(cfg.norm, x_, lp["norm"], cfg.norm_eps)
        y, conv_s, ssm_s = mamba2_decode(lp["mamba"], h, conv_s, ssm_s, cfg)
        return x_ + y, (conv_s, ssm_s)

    new_cache = {"len": pos + 1}
    if "groups" in params:
        shared = params["shared"]

        def group_step(x_, layer):
            gp, conv_s, ssm_s, kc, vc = layer
            x_, (conv_new, ssm_new) = jax.lax.scan(
                mamba_step, x_, (gp, conv_s, ssm_s))
            h = apply_norm(cfg.norm, x_, shared["attn_norm"], cfg.norm_eps)
            a, kc, vc = attention_decode(shared["attn"], h, kc, vc, pos, cfg)
            x_ = x_ + a
            x_ = x_ + mlp_block(shared["mlp"],
                                apply_norm(cfg.norm, x_, shared["mlp_norm"],
                                           cfg.norm_eps), cfg)
            return x_, (conv_new, ssm_new, kc, vc)

        x, (conv_g, ssm_g, k_all, v_all) = jax.lax.scan(
            group_step, x,
            (params["groups"], cache["conv"], cache["ssm"],
             cache["k"], cache["v"]))
        new_cache.update({"conv": conv_g, "ssm": ssm_g,
                          "k": k_all, "v": v_all})
    conv_t, ssm_t = cache["conv_tail"], cache["ssm_tail"]
    if "tail" in params:
        x, (conv_t, ssm_t) = jax.lax.scan(
            mamba_step, x, (params["tail"], conv_t, ssm_t))
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["unembed"].astype(x.dtype))[:, 0]
    new_cache.update({"conv_tail": conv_t, "ssm_tail": ssm_t})
    return logits, new_cache


def hybrid_prefill(params, cfg: ModelConfig, *, tokens, cache_len: int):
    """Prefill: run the full-sequence forward while building every cache.

    SSM states after a full sequence come from re-running the chunked scan
    and keeping the final state; conv states keep the last K-1 inputs; the
    shared block's KV caches are collected per application.
    """
    from repro.models.mamba2 import _causal_conv, _split_proj, ssd_chunked
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    di, g, n, h = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads)

    def mamba_with_state(lp, x_):
        hin = apply_norm(cfg.norm, x_, lp["norm"], cfg.norm_eps)
        p = lp["mamba"]
        zxbcdt = hin @ p["in_proj"].astype(hin.dtype)
        z, xbc, dt = _split_proj(zxbcdt, cfg)
        conv_state = xbc[:, -(cfg.conv_kernel - 1):]
        xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(hin.dtype),
                                       p["conv_b"].astype(hin.dtype)))
        xs = xbc[..., :di].reshape(b, s, h, cfg.ssm_head_dim)
        b_mat = xbc[..., di: di + g * n].reshape(b, s, g, n)
        c_mat = xbc[..., di + g * n:].reshape(b, s, g, n)
        dt_full = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        a_neg = -jnp.exp(p["A_log"])
        y, ssm_state = ssd_chunked(xs, dt_full, a_neg, b_mat, c_mat,
                                   cfg.ssm_chunk)
        y = y + xs * p["D"][None, None, :, None].astype(hin.dtype)
        y = y.reshape(b, s, di) * jax.nn.silu(z)
        var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
             * p["norm_scale"]).astype(hin.dtype)
        return x_ + y @ p["out_proj"].astype(hin.dtype), conv_state, ssm_state

    def inner(x_, lp):
        out, conv_s, ssm_s = mamba_with_state(lp, x_)
        return out, (conv_s, ssm_s)

    cache = {"len": jnp.asarray(s, jnp.int32)}
    if "groups" in params:
        shared = params["shared"]

        def outer(x_, gp):
            x_, states = jax.lax.scan(inner, x_, gp)
            hn = apply_norm(cfg.norm, x_, shared["attn_norm"], cfg.norm_eps)
            a, (kc, vc) = attention_prefill(shared["attn"], hn, cfg,
                                            cache_len)
            x_ = x_ + a
            x_ = x_ + mlp_block(shared["mlp"],
                                apply_norm(cfg.norm, x_, shared["mlp_norm"],
                                           cfg.norm_eps), cfg)
            return x_, states + (kc, vc)

        x, (conv_g, ssm_g, k_all, v_all) = jax.lax.scan(outer, x,
                                                        params["groups"])
        cache.update({"conv": conv_g, "ssm": ssm_g, "k": k_all, "v": v_all})
    n_groups, period, tail = _layout(cfg)
    conv_ch = di + 2 * g * n
    conv_t = jnp.zeros((tail, b, cfg.conv_kernel - 1, conv_ch), x.dtype)
    ssm_t = jnp.zeros((tail, b, h, n, cfg.ssm_head_dim), jnp.float32)
    if "tail" in params:
        x, (conv_t, ssm_t) = jax.lax.scan(inner, x, params["tail"])
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["unembed"].astype(x.dtype))[:, 0]
    cache.update({"conv_tail": conv_t, "ssm_tail": ssm_t})
    return logits, cache
