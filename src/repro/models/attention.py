"""GQA attention: chunked (flash-like) jnp reference + KV-cache decode.

The chunked path is the default lowering everywhere (train / prefill): an
online-softmax ``lax.scan`` over KV blocks, so no O(S²) score tensor is ever
materialized — the per-step transient is (B, Sq, H, chunk).  The Pallas
flash-attention kernel (repro/kernels/flash_attention) is the TPU-target
implementation of the same contraction and is validated against this
reference; the dry-run lowers the jnp path (Pallas does not lower on the CPU
backend — DESIGN.md §5).

Supports: grouped KV heads (GQA/MQA), qk-norm (qwen3), QKV bias (qwen2),
RoPE / M-RoPE, bidirectional (whisper encoder) and cross attention.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    KeyGen,
    apply_mrope,
    apply_rope,
    dense_init,
    rmsnorm,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig,
                   cross: bool = False) -> Dict[str, jnp.ndarray]:
    kg = KeyGen(key)
    d, h, kvh, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
    p = {
        "q": dense_init(kg(), (d, h * hd), d),
        "k": dense_init(kg(), (d, kvh * hd), d),
        "v": dense_init(kg(), (d, kvh * hd), d),
        "o": dense_init(kg(), (h * hd, d), h * hd),
    }
    if cfg.qkv_bias:
        p["q_b"] = jnp.zeros((h * hd,), jnp.float32)
        p["k_b"] = jnp.zeros((kvh * hd,), jnp.float32)
        p["v_b"] = jnp.zeros((kvh * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def attention_specs(cfg: ModelConfig, prefix: Tuple = ()) -> Dict[str, Tuple]:
    """Logical axes per param dim (layer-stack prefix prepended by caller)."""
    p = {
        "q": prefix + ("embed", "heads"),
        "k": prefix + ("embed", "kv_heads"),
        "v": prefix + ("embed", "kv_heads"),
        "o": prefix + ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p["q_b"] = prefix + ("heads",)
        p["k_b"] = prefix + ("kv_heads",)
        p["v_b"] = prefix + ("kv_heads",)
    if cfg.qk_norm:
        p["q_norm"] = prefix + (None,)
        p["k_norm"] = prefix + (None,)
    return p


# ---------------------------------------------------------------------------
# Core contraction: chunked online-softmax attention
# ---------------------------------------------------------------------------


N_CAUSAL_Q_BLOCKS = 8


def chunked_attention(
    q: jnp.ndarray,           # (B, Sq, H, hd)
    k: jnp.ndarray,           # (B, Sk, KVH, hd)
    v: jnp.ndarray,           # (B, Sk, KVH, hd)
    *,
    causal: bool,
    chunk: int = 512,
    q_offset=0,               # int or scalar array: absolute pos of q[0]
    kv_len=None,              # scalar array: valid KV prefix (decode masking)
    block_causal: bool = True,
) -> jnp.ndarray:
    """Online-softmax attention over KV chunks. Returns (B, Sq, H, hd).

    Causal full-sequence calls are q-blocked (§Perf iteration C1): the query
    range is split into ``N_CAUSAL_Q_BLOCKS`` python-unrolled blocks, each
    attending only to its causal KV prefix — skipping the fully-masked
    chunks that a single whole-q scan would compute and discard (~45 % of
    the score FLOPs at 8 blocks).
    """
    b, sq, h, hd = q.shape
    if (block_causal and causal and kv_len is None and sq == k.shape[1]
            and isinstance(q_offset, int) and q_offset == 0
            and sq >= 2 * chunk and sq % N_CAUSAL_Q_BLOCKS == 0):
        qb = sq // N_CAUSAL_Q_BLOCKS
        outs = []
        for i in range(N_CAUSAL_Q_BLOCKS):
            hi = (i + 1) * qb
            outs.append(chunked_attention(
                q[:, i * qb: hi], k[:, :hi], v[:, :hi],
                causal=True, chunk=chunk, q_offset=i * qb,
                block_causal=False))
        return jnp.concatenate(outs, axis=1)
    sk, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0
    rep = h // kvh
    if sq == 1:
        # decode fast path: no scan — scores are only (B, H, Sk), and the
        # softmax/contraction reductions over a sharded Sk lower to clean
        # psum patterns under SPMD (no dynamic slicing of sharded dims).
        # ``kv_len`` may be a scalar (all rows share a length) or a (B,)
        # vector (per-slot lengths — the serving engine's slotted decode).
        scale = 1.0 / (hd ** 0.5)
        qg = q.reshape(b, kvh, rep, hd).astype(jnp.float32) * scale
        s = jnp.einsum("bgrd,bcgd->bgrc", qg, k.astype(jnp.float32))
        k_pos = jnp.arange(sk)
        if kv_len is not None and jnp.ndim(kv_len) == 1:
            mask = k_pos[None, :] < kv_len[:, None]            # (B, Sk)
            s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        else:
            limit = sk if kv_len is None else kv_len
            mask = k_pos < limit
            if causal and q_offset is not None and kv_len is None:
                mask = mask & (k_pos <= q_offset)
            s = jnp.where(mask[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bgrc,bcgd->bgrd", p, v.astype(jnp.float32))
        return out.reshape(b, 1, h, hd).astype(q.dtype)
    chunk = min(chunk, sk)
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    scale = 1.0 / (hd ** 0.5)
    qg = (q.reshape(b, sq, kvh, rep, hd).astype(jnp.float32) * scale)
    kc = k.reshape(b, n_chunks, chunk, kvh, hd)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd)
    q_pos = q_offset + jnp.arange(sq)                      # (Sq,)
    limit = sk if kv_len is None else kv_len

    # The chunk body is checkpointed: without it, the scan's backward stores
    # every chunk's (B, Sq, H, chunk) score tensor — an O(S²) f32 residual
    # that defeats the entire point of the online softmax (measured: 7.2 GiB
    # per layer for qwen2-0.5b train_4k; see EXPERIMENTS.md §Perf iter 1).
    @jax.checkpoint
    def body(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        k_pos = j * chunk + jnp.arange(chunk)              # (chunk,)
        s = jnp.einsum("bqgrd,bcgd->bqgrc", qg, kj.astype(jnp.float32))
        mask = k_pos[None, :] < limit                      # (1, chunk)
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bqgrc,bcgd->bqgrd", p,
                                vj.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kvh, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, rep), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, rep, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block forward
# ---------------------------------------------------------------------------


def _project_qkv(p, x, cfg: ModelConfig, kv_src: Optional[jnp.ndarray] = None):
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    src = x if kv_src is None else kv_src
    sk = src.shape[1]
    q = (x @ p["q"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (src @ p["k"].astype(x.dtype)).reshape(b, sk, kvh, hd)
    v = (src @ p["v"].astype(x.dtype)).reshape(b, sk, kvh, hd)
    if cfg.qkv_bias:
        q = q + p["q_b"].astype(x.dtype).reshape(h, hd)
        k = k + p["k_b"].astype(x.dtype).reshape(kvh, hd)
        v = v + p["v_b"].astype(x.dtype).reshape(kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rotate(q, k, positions, cfg: ModelConfig):
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attention_block(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                 # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,   # (B,S) or (3,B,S) for mrope
    causal: bool = True,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if use_rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q, k = _rotate(q, k, positions, cfg)
    out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    return out.reshape(b, s, -1) @ p["o"].astype(x.dtype)


def attention_prefill(p, x, cfg: ModelConfig, cache_len: int,
                      positions=None, use_rope: bool = True):
    """Prefill: returns (out, (k_cache, v_cache)) with caches padded to
    ``cache_len`` so decode can append in place."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if use_rope:
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q, k = _rotate(q, k, positions, cfg)
    out = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    pad = cache_len - s
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = out.reshape(b, s, -1) @ p["o"].astype(x.dtype)
    return y, (kc, vc)


def attention_decode(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                 # (B, 1, D)
    k_cache: jnp.ndarray,           # (B, S_max, KVH, hd)
    v_cache: jnp.ndarray,
    pos,                            # scalar int32: current length
    cfg: ModelConfig,
    use_rope: bool = True,
):
    """One decode step. Returns (out, k_cache, v_cache)."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    if use_rope:
        if cfg.mrope:
            positions = jnp.broadcast_to(pos, (3, b, 1))
        else:
            positions = jnp.broadcast_to(pos, (b, 1))
        q, k = _rotate(q, k, positions, cfg)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=1)
    out = chunked_attention(q, k_cache, v_cache, causal=False,
                            chunk=cfg.attn_chunk, kv_len=pos + 1)
    y = out.reshape(b, 1, -1) @ p["o"].astype(x.dtype)
    return y, k_cache, v_cache


def attention_decode_slotted(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                 # (B, 1, D)
    k_cache: jnp.ndarray,           # (B, S_max, KVH, hd)
    v_cache: jnp.ndarray,
    lens: jnp.ndarray,              # (B,) int32: per-slot current lengths
    cfg: ModelConfig,
    use_rope: bool = True,
    interpret: Optional[bool] = None,
):
    """One decode step with independent per-slot sequence lengths.

    Each batch row is a serving slot at its own position: RoPE is applied at
    ``lens[b]``, the new KV row is scattered at ``lens[b]`` (clamped so a
    finished slot at the cache boundary overwrites its own dead tail rather
    than a neighbour), and attention masks each row to its own valid prefix.
    On TPU the masked contraction is the Pallas decode-attention kernel
    (kernels/decode_attention — per-row ``kv_len`` is a scalar-prefetch
    operand there; ``interpret=None`` auto-selects the compiled kernel);
    elsewhere it is the same jnp fast path the scalar decode uses, so batch
    rows are bit-identical to a one-request decode.

    Returns (out, k_cache, v_cache).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    if use_rope:
        if cfg.mrope:
            positions = jnp.broadcast_to(lens[None, :, None], (3, b, 1))
        else:
            positions = lens[:, None]
        q, k = _rotate(q, k, positions, cfg)
    pos_w = jnp.minimum(lens, k_cache.shape[1] - 1)
    upd = jax.vmap(lambda c, one, pw: jax.lax.dynamic_update_slice_in_dim(
        c, one, pw, axis=0))
    k_cache = upd(k_cache, k, pos_w)
    v_cache = upd(v_cache, v, pos_w)
    kv_len = lens + 1
    if jax.default_backend() == "tpu":
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q[:, 0], k_cache, v_cache, kv_len,
                               interpret=interpret)[:, None]
    else:
        out = chunked_attention(q, k_cache, v_cache, causal=False,
                                chunk=cfg.attn_chunk, kv_len=kv_len)
    y = out.reshape(b, 1, -1) @ p["o"].astype(x.dtype)
    return y, k_cache, v_cache


def attention_decode_paged(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                 # (B, 1, D)
    k_pool: jnp.ndarray,            # (P, BS, KVH, hd) global block pool
    v_pool: jnp.ndarray,
    lens: jnp.ndarray,              # (B,) int32: per-slot current lengths
    tables: jnp.ndarray,            # (B, NB) int32 block tables
    active: jnp.ndarray,            # (B,) bool: rows holding live requests
    cfg: ModelConfig,
    use_rope: bool = True,
    interpret: Optional[bool] = None,
):
    """One decode step against a paged (block-pool) KV cache.

    Identical per-row arithmetic to :func:`attention_decode_slotted`, but
    K/V live in a global pool of fixed-size blocks addressed through each
    slot's block table.  The new KV row is scattered at the block/offset
    of logical position ``lens[b]``; the write is *dropped* for inactive
    rows (``mode="drop"`` via the sentinel block index) — a freed block
    may already belong to another slot, so unlike the dense path an
    inactive row must not touch the pool at all.

    Off-TPU the contraction gathers each row's blocks into a contiguous
    ``(B, NB*BS, KVH, hd)`` view and reuses the exact sq==1 jnp fast path
    — when ``NB*BS`` equals the dense engine's ``cache_len``, the result
    is bit-identical to the dense slotted decode (same shapes, same
    reduction order; invalid positions mask to exact zeros).  On TPU the
    paged Pallas kernel consumes the table directly via scalar prefetch.

    Returns (out, k_pool, v_pool).
    """
    b = x.shape[0]
    n_blocks, bs = k_pool.shape[0], k_pool.shape[1]
    span = tables.shape[1] * bs
    q, k, v = _project_qkv(p, x, cfg)
    if use_rope:
        if cfg.mrope:
            positions = jnp.broadcast_to(lens[None, :, None], (3, b, 1))
        else:
            positions = lens[:, None]
        q, k = _rotate(q, k, positions, cfg)
    pos_w = jnp.minimum(lens, span - 1)
    blk = jnp.take_along_axis(tables, (pos_w // bs)[:, None], axis=1)[:, 0]
    blk = jnp.where(active, blk, n_blocks)      # inactive rows: dropped
    off = pos_w % bs
    k_pool = k_pool.at[blk, off].set(k[:, 0], mode="drop")
    v_pool = v_pool.at[blk, off].set(v[:, 0], mode="drop")
    kv_len = lens + 1
    if jax.default_backend() == "tpu":
        from repro.kernels.decode_attention.ops import paged_decode_attention
        out = paged_decode_attention(q[:, 0], k_pool, v_pool, tables,
                                     kv_len, interpret=interpret)[:, None]
    else:
        from repro.kernels.decode_attention.ref import gather_paged_kv
        kd, vd = gather_paged_kv(k_pool, v_pool, tables)
        out = chunked_attention(q, kd, vd, causal=False,
                                chunk=cfg.attn_chunk, kv_len=kv_len)
    y = out.reshape(b, 1, -1) @ p["o"].astype(x.dtype)
    return y, k_pool, v_pool


def cross_attention_block(p, x, enc_out, cfg: ModelConfig) -> jnp.ndarray:
    """Cross attention (whisper decoder): queries from x, KV from encoder."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, kv_src=enc_out)
    out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return out.reshape(b, s, -1) @ p["o"].astype(x.dtype)
