"""Whisper-style encoder-decoder (audio backbone; conv frontend is a STUB).

Per the assignment, ``input_specs()`` provides precomputed frame embeddings
(post-conv-frontend), so the encoder consumes (B, T_frames, D) directly plus
sinusoidal positions.  Decoder: causal self-attention (cached at decode) +
cross-attention to the encoder output (cached once at prefill) + GELU MLP,
pre-LayerNorm, tied decoder embeddings — matching arXiv:2212.04356 except
the decoder uses sinusoidal rather than learned positions (documented
deviation: learned tables would pin parameter shapes to one sequence length,
breaking the multi-shape dry-run).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models.attention import (
    attention_block,
    attention_decode,
    attention_prefill,
    attention_specs,
    chunked_attention,
    cross_attention_block,
    init_attention,
    _project_qkv,
)
from repro.models.common import (
    KeyGen,
    apply_norm,
    cast_tree,
    embed_init,
    init_norm,
    norm_specs,
    sinusoidal_positions,
)
from repro.models.mlp import init_mlp, mlp_block, mlp_specs


# ---------------------------------------------------------------------------
# Init / specs
# ---------------------------------------------------------------------------


def _init_enc_layer(key, cfg):
    kg = KeyGen(key)
    return {
        "attn_norm": init_norm(cfg.norm, cfg.d_model),
        "attn": init_attention(kg(), cfg),
        "mlp_norm": init_norm(cfg.norm, cfg.d_model),
        "mlp": init_mlp(kg(), cfg),
    }


def _init_dec_layer(key, cfg):
    kg = KeyGen(key)
    p = _init_enc_layer(kg(), cfg)
    p["cross_norm"] = init_norm(cfg.norm, cfg.d_model)
    p["cross"] = init_attention(kg(), cfg, cross=True)
    return p


def init_encdec(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    kg = KeyGen(key)
    enc_keys = jax.random.split(kg(), cfg.n_layers)
    dec_keys = jax.random.split(kg(), cfg.n_dec_layers)
    params = {
        "embed": embed_init(kg(), (cfg.vocab_size, cfg.d_model)),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_norm(cfg.norm, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    return cast_tree(params, jnp.dtype(cfg.dtype))


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    as_tuple = lambda s: isinstance(s, tuple)
    enc = {
        "attn_norm": norm_specs(cfg.norm),
        "attn": attention_specs(cfg),
        "mlp_norm": norm_specs(cfg.norm),
        "mlp": mlp_specs(cfg),
    }
    dec = dict(enc)
    dec["cross_norm"] = norm_specs(cfg.norm)
    dec["cross"] = attention_specs(cfg)
    stack = lambda t: jax.tree_util.tree_map(
        lambda s: ("layers",) + s, t, is_leaf=as_tuple)
    return {
        "embed": ("vocab", "embed_unsharded"),
        "enc_layers": stack(enc),
        "enc_norm": norm_specs(cfg.norm),
        "dec_layers": stack(dec),
        "final_norm": norm_specs(cfg.norm),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, T, D) stub frontend output -> encoder hidden states."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = logical_constraint(x, "batch", "seq", None)

    def body(x_, lp):
        h = x_ + attention_block(
            lp["attn"], apply_norm(cfg.norm, x_, lp["attn_norm"],
                                   cfg.norm_eps),
            cfg, causal=False, use_rope=False)
        h = h + mlp_block(
            lp["mlp"], apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps),
            cfg)
        return logical_constraint(h, "batch", "seq", None), None

    body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return apply_norm(cfg.norm, x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _dec_positions(length: int, cfg: ModelConfig) -> jnp.ndarray:
    return sinusoidal_positions(length, cfg.d_model)


def decode_train(params, dec_tokens: jnp.ndarray, enc_out: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    """Teacher-forced decoder forward. Returns logits (B, S_dec, V)."""
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(
        jnp.dtype(cfg.dtype))
    x = x + _dec_positions(x.shape[1], cfg).astype(x.dtype)
    x = logical_constraint(x, "batch", "seq", None)

    def body(x_, lp):
        h = x_ + attention_block(
            lp["attn"], apply_norm(cfg.norm, x_, lp["attn_norm"],
                                   cfg.norm_eps),
            cfg, causal=True, use_rope=False)
        h = h + cross_attention_block(
            lp["cross"], apply_norm(cfg.norm, h, lp["cross_norm"],
                                    cfg.norm_eps), enc_out, cfg)
        h = h + mlp_block(
            lp["mlp"], apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps),
            cfg)
        return logical_constraint(h, "batch", "seq", None), None

    body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    return encdec_unembed(params, x, cfg)


def encdec_unembed(params, x, cfg: ModelConfig) -> jnp.ndarray:
    logits = x @ params["embed"].T.astype(x.dtype)   # tied
    return logical_constraint(logits, "batch", "seq", "vocab")


def encdec_hidden(params, cfg: ModelConfig, *, frames, dec_tokens
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decoder final hidden states (pre-unembed) for the chunked loss."""
    enc_out = encode(params, frames, cfg)
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(
        jnp.dtype(cfg.dtype))
    x = x + _dec_positions(x.shape[1], cfg).astype(x.dtype)
    x = logical_constraint(x, "batch", "seq", None)

    def body(x_, lp):
        h = x_ + attention_block(
            lp["attn"], apply_norm(cfg.norm, x_, lp["attn_norm"],
                                   cfg.norm_eps),
            cfg, causal=True, use_rope=False)
        h = h + cross_attention_block(
            lp["cross"], apply_norm(cfg.norm, h, lp["cross_norm"],
                                    cfg.norm_eps), enc_out, cfg)
        h = h + mlp_block(
            lp["mlp"], apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps),
            cfg)
        return logical_constraint(h, "batch", "seq", None), None

    body_fn = jax.checkpoint(body) if cfg.remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def encdec_forward(params, cfg: ModelConfig, *, frames, dec_tokens
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    enc_out = encode(params, frames, cfg)
    logits = decode_train(params, dec_tokens, enc_out, cfg)
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------


def encdec_prefill(params, cfg: ModelConfig, *, frames, dec_tokens,
                   cache_len: int):
    """Encode audio + teacher-force the decoder prompt; build caches."""
    enc_out = encode(params, frames, cfg)
    x = jnp.take(params["embed"], dec_tokens, axis=0).astype(
        jnp.dtype(cfg.dtype))
    s = dec_tokens.shape[1]
    x = x + _dec_positions(s, cfg).astype(x.dtype)

    def body(x_, lp):
        h = apply_norm(cfg.norm, x_, lp["attn_norm"], cfg.norm_eps)
        a, (kc, vc) = attention_prefill(lp["attn"], h, cfg, cache_len,
                                        use_rope=False)
        h = x_ + a
        # cross attention + its cache (computed once from enc_out)
        hn = apply_norm(cfg.norm, h, lp["cross_norm"], cfg.norm_eps)
        q, ck, cv = _project_qkv(lp["cross"], hn, cfg, kv_src=enc_out)
        attn = chunked_attention(q, ck, cv, causal=False,
                                 chunk=cfg.attn_chunk)
        h = h + attn.reshape(h.shape[0], s, -1) \
            @ lp["cross"]["o"].astype(h.dtype)
        h = h + mlp_block(
            lp["mlp"], apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps),
            cfg)
        return h, (kc, vc, ck, cv)

    x, (k_all, v_all, ck_all, cv_all) = jax.lax.scan(body, x,
                                                     params["dec_layers"])
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    cache = {"k": k_all, "v": v_all, "ck": ck_all, "cv": cv_all,
             "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def init_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      enc_len: int):
    dt = jnp.dtype(cfg.dtype)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_dec_layers, batch, cache_len, kvh, hd), dt),
        "v": jnp.zeros((cfg.n_dec_layers, batch, cache_len, kvh, hd), dt),
        "ck": jnp.zeros((cfg.n_dec_layers, batch, enc_len, kvh, hd), dt),
        "cv": jnp.zeros((cfg.n_dec_layers, batch, enc_len, kvh, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def encdec_cache_specs(cfg: ModelConfig):
    kv = ("layers", "batch", None, "kv_heads", "head_dim")
    return {"k": kv, "v": kv, "ck": kv, "cv": kv, "len": ()}


def encdec_decode_step(params, cache, tokens, cfg: ModelConfig):
    """One decoder token; cross caches are static."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    pos = cache["len"]
    # position embedding for the current step: row `pos` of the sinusoid —
    # computed directly to stay shape-static.
    d = cfg.d_model
    half_dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * half_dim / d)
    ang = pos.astype(jnp.float32) * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
    x = x + pe.astype(x.dtype)

    def body(x_, layer):
        lp, kc, vc, ck, cv = layer
        h = apply_norm(cfg.norm, x_, lp["attn_norm"], cfg.norm_eps)
        a, kc, vc = attention_decode(lp["attn"], h, kc, vc, pos, cfg,
                                     use_rope=False)
        h = x_ + a
        hn = apply_norm(cfg.norm, h, lp["cross_norm"], cfg.norm_eps)
        q, _, _ = _project_qkv(lp["cross"], hn, cfg)  # q only; KV cached
        attn = chunked_attention(q, ck, cv, causal=False,
                                 chunk=cfg.attn_chunk)
        h = h + attn.reshape(h.shape[0], 1, -1) \
            @ lp["cross"]["o"].astype(h.dtype)
        h = h + mlp_block(
            lp["mlp"], apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps),
            cfg)
        return h, (kc, vc)

    x, (k_all, v_all) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    new_cache = {"k": k_all, "v": v_all, "ck": cache["ck"],
                 "cv": cache["cv"], "len": pos + 1}
    return logits, new_cache
