"""Shared building blocks: norms, RoPE (+M-RoPE), init, logical axis specs.

Parameters are plain nested dicts of ``jnp.ndarray``.  For every params tree
there is a parallel *spec tree* of tuples naming the **logical** axis of each
dimension (e.g. ``("layers", "embed", "heads")``); the distributed layer maps
logical names to physical mesh axes (see repro/distributed/sharding.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5
            ) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(kind: str, x: jnp.ndarray, p: Dict[str, jnp.ndarray],
               eps: float) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


def init_norm(kind: str, d: int) -> Dict[str, jnp.ndarray]:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_specs(kind: str) -> Dict[str, Tuple]:
    p = {"scale": (None,)}
    if kind == "layernorm":
        p["bias"] = (None,)
    return p


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32. Half-split convention."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions: (3, B, S) — temporal/height/width position
    ids.  The rotary half-dim is split into three sections, each rotated by
    its own position component (arXiv:2409.12191 §2.1).
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, hd)
    freqs = rope_freqs(hd, theta)                      # (half,)
    # build per-dimension positions: (B, S, half)
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)      # (half,)
    pos = positions.astype(jnp.float32)                # (3, B, S)
    pos_per_dim = jnp.take(pos, sec_id, axis=0)        # (half, B, S)
    angles = jnp.moveaxis(pos_per_dim, 0, -1) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (length, d_model)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / d_model)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Tuple[int, ...], in_axis_size: int,
               dtype=jnp.float32) -> jnp.ndarray:
    std = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)


class KeyGen:
    """Sequential PRNG key dispenser — keeps init code flat."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def cast_tree(tree: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def count_params(tree: Any) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))
