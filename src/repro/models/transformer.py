"""Decoder-only LM: scan-over-layers transformer for dense / MoE / VLM.

Design notes (DESIGN.md §5):

* layer parameters are stacked on a leading ``layers`` axis and consumed by
  ``lax.scan`` — one compiled layer body regardless of depth (88-layer
  configs compile as fast as 4-layer ones, and remat applies per layer);
* three entry points share the layer body: ``forward`` (training),
  ``prefill`` (returns a padded KV cache), ``decode_step`` (one token);
* MoE layers thread an auxiliary load-balance loss through the scan carry;
* activations may enter as token ids (LM) or precomputed embeddings
  (VLM / audio stub frontends).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.models.attention import (
    attention_block,
    attention_decode,
    attention_decode_paged,
    attention_decode_slotted,
    attention_prefill,
    attention_specs,
    init_attention,
)
from repro.models.common import (
    KeyGen,
    apply_norm,
    cast_tree,
    embed_init,
    init_norm,
    norm_specs,
)
from repro.models.mlp import init_mlp, mlp_block, mlp_specs
from repro.models.moe import init_moe, moe_block, moe_specs


# ---------------------------------------------------------------------------
# Init / specs
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    kg = KeyGen(key)
    p: Dict[str, Any] = {
        "attn_norm": init_norm(cfg.norm, cfg.d_model),
        "attn": init_attention(kg(), cfg),
        "mlp_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(kg(), cfg)
    else:
        p["mlp"] = init_mlp(kg(), cfg)
    return p


def init_lm(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    kg = KeyGen(key)
    layer_keys = jax.random.split(kg(), cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    params: Dict[str, Any] = {
        "embed": embed_init(kg(), (cfg.vocab_size, cfg.d_model)),
        "layers": layers,
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(kg(), (cfg.d_model, cfg.vocab_size))
    return cast_tree(params, jnp.dtype(cfg.dtype))


def lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    lp: Dict[str, Any] = {
        "attn_norm": norm_specs(cfg.norm),
        "attn": attention_specs(cfg),
        "mlp_norm": norm_specs(cfg.norm),
    }
    if cfg.family == "moe":
        lp["moe"] = moe_specs(cfg)
    else:
        lp["mlp"] = mlp_specs(cfg)
    # prepend the stacked "layers" axis to every layer param
    lp = jax.tree_util.tree_map(lambda s: ("layers",) + s, lp,
                                is_leaf=lambda s: isinstance(s, tuple))
    specs: Dict[str, Any] = {
        "embed": ("vocab", "embed_unsharded"),
        "layers": lp,
        "final_norm": norm_specs(cfg.norm),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ("embed_unsharded", "vocab")
    return specs


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------


def _layer_fwd(lp: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig,
               positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = x + attention_block(
        lp["attn"], apply_norm(cfg.norm, x, lp["attn_norm"], cfg.norm_eps),
        cfg, positions=positions, causal=True)
    h = logical_constraint(h, "batch", "seq", None)
    hn = apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_block(lp["moe"], hn, cfg)
    else:
        y, aux = mlp_block(lp["mlp"], hn, cfg), jnp.zeros((), jnp.float32)
    out = h + y
    out = logical_constraint(out, "batch", "seq", None)
    return out, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0)
    return x.astype(jnp.dtype(cfg.dtype))


def unembed(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    return logical_constraint(logits, "batch", "seq", "vocab")


def lm_hidden(
    params: Dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: Optional[jnp.ndarray] = None,     # (B, S) int32
    embeds: Optional[jnp.ndarray] = None,     # (B, S, D) — VLM/audio stubs
    positions: Optional[jnp.ndarray] = None,  # (B,S) or (3,B,S) for M-RoPE
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Backbone forward. Returns (final-norm hidden (B,S,D), aux_loss) —
    the loss path unembeds per sequence chunk so full (B,S,V) logits never
    materialize (§Perf iteration C2')."""
    x = embed_tokens(params, tokens, cfg) if embeds is None \
        else embeds.astype(jnp.dtype(cfg.dtype))
    x = logical_constraint(x, "batch", "seq", None)

    body = _remat(
        lambda lp, x_: _layer_fwd(lp, x_, cfg, positions), cfg)

    def scan_body(carry, lp):
        x_, aux = carry
        x_new, aux_l = body(lp, x_)
        return (x_new, aux + aux_l), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    return x, aux


def lm_forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward. Returns (logits (B,S,V), aux_loss)."""
    x, aux = lm_hidden(params, cfg, tokens=tokens, embeds=embeds,
                       positions=positions)
    return unembed(params, x, cfg), aux


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, cache_len, kvh, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig) -> Dict[str, Any]:
    kv = ("layers", "batch", None, "kv_heads", "head_dim")
    return {"k": kv, "v": kv, "len": ()}


def lm_prefill(
    params: Dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: Optional[jnp.ndarray] = None,
    embeds: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
    cache_len: int,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Prefill pass: returns (last-token logits (B,V), populated cache)."""
    x = embed_tokens(params, tokens, cfg) if embeds is None \
        else embeds.astype(jnp.dtype(cfg.dtype))
    x = logical_constraint(x, "batch", "seq", None)
    s = x.shape[1]

    def scan_body(x_, lp):
        h = apply_norm(cfg.norm, x_, lp["attn_norm"], cfg.norm_eps)
        a, (kc, vc) = attention_prefill(lp["attn"], h, cfg, cache_len,
                                        positions=positions)
        h = x_ + a
        hn = apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_block(lp["moe"], hn, cfg)
        else:
            y = mlp_block(lp["mlp"], hn, cfg)
        out = logical_constraint(h + y, "batch", "seq", None)
        return out, (kc, vc)

    x, (k_all, v_all) = jax.lax.scan(scan_body, x, params["layers"])
    x = apply_norm(cfg.norm, x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)[:, 0]
    cache = {"k": k_all, "v": v_all,
             "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def init_slot_cache(cfg: ModelConfig, batch: int, cache_len: int,
                    dtype=None) -> Dict[str, Any]:
    """Slot-cache layout (serving engine): like :func:`init_cache` but with
    independent per-slot lengths ``lens: (batch,)`` instead of one shared
    scalar ``len`` — each batch row is a serving slot at its own position."""
    cache = init_cache(cfg, batch, cache_len, dtype)
    del cache["len"]
    cache["lens"] = jnp.zeros((batch,), jnp.int32)
    return cache


def lm_prefill_slotted(
    params: Dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: jnp.ndarray,          # (B, L) right-padded prompts
    lens: jnp.ndarray,            # (B,) true prompt lengths (<= L)
    cache_len: int,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Bucket prefill: prompts right-padded to a shared length ``L``.

    Causality keeps each row's first ``lens[b]`` positions independent of
    the pad tail, so the gathered last-real-token logits and the cache rows
    ``< lens[b]`` are exact; pad-tail KV rows hold garbage but stay masked
    forever because the slot's length is ``lens[b]``.  Returns per-row
    last-real-token logits ``(B, V)`` and a slot cache (``lens`` per row).
    """
    x = embed_tokens(params, tokens, cfg)
    x = logical_constraint(x, "batch", "seq", None)

    def scan_body(x_, lp):
        h = apply_norm(cfg.norm, x_, lp["attn_norm"], cfg.norm_eps)
        a, (kc, vc) = attention_prefill(lp["attn"], h, cfg, cache_len)
        h = x_ + a
        hn = apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_block(lp["moe"], hn, cfg)
        else:
            y = mlp_block(lp["mlp"], hn, cfg)
        out = logical_constraint(h + y, "batch", "seq", None)
        return out, (kc, vc)

    x, (k_all, v_all) = jax.lax.scan(scan_body, x, params["layers"])
    last = jnp.take_along_axis(
        x, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)  # (B, 1, D)
    last = apply_norm(cfg.norm, last, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, last, cfg)[:, 0]
    cache = {"k": k_all, "v": v_all, "lens": lens.astype(jnp.int32)}
    return logits, cache


def lm_decode_step_slotted(
    params: Dict[str, Any],
    cache: Dict[str, Any],        # slot cache: k/v + "lens" (B,)
    tokens: jnp.ndarray,          # (B, 1) int32
    active: jnp.ndarray,          # (B,) bool: rows that hold a live request
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step over every slot with independent lengths.

    Inactive slots still flow through the batch (their output logits are
    garbage and ignored by the engine) but their length does not advance,
    so the next admission's prefill overwrites a clean slot."""
    x = embed_tokens(params, tokens, cfg)
    lens = cache["lens"]

    def scan_body(x_, layer):
        lp, kc, vc = layer
        h = apply_norm(cfg.norm, x_, lp["attn_norm"], cfg.norm_eps)
        a, kc_new, vc_new = attention_decode_slotted(lp["attn"], h, kc, vc,
                                                     lens, cfg)
        h = x_ + a
        hn = apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_block(lp["moe"], hn, cfg)
        else:
            y = mlp_block(lp["mlp"], hn, cfg)
        return h + y, (kc_new, vc_new)

    x, (k_all, v_all) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"]))
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)[:, 0]
    new_cache = {"k": k_all, "v": v_all,
                 "lens": lens + active.astype(jnp.int32)}
    return logits, new_cache


def init_paged_cache(cfg: ModelConfig, slots: int, cache_len: int,
                     n_blocks: int, block_size: int,
                     dtype=None) -> Dict[str, Any]:
    """Paged cache layout: a global pool of fixed-size KV blocks shared by
    every slot, plus per-slot block tables.

    ``k``/``v``: (layers, n_blocks, block_size, KVH, hd) pools;
    ``tables``: (slots, cache_len // block_size) int32, sentinel
    ``n_blocks`` marks unallocated entries; ``lens``: per-slot lengths.
    Pools are zero-initialized so unwritten positions gather finite values
    (masked to exact zeros by the softmax)."""
    assert cache_len % block_size == 0, \
        "cache_len must be a block_size multiple"
    dtype = dtype or jnp.dtype(cfg.dtype)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, n_blocks, block_size, kvh, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "lens": jnp.zeros((slots,), jnp.int32),
        "tables": jnp.full((slots, cache_len // block_size), n_blocks,
                           jnp.int32),
    }


def paged_cache_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Axis-name specs for the paged cache: leaves with a "blocks" axis are
    pool-resident (spliced block/offset-wise); "batch" leaves are per-slot."""
    kv = ("layers", "blocks", "block", "kv_heads", "head_dim")
    return {"k": kv, "v": kv,
            "lens": ("batch",), "tables": ("batch", None)}


def lm_prefill_paged(
    params: Dict[str, Any],
    cfg: ModelConfig,
    *,
    tokens: jnp.ndarray,          # (B, L) right-padded prompts
    lens: jnp.ndarray,            # (B,) true prompt lengths (<= L)
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Bucket prefill for the paged engine: identical forward to the
    slotted prefill, but the K/V rows come back *unpadded* (cache_len = L)
    as a row cache the engine scatters into pool blocks — prefill never
    reserves worst-case dense rows."""
    return lm_prefill_slotted(params, cfg, tokens=tokens, lens=lens,
                              cache_len=tokens.shape[1])


def lm_decode_step_paged(
    params: Dict[str, Any],
    cache: Dict[str, Any],        # paged cache: k/v pools + lens + tables
    tokens: jnp.ndarray,          # (B, 1) int32
    active: jnp.ndarray,          # (B,) bool
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step over every slot against the shared block pool.

    Like :func:`lm_decode_step_slotted` but K/V scatter/gather goes
    through each slot's block table; inactive rows never write the pool
    (their blocks may have been reassigned)."""
    x = embed_tokens(params, tokens, cfg)
    lens, tables = cache["lens"], cache["tables"]

    def scan_body(x_, layer):
        lp, kc, vc = layer
        h = apply_norm(cfg.norm, x_, lp["attn_norm"], cfg.norm_eps)
        a, kc_new, vc_new = attention_decode_paged(
            lp["attn"], h, kc, vc, lens, tables, active, cfg)
        h = x_ + a
        hn = apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_block(lp["moe"], hn, cfg)
        else:
            y = mlp_block(lp["mlp"], hn, cfg)
        return h + y, (kc_new, vc_new)

    x, (k_all, v_all) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"]))
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)[:, 0]
    new_cache = {"k": k_all, "v": v_all, "tables": tables,
                 "lens": lens + active.astype(jnp.int32)}
    return logits, new_cache


def lm_decode_step(
    params: Dict[str, Any],
    cache: Dict[str, Any],
    tokens: jnp.ndarray,          # (B, 1) int32
    cfg: ModelConfig,
    embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step: returns (logits (B,V), updated cache)."""
    x = embed_tokens(params, tokens, cfg) if embeds is None \
        else embeds.astype(jnp.dtype(cfg.dtype))
    pos = cache["len"]

    def scan_body(x_, layer):
        lp, kc, vc = layer
        h = apply_norm(cfg.norm, x_, lp["attn_norm"], cfg.norm_eps)
        a, kc_new, vc_new = attention_decode(lp["attn"], h, kc, vc, pos, cfg)
        h = x_ + a
        hn = apply_norm(cfg.norm, h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_block(lp["moe"], hn, cfg)
        else:
            y = mlp_block(lp["mlp"], hn, cfg)
        return h + y, (kc_new, vc_new)

    x, (k_all, v_all) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"]))
    x = apply_norm(cfg.norm, x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)[:, 0]
    new_cache = {"k": k_all, "v": v_all, "len": pos + 1}
    return logits, new_cache
