"""ModelBundle: one functional API over every architecture family.

The launcher, trainer, server, dry-run and tests all consume this interface;
family dispatch happens once, here.

* ``apply_train(params, batch) -> (logits, aux)`` — full teacher-forced pass
* ``prefill(params, batch) -> (last_logits, cache)``
* ``decode_step(params, cache, batch) -> (logits, cache)``
* ``input_specs(cell) -> (tree of ShapeDtypeStruct, tree of logical axes)``
* ``cache_shapes(cell) -> tree of ShapeDtypeStruct`` (dry-run, no alloc)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ENCDEC_DECODE_ENC_LEN, ShapeCell
from repro.models import encdec as M_encdec
from repro.models import hybrid as M_hybrid
from repro.models import transformer as M_lm

I32 = jnp.int32


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    specs: Callable[[], Any]
    apply_train: Callable[[Any, Dict[str, Any]], Tuple[jnp.ndarray, jnp.ndarray]]
    prefill: Callable[[Any, Dict[str, Any]], Tuple[jnp.ndarray, Any]]
    decode_step: Callable[[Any, Any, Dict[str, Any]], Tuple[jnp.ndarray, Any]]
    make_cache: Callable[[int, int], Any]
    cache_specs: Callable[[], Any]
    # chunked-loss path (§Perf C2'): backbone hidden states + per-chunk
    # unembed, so (B, S, V) logits never fully materialize in training.
    apply_hidden: Optional[Callable[[Any, Dict[str, Any]],
                                    Tuple[jnp.ndarray, jnp.ndarray]]] = None
    unembed_chunk: Optional[Callable[[Any, jnp.ndarray], jnp.ndarray]] = None
    # slot-cache serving path (repro.serve, DESIGN.md §12): independent
    # per-slot sequence lengths — the cache carries ``lens: (slots,)``
    # instead of one shared scalar ``len``.
    # * ``prefill_slotted(params, {"tokens": (B, L), "lens": (B,),
    #   "cache_len": int}) -> (last-real-token logits (B, V), slot cache)``
    # * ``decode_slotted(params, cache, {"tokens": (B, 1),
    #   "active": (B,) bool}) -> (logits (B, V), slot cache)``
    # * ``make_slot_cache(slots, cache_len) -> slot cache``
    # ``prefill_pads`` says whether prefill_slotted accepts right-padded
    # prompts (lens[b] < L) — attention families do; SSM states fold every
    # token so hybrid buckets must be exact-length.
    prefill_slotted: Optional[Callable[[Any, Dict[str, Any]],
                                       Tuple[jnp.ndarray, Any]]] = None
    decode_slotted: Optional[Callable[[Any, Any, Dict[str, Any]],
                                      Tuple[jnp.ndarray, Any]]] = None
    make_slot_cache: Optional[Callable[[int, int], Any]] = None
    prefill_pads: bool = False
    # paged-cache serving path (DESIGN.md §15): K/V live in a global pool
    # of fixed-size blocks addressed through per-slot block tables, so the
    # engine admits on free *blocks* instead of worst-case dense slots.
    # * ``make_paged_cache(slots, cache_len, n_blocks, block_size)`` —
    #   pools + ``tables: (slots, cache_len // block_size)`` + ``lens``
    # * ``prefill_paged(params, {"tokens": (B, L), "lens": (B,)}) ->
    #   (logits, row cache)`` — K/V rows unpadded (cache_len = L); the
    #   engine scatters them into pool blocks
    # * ``decode_paged(params, cache, {"tokens", "active"})`` — like
    #   decode_slotted but through the block tables
    # * ``paged_cache_specs()`` — leaves with a "blocks" axis are
    #   pool-resident; "batch" leaves are per-slot
    prefill_paged: Optional[Callable[[Any, Dict[str, Any]],
                                     Tuple[jnp.ndarray, Any]]] = None
    decode_paged: Optional[Callable[[Any, Any, Dict[str, Any]],
                                    Tuple[jnp.ndarray, Any]]] = None
    make_paged_cache: Optional[Callable[[int, int, int, int], Any]] = None
    paged_cache_specs: Optional[Callable[[], Any]] = None

    # ------------------------------------------------------------ dry-run io
    def input_specs(self, cell: ShapeCell) -> Tuple[Dict[str, Any],
                                                    Dict[str, Any]]:
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        dt = jnp.dtype(cfg.dtype)
        tok = lambda shape: jax.ShapeDtypeStruct(shape, I32)
        emb = lambda shape: jax.ShapeDtypeStruct(shape, dt)

        if cell.kind == "decode":
            specs = {"tokens": tok((b, 1))}
            axes = {"tokens": ("batch", None)}
            return specs, axes

        if cfg.family == "vlm":
            specs = {"embeds": emb((b, s, cfg.d_model)),
                     "positions": tok((3, b, s))}
            axes = {"embeds": ("batch", "seq", None),
                    "positions": (None, "batch", "seq")}
        elif cfg.family == "encdec":
            sd = max(s // cfg.dec_ratio, 8)
            specs = {"frames": emb((b, s, cfg.d_model)),
                     "dec_tokens": tok((b, sd))}
            axes = {"frames": ("batch", "seq", None),
                    "dec_tokens": ("batch", "seq")}
        else:
            specs = {"tokens": tok((b, s))}
            axes = {"tokens": ("batch", "seq")}

        if cell.kind == "train":
            if cfg.family == "encdec":
                sd = max(s // cfg.dec_ratio, 8)
                specs["labels"] = tok((b, sd))
            else:
                specs["labels"] = tok((b, s))
            axes["labels"] = ("batch", "seq")
        return specs, axes

    def cache_shapes(self, cell: ShapeCell) -> Any:
        """ShapeDtypeStructs of the decode cache (no allocation)."""
        return jax.eval_shape(
            lambda: self.make_cache(cell.global_batch, cell.seq_len))

    def supports(self, cell: ShapeCell) -> Tuple[bool, str]:
        """Assignment skip rules (DESIGN.md §4)."""
        if cell.name == "long_500k" and not self.cfg.sub_quadratic:
            return False, ("full-attention arch: 500k-token KV decode is the "
                           "quadratic regime the assignment excludes")
        return True, ""


# ---------------------------------------------------------------------------
# Family adapters
# ---------------------------------------------------------------------------


def _lm_bundle(cfg: ModelConfig) -> ModelBundle:
    def apply_train(params, batch):
        return M_lm.lm_forward(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"))

    def prefill(params, batch):
        return M_lm.lm_prefill(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"),
                               cache_len=batch["cache_len"])

    def decode_step(params, cache, batch):
        return M_lm.lm_decode_step(params, cache, batch["tokens"], cfg)

    def apply_hidden(params, batch):
        return M_lm.lm_hidden(params, cfg, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"),
                              positions=batch.get("positions"))

    def prefill_slotted(params, batch):
        return M_lm.lm_prefill_slotted(params, cfg, tokens=batch["tokens"],
                                       lens=batch["lens"],
                                       cache_len=batch["cache_len"])

    def decode_slotted(params, cache, batch):
        return M_lm.lm_decode_step_slotted(params, cache, batch["tokens"],
                                           batch["active"], cfg)

    def prefill_paged(params, batch):
        return M_lm.lm_prefill_paged(params, cfg, tokens=batch["tokens"],
                                     lens=batch["lens"])

    def decode_paged(params, cache, batch):
        return M_lm.lm_decode_step_paged(params, cache, batch["tokens"],
                                         batch["active"], cfg)

    return ModelBundle(
        cfg=cfg,
        init=lambda rng: M_lm.init_lm(rng, cfg),
        specs=lambda: M_lm.lm_specs(cfg),
        apply_train=apply_train,
        prefill=prefill,
        decode_step=decode_step,
        make_cache=lambda b, s: M_lm.init_cache(cfg, b, s),
        cache_specs=lambda: M_lm.cache_specs(cfg),
        apply_hidden=apply_hidden,
        unembed_chunk=lambda params, x: M_lm.unembed(params, x, cfg),
        prefill_slotted=prefill_slotted,
        decode_slotted=decode_slotted,
        make_slot_cache=lambda b, s: M_lm.init_slot_cache(cfg, b, s),
        prefill_pads=True,
        prefill_paged=prefill_paged,
        decode_paged=decode_paged,
        make_paged_cache=lambda b, s, nb, bs: M_lm.init_paged_cache(
            cfg, b, s, nb, bs),
        paged_cache_specs=lambda: M_lm.paged_cache_specs(cfg),
    )


def _hybrid_bundle(cfg: ModelConfig) -> ModelBundle:
    def apply_train(params, batch):
        return M_hybrid.hybrid_forward(params, cfg, tokens=batch["tokens"])

    def prefill(params, batch):
        return M_hybrid.hybrid_prefill(params, cfg, tokens=batch["tokens"],
                                       cache_len=batch["cache_len"])

    def decode_step(params, cache, batch):
        return M_hybrid.hybrid_decode_step(params, cache, batch["tokens"],
                                           cfg)

    def prefill_slotted(params, batch):
        return M_hybrid.hybrid_prefill_slotted(
            params, cfg, tokens=batch["tokens"], lens=batch["lens"],
            cache_len=batch["cache_len"])

    def decode_slotted(params, cache, batch):
        return M_hybrid.hybrid_decode_step_slotted(
            params, cache, batch["tokens"], batch["active"], cfg)

    def prefill_paged(params, batch):
        return M_hybrid.hybrid_prefill_paged(
            params, cfg, tokens=batch["tokens"], lens=batch["lens"])

    def decode_paged(params, cache, batch):
        return M_hybrid.hybrid_decode_step_paged(
            params, cache, batch["tokens"], batch["active"], cfg)

    return ModelBundle(
        cfg=cfg,
        init=lambda rng: M_hybrid.init_hybrid(rng, cfg),
        specs=lambda: M_hybrid.hybrid_specs(cfg),
        apply_train=apply_train,
        prefill=prefill,
        decode_step=decode_step,
        make_cache=lambda b, s: M_hybrid.init_hybrid_cache(cfg, b, s),
        cache_specs=lambda: M_hybrid.hybrid_cache_specs(cfg),
        apply_hidden=lambda params, batch: M_hybrid.hybrid_hidden(
            params, cfg, tokens=batch["tokens"]),
        unembed_chunk=lambda params, x: M_hybrid.hybrid_unembed(
            params, x, cfg),
        prefill_slotted=prefill_slotted,
        decode_slotted=decode_slotted,
        make_slot_cache=lambda b, s: M_hybrid.init_hybrid_slot_cache(
            cfg, b, s),
        prefill_pads=False,
        prefill_paged=prefill_paged,
        decode_paged=decode_paged,
        make_paged_cache=lambda b, s, nb, bs: M_hybrid.init_hybrid_paged_cache(
            cfg, b, s, nb, bs),
        paged_cache_specs=lambda: M_hybrid.hybrid_paged_cache_specs(cfg),
    )


def _encdec_bundle(cfg: ModelConfig) -> ModelBundle:
    def apply_train(params, batch):
        return M_encdec.encdec_forward(params, cfg, frames=batch["frames"],
                                       dec_tokens=batch["dec_tokens"])

    def prefill(params, batch):
        return M_encdec.encdec_prefill(params, cfg, frames=batch["frames"],
                                       dec_tokens=batch["dec_tokens"],
                                       cache_len=batch["cache_len"])

    def decode_step(params, cache, batch):
        return M_encdec.encdec_decode_step(params, cache, batch["tokens"],
                                           cfg)

    return ModelBundle(
        cfg=cfg,
        init=lambda rng: M_encdec.init_encdec(rng, cfg),
        specs=lambda: M_encdec.encdec_specs(cfg),
        apply_train=apply_train,
        prefill=prefill,
        decode_step=decode_step,
        make_cache=lambda b, s: M_encdec.init_encdec_cache(
            cfg, b, s, ENCDEC_DECODE_ENC_LEN),
        cache_specs=lambda: M_encdec.encdec_cache_specs(cfg),
        apply_hidden=lambda params, batch: M_encdec.encdec_hidden(
            params, cfg, frames=batch["frames"],
            dec_tokens=batch["dec_tokens"]),
        unembed_chunk=lambda params, x: M_encdec.encdec_unembed(
            params, x, cfg),
    )


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family in ("dense", "moe", "vlm"):
        return _lm_bundle(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return _hybrid_bundle(cfg)
    if cfg.family == "encdec":
        return _encdec_bundle(cfg)
    raise ValueError(f"unknown family: {cfg.family}")
