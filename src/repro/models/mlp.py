"""Feed-forward blocks: SwiGLU (llama-style) and GELU (whisper-style)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init


def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int = 0
             ) -> Dict[str, jnp.ndarray]:
    kg = KeyGen(key)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "gate": dense_init(kg(), (d, f), d),
            "up": dense_init(kg(), (d, f), d),
            "down": dense_init(kg(), (f, d), f),
        }
    return {
        "up": dense_init(kg(), (d, f), d),
        "up_b": jnp.zeros((f,), jnp.float32),
        "down": dense_init(kg(), (f, d), f),
        "down_b": jnp.zeros((d,), jnp.float32),
    }


def mlp_specs(cfg: ModelConfig, prefix: Tuple = ()) -> Dict[str, Tuple]:
    if cfg.act == "swiglu":
        return {
            "gate": prefix + ("embed", "mlp"),
            "up": prefix + ("embed", "mlp"),
            "down": prefix + ("mlp", "embed"),
        }
    return {
        "up": prefix + ("embed", "mlp"),
        "up_b": prefix + ("mlp",),
        "down": prefix + ("mlp", "embed"),
        "down_b": prefix + (None,),
    }


def mlp_block(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig
              ) -> jnp.ndarray:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["gate"].astype(x.dtype)) \
            * (x @ p["up"].astype(x.dtype))
        return h @ p["down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["up"].astype(x.dtype)
                    + p["up_b"].astype(x.dtype), approximate=True)
    return h @ p["down"].astype(x.dtype) + p["down_b"].astype(x.dtype)
