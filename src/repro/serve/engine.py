"""Continuous-batching inference engine over slot caches (DESIGN.md §12).

The production serving loop for search winners and the LM zoo: requests are
admitted into per-slot cache rows the moment a slot frees (no wave
barrier), prefill runs in padding-bucketed batches (serve/buckets.py), and
decode is ONE jitted step over all slots per iteration — every batch row is
a slot at its own sequence position (``cache["lens"]``), so mixed prompt
and output lengths coexist in flight.

Greedy decode through the engine is bit-identical per request to a scalar
one-request reference (:func:`greedy_reference`): every model op on the
batch axis is row-local, prefill buckets right-pad (masked contributions
are exact zeros), and the slotted decode step shares the scalar path's
arithmetic (models/attention.py).

Wall-clock behaviour: ``run(requests)`` honours each request's
``arrival_s`` (open-loop load — the Poisson generator in serve/loadgen.py);
``realtime=False`` collapses arrivals to "already queued" for deterministic
tests.

Failure semantics (DESIGN.md §13) — an always-on edge deployment needs
explicit answers to "what if it never finishes / keeps arriving / must
shut down":

* **deadlines** — a request carrying ``deadline_s`` (latency budget from
  arrival) is expired the moment the budget runs out: its slot is
  reclaimed for the next waiting request and the partial output is
  returned flagged ``expired`` (on the virtual clock one decode step is
  one second, so budgets are deterministic step counts in tests);
* **backpressure** — ``EngineConfig.max_queue`` bounds the admission
  queue; a submit over the bound is *rejected explicitly* (flagged
  ``rejected``, returned unserved) instead of growing the queue without
  limit;
* **graceful drain** — :meth:`ServeEngine.drain` completes the in-flight
  requests without admitting more work, the shutdown path that never
  abandons a sequence mid-decode.

Replication hooks (DESIGN.md §14): the engine is also the unit a
:class:`~repro.serve.router.ReplicaRouter` replicates, so it exposes the
health/metrics surface the router dispatches on — :meth:`tick` (one
scheduling round on the *caller's* clock: expire → admit → decode),
:meth:`cancel` (withdraw a request without recording a result — the
hedge-loser / failover path), :meth:`take_finished` (drain completions
incrementally), and the :attr:`in_flight` / :attr:`queue_depth` /
:attr:`has_work` load metrics.  ``decode_steps`` doubles as the heartbeat
counter: a replica with work whose ``decode_steps`` stops advancing is
stalled.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultPlan
from repro.serve.buckets import build_buckets
from repro.serve.paged import BlockPool


@dataclasses.dataclass
class ServeRequest:
    """One inference request and its measured lifecycle."""

    rid: int
    prompt: np.ndarray             # (len,) int32
    max_new: int
    arrival_s: float = 0.0         # offset from the run's t0 (open loop)
    deadline_s: Optional[float] = None  # latency budget from arrival; the
    #   engine reclaims the slot and returns partial output on expiry
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    expired: bool = False          # deadline ran out (out = partial tokens)
    rejected: bool = False         # bounced off a full admission queue
    oom: bool = False              # shed by the paged engine when the block
    #   pool ran dry mid-decode (out = partial tokens, prefix of reference)
    blocks_held: int = 0           # peak cache blocks held (paged engine)
    # measured lifecycle (seconds from the run's t0)
    t_arrival: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0           # first token emitted (prefill argmax)
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_arrival


@dataclasses.dataclass
class EngineConfig:
    slots: int = 8                 # concurrent sequences in flight
    cache_len: int = 256           # per-slot KV/state capacity
    pad_to: int = 8                # prompt-length bucket granularity
    max_prefill_batch: int = 8     # rows per prefill dispatch
    max_wait: int = 0              # admission rounds a ready request may be
    #   held to fill a denser bucket (0 = admit immediately; latency knob)
    max_queue: Optional[int] = None  # admission-queue bound: a submit over
    #   it is rejected explicitly (backpressure).  None = unbounded
    # paged KV cache (DESIGN.md §15): admit on free *blocks* instead of
    # worst-case dense slots.  ``n_blocks=None`` sizes the pool for the
    # worst case (slots * cache_len / block_size — never OOMs); a smaller
    # pool trades capacity for memory, with explicit OOM shedding.
    paged: bool = False
    block_size: int = 16           # tokens per cache block
    n_blocks: Optional[int] = None  # pool size; None = worst case


class ServeEngine:
    """Slot-cache continuous batching over a ModelBundle's slotted path."""

    def __init__(self, bundle, params, config: Optional[EngineConfig] = None,
                 faults: Optional[FaultPlan] = None):
        cfg = config or EngineConfig()
        self.faults = faults  # "serve.decode" inject point (DESIGN.md §13)
        if bundle.decode_slotted is None or bundle.prefill_slotted is None:
            raise ValueError(
                f"family {bundle.cfg.family!r} has no slotted serving path "
                f"(supported: decoder-only LM and SSM/hybrid families)")
        if cfg.pad_to > 1 and not bundle.prefill_pads:
            raise ValueError(
                f"family {bundle.cfg.family!r} folds every prompt token "
                f"into running state — right-padded prefill buckets would "
                f"corrupt it; use pad_to=1 (exact-length buckets)")
        self.bundle = bundle
        self.params = params
        self.cfg = cfg
        self._specs = {k: v for k, v in bundle.cache_specs().items()
                       if k != "len"}
        self.paged = cfg.paged
        self.pool: Optional[BlockPool] = None
        if cfg.paged:
            if (bundle.decode_paged is None or bundle.prefill_paged is None
                    or bundle.make_paged_cache is None):
                raise ValueError(
                    f"family {bundle.cfg.family!r} has no paged serving "
                    f"path (supported: decoder-only LM and SSM/hybrid "
                    f"families)")
            if cfg.cache_len % cfg.block_size:
                raise ValueError(
                    f"cache_len {cfg.cache_len} is not a multiple of "
                    f"block_size {cfg.block_size}")
            max_blocks = cfg.cache_len // cfg.block_size
            n_blocks = cfg.n_blocks or cfg.slots * max_blocks
            self.pool = BlockPool(n_blocks, cfg.block_size, cfg.slots,
                                  max_blocks)
            # pool-resident leaves are spliced block/offset-wise; per-slot
            # leaves (hybrid conv/SSM state) splice at their batch axis
            pspecs = bundle.paged_cache_specs()
            self._pool_specs = {k: v for k, v in pspecs.items()
                                if k not in ("lens", "tables")
                                and "blocks" in v}
            self._row_specs = {k: v for k, v in pspecs.items()
                               if k not in ("lens", "tables")
                               and "blocks" not in v}
            self._tables_dirty = False

        def _prefill(params, tokens, lens):
            return bundle.prefill_slotted(
                params, {"tokens": tokens, "lens": lens,
                         "cache_len": cfg.cache_len})

        def _decode(params, cache, tokens, active):
            return bundle.decode_slotted(
                params, cache, {"tokens": tokens, "active": active})

        def _splice(cache, cache1, slot_idx):
            # scatter each prefill row's cache into its slot; rows whose
            # slot index is out of range (batch padding) are dropped
            out = dict(cache)
            for key, spec in self._specs.items():
                ax = spec.index("batch")
                idx = (slice(None),) * ax + (slot_idx,)
                out[key] = cache[key].at[idx].set(cache1[key], mode="drop")
            out["lens"] = cache["lens"].at[slot_idx].set(
                cache1["lens"], mode="drop")
            return out

        def _prefill_paged(params, tokens, lens):
            return bundle.prefill_paged(
                params, {"tokens": tokens, "lens": lens})

        def _decode_paged(params, cache, tokens, active):
            return bundle.decode_paged(
                params, cache, {"tokens": tokens, "active": active})

        def _splice_paged(cache, rows, slot_idx, blk, off):
            # scatter prefill rows into the block pool: (B, L) block /
            # offset index arrays computed host-side from the allocator;
            # sentinel block indices (pad rows, pad tail) are dropped
            out = dict(cache)
            for key, spec in self._pool_specs.items():
                ax = spec.index("blocks")
                idx = (slice(None),) * ax + (blk, off)
                out[key] = cache[key].at[idx].set(rows[key], mode="drop")
            for key, spec in self._row_specs.items():
                ax = spec.index("batch")
                idx = (slice(None),) * ax + (slot_idx,)
                out[key] = cache[key].at[idx].set(rows[key], mode="drop")
            out["lens"] = cache["lens"].at[slot_idx].set(
                rows["lens"], mode="drop")
            return out

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._splice = jax.jit(_splice)
        if cfg.paged:
            self._prefill_paged = jax.jit(_prefill_paged)
            self._decode_paged = jax.jit(_decode_paged)
            self._splice_paged = jax.jit(_splice_paged)
        self.reset()

    # ------------------------------------------------------------ lifecycle
    def reset(self) -> None:
        """Fresh slot state (cache arrays are reallocated; the jitted
        executables persist, so a warmed engine stays warm)."""
        cfg = self.cfg
        if self.paged:
            self.pool.reset()
            self.cache = self.bundle.make_paged_cache(
                cfg.slots, cfg.cache_len, self.pool.n_blocks, cfg.block_size)
            self._tables_dirty = False
        else:
            self.cache = self.bundle.make_slot_cache(cfg.slots,
                                                     cfg.cache_len)
        self.active: List[Optional[ServeRequest]] = [None] * cfg.slots
        self.last_tok = np.zeros((cfg.slots,), np.int32)
        self.waiting: List[ServeRequest] = []   # arrived, not yet admitted
        self.finished: List[ServeRequest] = []
        self.rejected: List[ServeRequest] = []  # bounced at admission
        self.decode_steps = 0
        self.prefill_calls = 0
        self.shed_blocks = 0        # paged OOM sheds (explicit, counted)
        self.peak_concurrency = 0   # max sequences simultaneously in flight

    def submit(self, req: ServeRequest) -> bool:
        """Queue a request.  Returns ``False`` (and flags the request
        ``rejected``) when the bounded admission queue is full — explicit
        backpressure the caller can act on, instead of unbounded queue
        growth.  Malformed requests still raise."""
        if len(req.prompt) > self.cfg.cache_len:
            raise ValueError(f"request {req.rid}: prompt length "
                             f"{len(req.prompt)} exceeds cache_len "
                             f"{self.cfg.cache_len}")
        if self.paged:
            need = self.pool.blocks_for(len(req.prompt))
            if need > self.pool.n_blocks:
                # would never fit even an empty pool: reject explicitly
                # (truncating the prompt would silently change the output)
                raise ValueError(
                    f"request {req.rid}: prompt needs {need} cache blocks "
                    f"but the pool only has {self.pool.n_blocks}")
        if self.cfg.max_queue is not None \
                and len(self.waiting) >= self.cfg.max_queue:
            req.rejected = True
            req.t_done = req.t_arrival
            self.rejected.append(req)
            return False
        self.waiting.append(req)
        return True

    def cancel(self, rid: int) -> Optional[ServeRequest]:
        """Withdraw a request without recording a result: an in-flight
        request's slot is reclaimed, a queued one leaves the queue.  The
        router's hedge-loser and failover path — the caller owns the
        request's fate.  Returns the withdrawn request, or ``None`` when
        ``rid`` is not held here (already finished, or never submitted)."""
        for s, r in enumerate(self.active):
            if r is not None and r.rid == rid:
                if self.paged:
                    self._release_blocks(s, r)
                self.active[s] = None
                return r
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                return self.waiting.pop(i)
        return None

    def take_finished(self) -> List[ServeRequest]:
        """Drain the finished list (completed + expired since the last
        take).  The router's per-tick completion collector; :meth:`run`
        keeps its own accounting and never calls this."""
        out = self.finished
        self.finished = []
        return out

    # ----------------------------------------------------- health / metrics
    @property
    def in_flight(self) -> List[ServeRequest]:
        """Requests currently occupying slots."""
        return [r for r in self.active if r is not None]

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.active)

    @property
    def free_blocks(self) -> Optional[int]:
        """Free cache blocks in the pool (``None`` for a dense engine) —
        the memory-depth signal the router's placement prefers."""
        return self.pool.free_count if self.paged else None

    def stats(self) -> Dict[str, Any]:
        """Counters for loadgen reports: throughput-side (decode steps,
        prefill dispatches), concurrency (peak sequences in flight) and —
        for the paged engine — block-pool residency."""
        d: Dict[str, Any] = {
            "decode_steps": self.decode_steps,
            "prefill_calls": self.prefill_calls,
            "peak_concurrency": self.peak_concurrency,
            "shed_blocks": self.shed_blocks,
        }
        if self.paged:
            d.update({
                "n_blocks": self.pool.n_blocks,
                "block_size": self.cfg.block_size,
                "free_blocks": self.pool.free_count,
                "peak_blocks_used": self.pool.peak_used,
            })
        return d

    # ------------------------------------------------------------ block pool
    def _release_blocks(self, slot: int, req: ServeRequest) -> None:
        """Return a leaving request's blocks to the pool (records its peak
        residency first; held counts are monotone until release)."""
        req.blocks_held = max(req.blocks_held, self.pool.held(slot))
        if self.pool.free_slot(slot):
            self._tables_dirty = True

    def _refresh_tables(self) -> None:
        """Push the allocator's block tables to the device cache whenever
        allocation changed since the last dispatch."""
        if self._tables_dirty:
            self.cache["tables"] = jnp.asarray(self.pool.table_array())
            self._tables_dirty = False

    def _grow_blocks(self, now: float) -> int:
        """Pre-decode growth: every active slot needs the block covering
        its next write position.  On pool exhaustion, sheds the
        youngest-admitted starved request (explicit OOM: ``oom`` flag,
        partial output kept — a prefix of the reference — and the
        ``shed_blocks`` counter bumped; zero silent drops), then retries
        the remaining starved slots with the freed blocks.  Returns the
        number shed."""
        need = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            pos = len(req.prompt) + len(req.out) - 1  # next write position
            need.append((req.t_admit, req.rid, s, pos))
        need.sort()
        before = self.pool.allocs
        pending = need
        shed = 0
        while True:
            failed = []
            for item in pending:
                _, _, s, pos = item
                if not self.pool.ensure(s, pos):
                    failed.append(item)
            if not failed:
                break
            _, _, s, _ = failed[-1]   # youngest admission among the starved
            req = self.active[s]
            req.oom = True
            req.done = True
            req.t_done = now
            self._release_blocks(s, req)
            self.finished.append(req)
            self.active[s] = None
            self.shed_blocks += 1
            shed += 1
            pending = failed[:-1]
        if self.pool.allocs != before:
            self._tables_dirty = True
        return shed

    # ------------------------------------------------------------ admission
    def _admit(self, now: float) -> int:
        """Fill free slots from the waiting queue (FCFS), one bucketed
        prefill dispatch per padded prompt length.  Returns the number of
        requests admitted."""
        free = [s for s, r in enumerate(self.active) if r is None]
        if not free or not self.waiting:
            return 0
        if self.paged:
            # admit while *blocks* are available, not worst-case slots:
            # strict FCFS — the first waiting request whose prompt doesn't
            # fit blocks the line (no length-based overtaking, so paged
            # admission order matches dense admission order exactly)
            reqs: List[ServeRequest] = []
            slots: List[int] = []
            for req in self.waiting:
                if len(reqs) >= len(free):
                    break
                need = self.pool.blocks_for(len(req.prompt))
                if not self.pool.can_alloc(need):
                    break
                slot = free[len(reqs)]
                self.pool.alloc(slot, need)
                reqs.append(req)
                slots.append(slot)
            if not reqs:
                return 0
            del self.waiting[:len(reqs)]
            self._tables_dirty = True
        else:
            take = min(len(free), len(self.waiting))
            reqs = self.waiting[:take]
            del self.waiting[:take]
            slots = free[:take]
        buckets = build_buckets([r.prompt for r in reqs], slots,
                                self.cfg.slots, pad_to=self.cfg.pad_to,
                                max_batch=self.cfg.max_prefill_batch)
        for b in buckets:
            if self.paged:
                self._refresh_tables()
                logits, rows_cache = self._prefill_paged(
                    self.params, jnp.asarray(b.tokens), jnp.asarray(b.lens))
                blk, off = self._block_offsets(b)
                self.cache = self._splice_paged(
                    self.cache, rows_cache, jnp.asarray(b.slot_idx),
                    jnp.asarray(blk), jnp.asarray(off))
            else:
                logits, cache1 = self._prefill(self.params,
                                               jnp.asarray(b.tokens),
                                               jnp.asarray(b.lens))
                self.cache = self._splice(self.cache, cache1,
                                          jnp.asarray(b.slot_idx))
            self.prefill_calls += 1
            first = np.asarray(jnp.argmax(logits, axis=-1))
            for row, i in enumerate(b.rows):
                req, slot = reqs[i], slots[i]
                req.out.append(int(first[row]))
                req.t_admit = now
                req.t_first = now
                self.active[slot] = req
                self.last_tok[slot] = first[row]
                self._maybe_finish(slot, now)
        return len(reqs)

    def _block_offsets(self, b):
        """(B, L) block / offset index arrays for a prefill bucket: row r,
        position p lands in ``table[slot_r][p // bs]`` at offset
        ``p % bs``; pad rows and pad-tail positions get the sentinel block
        (scatter-dropped)."""
        bp, L = b.tokens.shape
        bs = self.cfg.block_size
        pos = np.arange(L)
        blk = np.full((bp, L), self.pool.n_blocks, np.int32)
        off = np.tile((pos % bs).astype(np.int32), (bp, 1))
        for row in range(len(b.rows)):
            slot = int(b.slot_idx[row])
            ln = int(b.lens[row])
            table = np.asarray(self.pool.slot_blocks(slot), np.int32)
            blk[row, :ln] = table[pos[:ln] // bs]
        return blk, off

    def _maybe_finish(self, slot: int, now: float) -> None:
        req = self.active[slot]
        seq_len = len(req.prompt) + len(req.out)
        if len(req.out) >= req.max_new or seq_len >= self.cfg.cache_len:
            req.done = True
            req.t_done = now
            if self.paged:
                self._release_blocks(slot, req)
            self.finished.append(req)
            self.active[slot] = None

    def _expire(self, now: float) -> int:
        """Reclaim slots (and drop queued requests) whose deadline passed.
        An expired in-flight request keeps its partial output; the freed
        slot is immediately admittable.  Returns the number expired."""
        n = 0
        for s, req in enumerate(self.active):
            if req is None or req.deadline_s is None:
                continue
            if now - req.t_arrival >= req.deadline_s:
                req.expired = True
                req.done = True
                req.t_done = now
                if self.paged:
                    self._release_blocks(s, req)  # deadline block reclaim
                self.finished.append(req)
                self.active[s] = None   # slot reclaimed
                n += 1
        still = []
        for req in self.waiting:
            if req.deadline_s is not None \
                    and now - req.t_arrival >= req.deadline_s:
                req.expired = True
                req.done = True
                req.t_done = now
                self.finished.append(req)
                n += 1
            else:
                still.append(req)
        self.waiting = still
        return n

    # --------------------------------------------------------------- decode
    def step(self, now: float) -> int:
        """One jitted decode step over every slot.  Returns the number of
        live tokens produced."""
        active_mask = np.array([r is not None for r in self.active])
        if not active_mask.any():
            return 0
        if self.paged:
            # grow each active slot's table to cover this step's write
            # position; pool exhaustion sheds explicitly (OOM), so the
            # mask may shrink before the dispatch
            self._grow_blocks(now)
            active_mask = np.array([r is not None for r in self.active])
            if not active_mask.any():
                return 0
            self._refresh_tables()
            decode = self._decode_paged
        else:
            decode = self._decode
        logits, self.cache = decode(
            self.params, self.cache,
            jnp.asarray(self.last_tok[:, None]), jnp.asarray(active_mask))
        self.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        produced = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self.last_tok[s] = nxt[s]
            produced += 1
            self._maybe_finish(s, now)
        return produced

    # ----------------------------------------------------------------- tick
    def tick(self, now: float, *, realtime: bool = False
             ) -> Dict[str, float]:
        """One scheduling round on the caller's clock: expire deadlines,
        admit waiting requests (bucketed prefill), one jitted decode step.
        The router drives its replicas through this — each replica advances
        exactly one round per router tick, so a shared virtual clock stays
        meaningful across replicas.

        Returns ``{"produced", "admitted", "expired", "stall_s"}`` counts;
        ``stall_s`` is the injected ``serve.decode`` stall the caller must
        add to its virtual clock (``realtime=True`` sleeps it here)."""
        expired = self._expire(now)
        admitted = self._admit(now)
        self.peak_concurrency = max(self.peak_concurrency,
                                    sum(r is not None for r in self.active))
        stall_s = 0.0
        if self.faults is not None:
            # injected decode stall: the engine owns no clock of its own, so
            # the plan is consulted (check), never slept inside (fire) —
            # the caller's virtual clock advances deterministically instead
            spec = self.faults.check("serve.decode", step=self.decode_steps)
            if spec is not None and spec.kind in ("hang", "stall"):
                if realtime:
                    time.sleep(spec.hang_s)
                else:
                    stall_s = spec.hang_s
        produced = self.step(now + stall_s)
        return {"produced": produced, "admitted": admitted,
                "expired": expired, "stall_s": stall_s}

    # ------------------------------------------------------------------ run
    def run(self, requests: Sequence[ServeRequest], *,
            realtime: bool = False,
            log: Optional[Callable[[str], None]] = None
            ) -> List[ServeRequest]:
        """Serve a workload to completion.

        ``realtime=True`` honours each request's ``arrival_s`` against the
        wall clock (open-loop load; the loop sleeps when idle before the
        next arrival).  ``realtime=False`` runs on a virtual clock that
        ticks once per decode step — ``arrival_s`` (and ``deadline_s``)
        are then counted in decode steps, which makes mid-flight
        admission and deadline expiry deterministic for tests.

        Every submitted request comes back exactly once: completed,
        ``expired`` (deadline hit; partial output), or ``rejected``
        (bounced off a full admission queue, never served).
        """
        self.reset()
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        t0 = time.monotonic()
        clock = (lambda: time.monotonic() - t0) if realtime else None
        vnow = 0.0

        while pending or self.waiting or any(self.active):
            now = clock() if realtime else vnow
            while pending and pending[0].arrival_s <= now:
                req = pending.pop(0)
                req.t_arrival = req.arrival_s
                self.submit(req)
            if not realtime and not self.waiting and not any(self.active) \
                    and pending:
                vnow = pending[0].arrival_s  # idle jump to the next arrival
                continue
            t = self.tick(clock() if realtime else vnow, realtime=realtime)
            produced, admitted, expired = (t["produced"], t["admitted"],
                                           t["expired"])
            if not realtime:
                vnow += 1.0 + t["stall_s"]
            if produced == 0 and not admitted and not expired:
                if realtime and pending and not self.waiting \
                        and not any(self.active):
                    # idle gap in the open-loop schedule
                    gap = pending[0].arrival_s - (time.monotonic() - t0)
                    if gap > 0:
                        time.sleep(min(gap, 0.05))
            if log and (admitted or expired):
                log(f"[serve] t={now:7.3f}s active="
                    f"{sum(r is not None for r in self.active)} "
                    f"waiting={len(self.waiting)} pending={len(pending)} "
                    f"finished={len(self.finished)}")
        return sorted(self.finished + self.rejected, key=lambda r: r.rid)

    # ---------------------------------------------------------------- drain
    def drain(self, *, realtime: bool = False,
              log: Optional[Callable[[str], None]] = None
              ) -> List[ServeRequest]:
        """Graceful shutdown: decode the in-flight requests to completion
        WITHOUT admitting any more work.  Requests still waiting in the
        admission queue are left there untouched — the caller reroutes or
        fails them explicitly.  Returns the requests that finished during
        the drain (deadlines stay live, measured on the drain's own
        clock)."""
        t0 = time.monotonic()
        vnow = 0.0
        before = len(self.finished)
        while any(r is not None for r in self.active):
            now = (time.monotonic() - t0) if realtime else vnow
            # expire only in-flight work: queued requests are not ours to
            # time out here — we are shutting down, not serving
            for s, req in enumerate(self.active):
                if req is not None and req.deadline_s is not None \
                        and now - req.t_arrival >= req.deadline_s:
                    req.expired = True
                    req.done = True
                    req.t_done = now
                    if self.paged:
                        self._release_blocks(s, req)
                    self.finished.append(req)
                    self.active[s] = None
            self.step(now)
            if not realtime:
                vnow += 1.0
            if log:
                log(f"[serve] drain t={now:7.3f}s active="
                    f"{sum(r is not None for r in self.active)} "
                    f"waiting={len(self.waiting)} (held)")
        return self.finished[before:]


# ---------------------------------------------------------------------------
# Scalar reference
# ---------------------------------------------------------------------------


def greedy_reference(bundle, params, prompt: np.ndarray, max_new: int,
                     cache_len: int,
                     decode_jit: Optional[Callable] = None) -> List[int]:
    """One-request greedy decode through the *scalar* serving path
    (``bundle.prefill`` + ``bundle.decode_step`` with the shared scalar
    cache length) — the bit-parity oracle for the engine."""
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = bundle.prefill(params,
                                   {"tokens": toks, "cache_len": cache_len})
    out = [int(jnp.argmax(logits[0]))]
    dec = decode_jit or jax.jit(bundle.decode_step)
    while len(out) < max_new and len(prompt) + len(out) < cache_len:
        logits, cache = dec(params, cache,
                            {"tokens": jnp.asarray([[out[-1]]], jnp.int32)})
        out.append(int(jnp.argmax(logits[0])))
    return out
