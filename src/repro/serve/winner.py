"""Genome front-end: search winner → deployable server (DESIGN.md §12).

Closes HALF's loop (search → implement → deploy): pick the best feasible
candidate for a design goal (`select_for_goal`), train it to convergence,
compile the deployment artifact (BN-folded + quantized params, unrolling
plan, accumulator formats — core/compile_model.py), and serve batched
classification requests through one jitted deployment-mode forward.

The ECG winners are single-forward classifiers, so "serving" is the
prefill-only degenerate case of the engine: admission buckets by batch
size (the input length is fixed by the genome's decimation gene), no
decode loop, no cache.

:class:`ReplicatedWinner` is the classification analogue of the serving
router (DESIGN.md §14): the compiled winner's params are staged onto N
devices with one jitted forward each, batches round-robin across live
replicas, a replica that raises fails over to the next one mid-call
(same batch, bit-identical logits — the forward is deterministic), and a
failure streak quarantines the replica with the scheduler's last-live
protection.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile_model import CompiledModel, compile_candidate
from repro.core.faults import FaultPlan, InjectedCrash
from repro.core.genome import Genome, describe
from repro.core.objective_schema import DesignGoal
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import prep_inputs, train_candidate
from repro.serve.buckets import pad_batch


@dataclasses.dataclass
class ServableWinner:
    """A compiled search winner plus its jitted deployment forward."""

    genome: Genome
    compiled: CompiledModel
    goal: Optional[str]
    input_length: int
    train_meta: Dict[str, float]
    _predict: Any = None           # jitted (B, L, 2) -> (B, n_classes)
    batches_served: int = 0
    paged: bool = False            # KV-cache preference recorded for the
    #   token-serving deployment path (launch/serve.py --engine --paged);
    #   the classifier forward itself has no KV cache

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Deployment-mode logits for a batch of windows ``(B, L, 2)``.

        Inputs at the dataset's max resolution are decimated to the
        genome's input length; the batch is padded to a power of two so
        repeated serving hits a handful of compiled executables."""
        x = prep_inputs(np.asarray(x), self.input_length)
        b = x.shape[0]
        bp = pad_batch(b, max(b, 1))
        if bp != b:
            x = np.concatenate([x, np.zeros((bp - b,) + x.shape[1:],
                                            x.dtype)])
        logits = self._predict(jnp.asarray(x))
        self.batches_served += 1
        return np.asarray(logits[:b])

    def classify(self, x: np.ndarray) -> np.ndarray:
        return self.predict(x).argmax(axis=1)

    def report(self) -> str:
        lines = [f"goal={self.goal} input_length={self.input_length} "
                 f"det={self.train_meta['detection_rate']:.3f} "
                 f"fa={self.train_meta['false_alarm_rate']:.3f}"]
        lines.append(self.compiled.report())
        return "\n".join(lines)


class _WinnerReplica:
    """One staged copy of a compiled winner plus its health state."""

    def __init__(self, idx: int, predict: Any, device: Any):
        self.idx = idx
        self.predict = predict
        self.device = device
        self.live = True
        self.fail_streak = 0
        self.batches_served = 0


@dataclasses.dataclass
class ReplicatedWinner:
    """N device-affine copies of a :class:`ServableWinner` behind one
    ``predict``: round-robin dispatch over live replicas, mid-call
    failover on a raising replica (the jitted forward is deterministic,
    so the retried batch returns bit-identical logits), fail-streak
    quarantine with last-live protection (core/scheduler.py idiom)."""

    winner: ServableWinner
    replicas: List[_WinnerReplica]
    quarantine_after: int = 3
    faults: Optional[FaultPlan] = None  # "router.dispatch" inject point
    stats: Dict[str, Any] = dataclasses.field(default_factory=lambda: {
        "batches": 0, "failovers": 0, "quarantined": []})

    @property
    def input_length(self) -> int:
        return self.winner.input_length

    @property
    def live_replicas(self) -> List[int]:
        return [r.idx for r in self.replicas if r.live]

    def _fail(self, rep: _WinnerReplica) -> None:
        rep.fail_streak += 1
        others = [r for r in self.replicas if r.live and r is not rep]
        if rep.fail_streak >= self.quarantine_after and others:
            rep.live = False
            self.stats["quarantined"].append(rep.idx)

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Deployment-mode logits for a batch ``(B, L, 2)``: dispatched to
        the next live replica (round-robin on batch count), failing over
        through the survivors when one raises.  Only when *every* live
        replica fails on the same batch does the error propagate."""
        x = prep_inputs(np.asarray(x), self.winner.input_length)
        b = x.shape[0]
        bp = pad_batch(b, max(b, 1))
        if bp != b:
            x = np.concatenate([x, np.zeros((bp - b,) + x.shape[1:],
                                            x.dtype)])
        xd = jnp.asarray(x)
        rid = self.stats["batches"]
        self.stats["batches"] += 1
        live = [r for r in self.replicas if r.live]
        order = live[rid % len(live):] + live[:rid % len(live)]
        last_err: Optional[BaseException] = None
        for i, rep in enumerate(order):
            if not rep.live:    # quarantined by an earlier lap's _fail
                continue
            try:
                if self.faults is not None:
                    spec = self.faults.check("router.dispatch", rid=rid,
                                             replica=rep.idx, tick=rid)
                    if spec is not None and spec.kind in ("crash",
                                                          "device_loss"):
                        raise InjectedCrash(
                            f"injected {spec.kind} at router.dispatch "
                            f"(replica {rep.idx})")
                logits = rep.predict(jnp.asarray(xd, copy=False)
                                     if rep.device is None
                                     else jax.device_put(xd, rep.device))
                rep.fail_streak = 0
                rep.batches_served += 1
                return np.asarray(logits[:b])
            except Exception as err:  # noqa: BLE001 — any replica failure
                last_err = err
                self._fail(rep)
                if i + 1 < len(order):
                    self.stats["failovers"] += 1
        raise RuntimeError(
            f"every live replica failed batch {rid}") from last_err

    def classify(self, x: np.ndarray) -> np.ndarray:
        return self.predict(x).argmax(axis=1)

    def report(self) -> str:
        live = sum(r.live for r in self.replicas)
        return (f"replicas={live}/{len(self.replicas)} live "
                f"(quarantined={self.stats['quarantined']})\n"
                + self.winner.report())


def replicate_winner(
    winner: ServableWinner,
    replicas: int = 2,
    *,
    devices: Optional[Sequence[Any]] = None,
    space: SearchSpace = DEFAULT_SPACE,
    quarantine_after: int = 3,
    faults: Optional[FaultPlan] = None,
) -> ReplicatedWinner:
    """Stage a compiled winner onto N replicas (device-affine when
    ``devices`` is given: replica i pins to ``devices[i % len]``) and
    front them with round-robin + failover dispatch.  Every replica runs
    the same deployment-mode forward on the same folded params, so
    replica choice never changes the logits."""
    from repro.core.trainer import forward

    if replicas < 1:
        raise ValueError("replicate_winner needs at least one replica")
    specs = winner.genome.phenotype(space)

    def _fwd(p, x):
        return forward(p, specs, x, quant=None, train=False)

    reps = []
    for i in range(replicas):
        dev = devices[i % len(devices)] if devices else None
        p = winner.compiled.params if dev is None \
            else jax.device_put(winner.compiled.params, dev)
        reps.append(_WinnerReplica(i, functools.partial(jax.jit(_fwd), p),
                                   dev))
    return ReplicatedWinner(winner=winner, replicas=reps,
                            quarantine_after=quarantine_after, faults=faults)


def compile_winner(
    genome: Genome,
    data_train: Tuple[np.ndarray, np.ndarray],
    data_val: Tuple[np.ndarray, np.ndarray],
    *,
    space: SearchSpace = DEFAULT_SPACE,
    goal: Optional[str] = None,
    train_steps: int = 300,
    train_batch: int = 64,
    seed: int = 0,
) -> ServableWinner:
    """Train + compile one genome into a :class:`ServableWinner`."""
    from repro.core.trainer import (evaluate, forward, init_candidate,
                                    presample_indices, refresh_bn_stats)
    from repro.optim import adamw
    from repro.core.trainer import make_train_step_indexed

    specs = genome.phenotype(space)
    quant = genome.quant(space)
    want_len = genome.input_length(space)
    x_tr = prep_inputs(data_train[0], want_len)

    rng = jax.random.PRNGKey(seed)
    params = init_candidate(rng, specs)
    opt = adamw(3e-3, b1=0.9, b2=0.99, weight_decay=1e-4)
    opt_state = opt.init(params)
    step_fn = make_train_step_indexed(specs, quant, opt)
    idx, calib_idx = presample_indices(seed, len(x_tr), train_steps,
                                       train_batch)
    x_dev, y_dev = jnp.asarray(x_tr), jnp.asarray(data_train[1])
    idx_dev = jnp.asarray(idx)
    for s in range(train_steps):
        params, opt_state, _ = step_fn(params, opt_state, x_dev, y_dev,
                                       idx_dev[s])
    params = refresh_bn_stats(params, specs, x_dev[jnp.asarray(calib_idx)],
                              quant)
    x_va = prep_inputs(data_val[0], want_len)
    det, fa, nll = evaluate(params, specs, quant, x_va, data_val[1])

    compiled = compile_candidate(genome, params, x_dev[jnp.asarray(calib_idx)],
                                 space=space)

    # one compiled deployment-mode executable (params are baked in as
    # constants — BN-folded and fake-quantized by compile_candidate)
    predict = jax.jit(lambda x: forward(compiled.params, specs, x,
                                        quant=None, train=False))
    return ServableWinner(
        genome=genome,
        compiled=compiled,
        goal=goal,
        input_length=want_len,
        train_meta={"detection_rate": det, "false_alarm_rate": fa,
                    "val_loss": nll, "steps": float(train_steps)},
        _predict=predict,
    )


def serve_winner(
    search,                         # EvolutionarySearch
    state,                          # NASState
    goal: Union[None, str, DesignGoal] = None,
    *,
    data_train: Tuple[np.ndarray, np.ndarray],
    data_val: Tuple[np.ndarray, np.ndarray],
    train_steps: int = 300,
    train_batch: int = 64,
    seed: int = 0,
    replicas: int = 1,
    devices: Optional[Sequence[Any]] = None,
    paged: bool = False,
    log=print,
) -> Union[ServableWinner, "ReplicatedWinner"]:
    """search → implement → deploy: pick the goal's best feasible
    candidate, train + compile it, return a serving handle.

    ``replicas > 1`` routes the winner through replicated dispatch
    (:func:`replicate_winner`): device-affine copies, round-robin +
    failover, fail-streak quarantine — the resilient deployment default.

    ``paged=True`` records the paged KV-cache preference on the handle
    for the token-serving deployment front-end (launch/serve.py builds
    ``EngineConfig(paged=True)`` from it — DESIGN.md §15); the winner's
    own classification forward is prefill-only and has no cache, so this
    changes nothing about ``predict``.

    Raises ``LookupError`` when no candidate meets the goal's constraints
    (serve nothing rather than an infeasible model)."""
    cand = search.select_for_goal(state, goal)
    if cand is None:
        raise LookupError(f"no feasible candidate for goal {goal!r} — "
                          f"run more generations")
    goal_name = goal if isinstance(goal, (str, type(None))) else goal.name
    log(f"[serve] winner for goal={goal_name}: "
        f"{describe(cand.genome, search.space)}")
    t0 = time.time()
    winner = compile_winner(cand.genome, data_train, data_val,
                            space=search.space, goal=goal_name,
                            train_steps=train_steps,
                            train_batch=train_batch, seed=seed)
    log(f"[serve] trained+compiled in {time.time()-t0:.1f}s "
        f"(det={winner.train_meta['detection_rate']:.3f} "
        f"fa={winner.train_meta['false_alarm_rate']:.3f})")
    if paged:
        winner.paged = True
        log("[serve] paged KV cache requested — recorded for the token-"
            "serving engine (the classifier forward itself is cache-free)")
    if replicas > 1:
        log(f"[serve] replicating winner onto {replicas} replicas")
        return replicate_winner(winner, replicas, devices=devices,
                                space=search.space)
    return winner
