"""Genome front-end: search winner → deployable server (DESIGN.md §12).

Closes HALF's loop (search → implement → deploy): pick the best feasible
candidate for a design goal (`select_for_goal`), train it to convergence,
compile the deployment artifact (BN-folded + quantized params, unrolling
plan, accumulator formats — core/compile_model.py), and serve batched
classification requests through one jitted deployment-mode forward.

The ECG winners are single-forward classifiers, so "serving" is the
prefill-only degenerate case of the engine: admission buckets by batch
size (the input length is fixed by the genome's decimation gene), no
decode loop, no cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compile_model import CompiledModel, compile_candidate
from repro.core.genome import Genome, describe
from repro.core.objective_schema import DesignGoal
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import prep_inputs, train_candidate
from repro.serve.buckets import pad_batch


@dataclasses.dataclass
class ServableWinner:
    """A compiled search winner plus its jitted deployment forward."""

    genome: Genome
    compiled: CompiledModel
    goal: Optional[str]
    input_length: int
    train_meta: Dict[str, float]
    _predict: Any = None           # jitted (B, L, 2) -> (B, n_classes)
    batches_served: int = 0

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Deployment-mode logits for a batch of windows ``(B, L, 2)``.

        Inputs at the dataset's max resolution are decimated to the
        genome's input length; the batch is padded to a power of two so
        repeated serving hits a handful of compiled executables."""
        x = prep_inputs(np.asarray(x), self.input_length)
        b = x.shape[0]
        bp = pad_batch(b, max(b, 1))
        if bp != b:
            x = np.concatenate([x, np.zeros((bp - b,) + x.shape[1:],
                                            x.dtype)])
        logits = self._predict(jnp.asarray(x))
        self.batches_served += 1
        return np.asarray(logits[:b])

    def classify(self, x: np.ndarray) -> np.ndarray:
        return self.predict(x).argmax(axis=1)

    def report(self) -> str:
        lines = [f"goal={self.goal} input_length={self.input_length} "
                 f"det={self.train_meta['detection_rate']:.3f} "
                 f"fa={self.train_meta['false_alarm_rate']:.3f}"]
        lines.append(self.compiled.report())
        return "\n".join(lines)


def compile_winner(
    genome: Genome,
    data_train: Tuple[np.ndarray, np.ndarray],
    data_val: Tuple[np.ndarray, np.ndarray],
    *,
    space: SearchSpace = DEFAULT_SPACE,
    goal: Optional[str] = None,
    train_steps: int = 300,
    train_batch: int = 64,
    seed: int = 0,
) -> ServableWinner:
    """Train + compile one genome into a :class:`ServableWinner`."""
    from repro.core.trainer import (evaluate, forward, init_candidate,
                                    presample_indices, refresh_bn_stats)
    from repro.optim import adamw
    from repro.core.trainer import make_train_step_indexed

    specs = genome.phenotype(space)
    quant = genome.quant(space)
    want_len = genome.input_length(space)
    x_tr = prep_inputs(data_train[0], want_len)

    rng = jax.random.PRNGKey(seed)
    params = init_candidate(rng, specs)
    opt = adamw(3e-3, b1=0.9, b2=0.99, weight_decay=1e-4)
    opt_state = opt.init(params)
    step_fn = make_train_step_indexed(specs, quant, opt)
    idx, calib_idx = presample_indices(seed, len(x_tr), train_steps,
                                       train_batch)
    x_dev, y_dev = jnp.asarray(x_tr), jnp.asarray(data_train[1])
    idx_dev = jnp.asarray(idx)
    for s in range(train_steps):
        params, opt_state, _ = step_fn(params, opt_state, x_dev, y_dev,
                                       idx_dev[s])
    params = refresh_bn_stats(params, specs, x_dev[jnp.asarray(calib_idx)],
                              quant)
    x_va = prep_inputs(data_val[0], want_len)
    det, fa, nll = evaluate(params, specs, quant, x_va, data_val[1])

    compiled = compile_candidate(genome, params, x_dev[jnp.asarray(calib_idx)],
                                 space=space)

    # one compiled deployment-mode executable (params are baked in as
    # constants — BN-folded and fake-quantized by compile_candidate)
    predict = jax.jit(lambda x: forward(compiled.params, specs, x,
                                        quant=None, train=False))
    return ServableWinner(
        genome=genome,
        compiled=compiled,
        goal=goal,
        input_length=want_len,
        train_meta={"detection_rate": det, "false_alarm_rate": fa,
                    "val_loss": nll, "steps": float(train_steps)},
        _predict=predict,
    )


def serve_winner(
    search,                         # EvolutionarySearch
    state,                          # NASState
    goal: Union[None, str, DesignGoal] = None,
    *,
    data_train: Tuple[np.ndarray, np.ndarray],
    data_val: Tuple[np.ndarray, np.ndarray],
    train_steps: int = 300,
    train_batch: int = 64,
    seed: int = 0,
    log=print,
) -> ServableWinner:
    """search → implement → deploy: pick the goal's best feasible
    candidate, train + compile it, return a serving handle.

    Raises ``LookupError`` when no candidate meets the goal's constraints
    (serve nothing rather than an infeasible model)."""
    cand = search.select_for_goal(state, goal)
    if cand is None:
        raise LookupError(f"no feasible candidate for goal {goal!r} — "
                          f"run more generations")
    goal_name = goal if isinstance(goal, (str, type(None))) else goal.name
    log(f"[serve] winner for goal={goal_name}: "
        f"{describe(cand.genome, search.space)}")
    t0 = time.time()
    winner = compile_winner(cand.genome, data_train, data_val,
                            space=search.space, goal=goal_name,
                            train_steps=train_steps,
                            train_batch=train_batch, seed=seed)
    log(f"[serve] trained+compiled in {time.time()-t0:.1f}s "
        f"(det={winner.train_meta['detection_rate']:.3f} "
        f"fa={winner.train_meta['false_alarm_rate']:.3f})")
    return winner
