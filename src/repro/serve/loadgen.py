"""Open-loop load generation + latency accounting (DESIGN.md §12, §14).

Open loop means arrivals follow their own clock (a Poisson process) and do
NOT wait for the server — the honest way to measure a serving system,
because a slow server accumulates queueing delay into the reported
latencies instead of silently throttling the load (closed-loop
coordinated omission).

Three arrival shapes, all seeded-deterministic:

* :func:`poisson_workload` — exponential inter-arrivals, the memoryless
  steady-state shape;
* :func:`gamma_workload` — gamma inter-arrivals with a chosen coefficient
  of variation: ``cv > 1`` produces heavy-tailed bursts (clumps of
  near-simultaneous arrivals separated by long gaps), the overload shape
  the replica router's load shedding is benchmarked under;
* :func:`onoff_workload` — on/off bursts: Poisson arrivals during on
  windows, silence during off windows — the diurnal/batch-upstream shape.
* :func:`longtail_workload` — Poisson arrivals with *log-normal* prompt
  lengths: most prompts short, a heavy tail near ``max_prompt`` — the
  length mix where paged KV allocation beats worst-case dense slots
  (DESIGN.md §15).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ServeRequest


def _requests_at(arrivals: np.ndarray, rng: np.random.Generator, *,
                 vocab_size: int, prompt_lens: Sequence[int],
                 out_lens: Sequence[int]) -> List[ServeRequest]:
    """Mixed prompt/output-length requests at the given arrival stamps.
    Draw order (one prompt-length choice, one prompt, one output choice
    per request) is part of the determinism contract."""
    reqs = []
    for i in range(len(arrivals)):
        plen = int(rng.choice(prompt_lens))
        reqs.append(ServeRequest(
            rid=i,
            prompt=rng.integers(0, vocab_size, plen).astype(np.int32),
            max_new=int(rng.choice(out_lens)),
            arrival_s=float(arrivals[i]),
        ))
    return reqs


def poisson_workload(
    n_requests: int,
    *,
    vocab_size: int,
    rate_per_s: float,
    prompt_lens: Sequence[int] = (4, 8, 12, 16, 24),
    out_lens: Sequence[int] = (4, 8, 12, 16, 24),
    seed: int = 0,
) -> List[ServeRequest]:
    """Mixed prompt/output-length requests with Poisson (exponential
    inter-arrival) timestamps.  ``rate_per_s=0`` degenerates to a burst
    (every request arrives at t=0) — the pure-throughput workload."""
    rng = np.random.default_rng(seed)
    if rate_per_s > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, n_requests))
    else:
        arrivals = np.zeros(n_requests)
    return _requests_at(arrivals, rng, vocab_size=vocab_size,
                        prompt_lens=prompt_lens, out_lens=out_lens)


def gamma_workload(
    n_requests: int,
    *,
    vocab_size: int,
    rate_per_s: float,
    cv: float = 3.0,
    prompt_lens: Sequence[int] = (4, 8, 12, 16, 24),
    out_lens: Sequence[int] = (4, 8, 12, 16, 24),
    seed: int = 0,
) -> List[ServeRequest]:
    """Heavy-tailed arrivals: gamma inter-arrival times with mean
    ``1/rate_per_s`` and coefficient of variation ``cv`` (shape
    ``1/cv**2``, scale ``cv**2/rate``).  ``cv=1`` recovers the
    exponential; ``cv > 1`` front-loads probability mass near zero with a
    long tail — clumps of back-to-back arrivals separated by idle gaps,
    the shape that drives a bounded admission queue into explicit
    shedding."""
    if rate_per_s <= 0:
        raise ValueError("gamma_workload needs rate_per_s > 0 "
                         "(use poisson_workload(rate_per_s=0) for a burst)")
    if cv <= 0:
        raise ValueError("coefficient of variation must be positive")
    rng = np.random.default_rng(seed)
    shape = 1.0 / (cv * cv)
    scale = (cv * cv) / rate_per_s
    arrivals = np.cumsum(rng.gamma(shape, scale, n_requests))
    return _requests_at(arrivals, rng, vocab_size=vocab_size,
                        prompt_lens=prompt_lens, out_lens=out_lens)


def onoff_workload(
    n_requests: int,
    *,
    vocab_size: int,
    rate_per_s: float,
    on_s: float,
    off_s: float,
    prompt_lens: Sequence[int] = (4, 8, 12, 16, 24),
    out_lens: Sequence[int] = (4, 8, 12, 16, 24),
    seed: int = 0,
) -> List[ServeRequest]:
    """On/off burst arrivals: Poisson at ``rate_per_s`` during ``on_s``-
    second windows, silence for ``off_s`` between them.  Implemented by
    drawing plain Poisson arrivals on a *busy-time* axis and folding that
    axis onto the wall clock, skipping the off windows — so every arrival
    lands strictly inside an on window and the within-burst statistics
    stay exactly Poisson."""
    if rate_per_s <= 0 or on_s <= 0 or off_s < 0:
        raise ValueError("onoff_workload needs rate_per_s > 0, on_s > 0, "
                         "off_s >= 0")
    rng = np.random.default_rng(seed)
    busy = np.cumsum(rng.exponential(1.0 / rate_per_s, n_requests))
    period = on_s + off_s
    arrivals = (busy // on_s) * period + (busy % on_s)
    return _requests_at(arrivals, rng, vocab_size=vocab_size,
                        prompt_lens=prompt_lens, out_lens=out_lens)


def longtail_workload(
    n_requests: int,
    *,
    vocab_size: int,
    rate_per_s: float,
    median_prompt: int = 6,
    sigma: float = 0.8,
    max_prompt: int = 64,
    out_lens: Sequence[int] = (4, 8, 12, 16),
    seed: int = 0,
) -> List[ServeRequest]:
    """Long-tail prompt-length mix: Poisson arrivals (``rate_per_s=0`` =
    burst) with prompt lengths drawn log-normally — median
    ``median_prompt``, log-space spread ``sigma``, clipped to
    ``[1, max_prompt]``.  Most prompts are a handful of tokens while a
    few approach ``max_prompt``; dense slots must reserve ``max_prompt``
    positions for everyone, a paged pool only pays for what each request
    actually holds.  Draw order (arrivals, then per-request prompt
    length / prompt / output choice) is part of the determinism
    contract."""
    if median_prompt < 1 or max_prompt < median_prompt:
        raise ValueError("need 1 <= median_prompt <= max_prompt")
    rng = np.random.default_rng(seed)
    if rate_per_s > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, n_requests))
    else:
        arrivals = np.zeros(n_requests)
    reqs = []
    for i in range(n_requests):
        plen = int(np.clip(round(rng.lognormal(np.log(median_prompt),
                                               sigma)), 1, max_prompt))
        reqs.append(ServeRequest(
            rid=i,
            prompt=rng.integers(0, vocab_size, plen).astype(np.int32),
            max_new=int(rng.choice(out_lens)),
            arrival_s=float(arrivals[i]),
        ))
    return reqs


def latency_stats(finished: Sequence[ServeRequest],
                  makespan_s: Optional[float] = None) -> Dict[str, float]:
    """p50/p99 end-to-end latency + time-to-first-token and throughput."""
    lat = np.array([r.latency_s for r in finished])
    ttft = np.array([r.ttft_s for r in finished])
    tokens = int(sum(len(r.out) for r in finished))
    span = makespan_s if makespan_s is not None else (
        max(r.t_done for r in finished) if len(finished) else 0.0)
    return {
        "requests": float(len(finished)),
        "tokens": float(tokens),
        "tok_per_s": tokens / span if span > 0 else 0.0,
        "p50_latency_s": float(np.percentile(lat, 50)) if len(lat) else 0.0,
        "p99_latency_s": float(np.percentile(lat, 99)) if len(lat) else 0.0,
        "p50_ttft_s": float(np.percentile(ttft, 50)) if len(ttft) else 0.0,
        "p99_ttft_s": float(np.percentile(ttft, 99)) if len(ttft) else 0.0,
        "makespan_s": float(span),
    }
