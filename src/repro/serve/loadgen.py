"""Open-loop load generation + latency accounting (DESIGN.md §12).

Open loop means arrivals follow their own clock (a Poisson process) and do
NOT wait for the server — the honest way to measure a serving system,
because a slow server accumulates queueing delay into the reported
latencies instead of silently throttling the load (closed-loop
coordinated omission).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ServeRequest


def poisson_workload(
    n_requests: int,
    *,
    vocab_size: int,
    rate_per_s: float,
    prompt_lens: Sequence[int] = (4, 8, 12, 16, 24),
    out_lens: Sequence[int] = (4, 8, 12, 16, 24),
    seed: int = 0,
) -> List[ServeRequest]:
    """Mixed prompt/output-length requests with Poisson (exponential
    inter-arrival) timestamps.  ``rate_per_s=0`` degenerates to a burst
    (every request arrives at t=0) — the pure-throughput workload."""
    rng = np.random.default_rng(seed)
    if rate_per_s > 0:
        arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, n_requests))
    else:
        arrivals = np.zeros(n_requests)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.choice(prompt_lens))
        reqs.append(ServeRequest(
            rid=i,
            prompt=rng.integers(0, vocab_size, plen).astype(np.int32),
            max_new=int(rng.choice(out_lens)),
            arrival_s=float(arrivals[i]),
        ))
    return reqs


def latency_stats(finished: Sequence[ServeRequest],
                  makespan_s: Optional[float] = None) -> Dict[str, float]:
    """p50/p99 end-to-end latency + time-to-first-token and throughput."""
    lat = np.array([r.latency_s for r in finished])
    ttft = np.array([r.ttft_s for r in finished])
    tokens = int(sum(len(r.out) for r in finished))
    span = makespan_s if makespan_s is not None else (
        max(r.t_done for r in finished) if len(finished) else 0.0)
    return {
        "requests": float(len(finished)),
        "tokens": float(tokens),
        "tok_per_s": tokens / span if span > 0 else 0.0,
        "p50_latency_s": float(np.percentile(lat, 50)) if len(lat) else 0.0,
        "p99_latency_s": float(np.percentile(lat, 99)) if len(lat) else 0.0,
        "p50_ttft_s": float(np.percentile(ttft, 50)) if len(ttft) else 0.0,
        "p99_ttft_s": float(np.percentile(ttft, 99)) if len(ttft) else 0.0,
        "makespan_s": float(span),
    }
