"""Continuous-batching inference serving (DESIGN.md §12).

* :class:`~repro.serve.engine.ServeEngine` — slot-cache continuous
  batching over a ModelBundle's slotted prefill/decode path.
* :mod:`repro.serve.loadgen` — open-loop Poisson workloads + latency stats.
* :func:`~repro.serve.winner.serve_winner` — genome front-end: NAS winner
  → train → compile → serve (search → implement → deploy).
"""
from repro.serve.buckets import PrefillBucket, build_buckets
from repro.serve.engine import (
    EngineConfig,
    ServeEngine,
    ServeRequest,
    greedy_reference,
)
from repro.serve.loadgen import latency_stats, poisson_workload
from repro.serve.winner import ServableWinner, compile_winner, serve_winner

__all__ = [
    "EngineConfig",
    "PrefillBucket",
    "ServableWinner",
    "ServeEngine",
    "ServeRequest",
    "build_buckets",
    "compile_winner",
    "greedy_reference",
    "latency_stats",
    "poisson_workload",
    "serve_winner",
]
