"""Continuous-batching inference serving (DESIGN.md §12, §14).

* :class:`~repro.serve.engine.ServeEngine` — slot-cache continuous
  batching over a ModelBundle's slotted prefill/decode path.
* :class:`~repro.serve.router.ReplicaRouter` — N engine replicas behind
  one submit/run/drain API: health-checked dispatch, failover, load
  shedding, hedged requests.
* :mod:`repro.serve.loadgen` — open-loop Poisson / heavy-tail / burst /
  long-tail-prompt workloads + latency stats.
* :mod:`repro.serve.paged` — :class:`~repro.serve.paged.BlockPool`
  block-granular KV-cache allocator behind ``EngineConfig(paged=True)``
  (DESIGN.md §15).
* :func:`~repro.serve.winner.serve_winner` — genome front-end: NAS winner
  → train → compile → serve (search → implement → deploy);
  :func:`~repro.serve.winner.replicate_winner` adds replicated dispatch.
"""
from repro.serve.buckets import PrefillBucket, build_buckets
from repro.serve.engine import (
    EngineConfig,
    ServeEngine,
    ServeRequest,
    greedy_reference,
)
from repro.serve.loadgen import (
    gamma_workload,
    latency_stats,
    longtail_workload,
    onoff_workload,
    poisson_workload,
)
from repro.serve.paged import BlockPool, blocks_for
from repro.serve.router import ReplicaRouter, RouterConfig
from repro.serve.winner import (
    ReplicatedWinner,
    ServableWinner,
    compile_winner,
    replicate_winner,
    serve_winner,
)

__all__ = [
    "BlockPool",
    "EngineConfig",
    "PrefillBucket",
    "ReplicaRouter",
    "ReplicatedWinner",
    "RouterConfig",
    "ServableWinner",
    "ServeEngine",
    "ServeRequest",
    "blocks_for",
    "build_buckets",
    "compile_winner",
    "gamma_workload",
    "greedy_reference",
    "latency_stats",
    "longtail_workload",
    "onoff_workload",
    "poisson_workload",
    "replicate_winner",
    "serve_winner",
]
