"""Prefill admission buckets (DESIGN.md §12).

The trainer's signature-bucket idiom (core/trainer_batch.py) applied to
serving: every distinct prefill shape ``(batch, length)`` is a compiled
executable, so admission quantizes both axes to keep the compile population
small and the batches dense.

* **length**: prompts are right-padded up to the next multiple of
  ``pad_to`` (granularity 1 = exact-length grouping — required for SSM
  families whose states fold every input token, and the bit-parity
  reference mode).  One bucket per padded length per admission round.
* **batch**: each bucket's row count is padded up to the next power of two
  (capped at ``max_batch``); pad rows carry dummy tokens and are scattered
  nowhere (their slot index is out of range and the cache splice drops
  out-of-bounds rows).

With ``pad_to=8`` and ``max_batch=8`` a workload of arbitrary prompt
lengths ≤ 32 compiles at most ``4 lengths × 4 batch sizes`` prefill
executables, ever.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


def pad_length(n: int, pad_to: int) -> int:
    """Smallest multiple of ``pad_to`` that is >= n."""
    return ((n + pad_to - 1) // pad_to) * pad_to


def pad_batch(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at ``max_batch``."""
    p = 1
    while p < n:
        p *= 2
    return min(p, max_batch)


@dataclasses.dataclass
class PrefillBucket:
    """One prefill dispatch: ``tokens (B_pad, L)`` right-padded rows, true
    ``lens``, and the destination slot per real row (pad rows get the
    out-of-range slot index ``n_slots`` and are dropped by the splice)."""

    tokens: np.ndarray      # (B_pad, L) int32
    lens: np.ndarray        # (B_pad,) int32 (pad rows: 1)
    slot_idx: np.ndarray    # (B_pad,) int32 (pad rows: n_slots → dropped)
    rows: List[int]         # indices into the admitted request list


def build_buckets(
    prompts: Sequence[np.ndarray],
    slots: Sequence[int],
    n_slots: int,
    *,
    pad_to: int = 1,
    max_batch: int = 8,
) -> List[PrefillBucket]:
    """Group admitted prompts by padded length into prefill dispatches.

    ``prompts[i]`` goes to slot ``slots[i]``.  Groups larger than
    ``max_batch`` split into chains of ``max_batch``-row dispatches.
    """
    by_len: Dict[int, List[int]] = {}
    for i, p in enumerate(prompts):
        by_len.setdefault(pad_length(len(p), pad_to), []).append(i)

    buckets = []
    for lpad, rows in sorted(by_len.items()):
        for lo in range(0, len(rows), max_batch):
            chunk = rows[lo: lo + max_batch]
            bp = pad_batch(len(chunk), max_batch)
            tokens = np.zeros((bp, lpad), np.int32)
            lens = np.ones((bp,), np.int32)
            slot_idx = np.full((bp,), n_slots, np.int32)
            for r, i in enumerate(chunk):
                tokens[r, : len(prompts[i])] = prompts[i]
                lens[r] = len(prompts[i])
                slot_idx[r] = slots[i]
            buckets.append(PrefillBucket(tokens=tokens, lens=lens,
                                         slot_idx=slot_idx, rows=chunk))
    return buckets
