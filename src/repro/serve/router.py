"""Replicated serving router: health-checked dispatch, failover, load
shedding and hedged requests (DESIGN.md §14).

A single :class:`~repro.serve.engine.ServeEngine` is a single point of
failure — one stalled or lost accelerator drops every request it holds.
:class:`ReplicaRouter` fronts N engine replicas (one per device, the
scheduler's device-affinity idiom) behind one submit/run/drain API and
adds the four behaviours an always-on deployment needs:

* **health-checked dispatch** — the router never trusts a replica's word:
  liveness is *derived from decode-step progress* (an engine with work
  whose ``decode_steps`` stops advancing is stalled, whatever it claims).
  ``heartbeat_misses`` consecutive progress-free ticks count one failure;
  ``quarantine_after`` failures — or a single injected ``device_loss`` —
  retire the replica.  The last live replica is never quarantined
  (partial progress beats none), mirroring the scheduler's device
  quarantine (core/scheduler.py).
* **failover** — requests in flight on a failed replica are re-dispatched
  to survivors *from the prompt*: greedy decode is deterministic, so the
  re-decoded output is bit-identical to the no-fault run (the chaos
  parity gate in tests/test_faults.py).  Failover requests jump the queue
  — they were admitted first, so FCFS order is preserved.
* **load shedding** — admission control rejects *explicitly* (flagged
  ``rejected``, returned unserved), never silently drops: a bounded
  router queue (``max_queue``) bounces overflow, and a request whose
  ``deadline_s`` is provably unmeetable (estimated queue wait from
  observed service times already exceeds it) is bounced up front rather
  than admitted to die.  Backpressure counts are surfaced in
  :attr:`ReplicaRouter.stats`.
* **hedged dispatch** — a request in flight longer than a seeded
  percentile of observed service times (``hedge_percentile`` over
  completions, once ``hedge_min_samples`` exist) is twinned onto a
  second replica — the speculation-twin idiom from the scheduler's
  straggler watcher.  First completion wins; the loser's slot is
  reclaimed (:meth:`ServeEngine.cancel`).

Clocks: like the engine, ``run(realtime=False)`` is a virtual clock —
one router tick = one decode step on every live replica = one second —
so every dispatch, failover, shed and hedge decision is deterministic
for tests and the bench.  ``realtime=True`` honours wall-clock arrivals.

Fault injection (seeded :class:`~repro.core.faults.FaultPlan`): the
router consults ``serve.replica`` once per live replica per tick
(``crash`` = replica loses its state and restarts, ``device_loss`` =
instant quarantine, ``stall`` = the replica silently stops progressing
for ``hang_s`` virtual seconds — only the heartbeat can notice) and
``router.dispatch`` at each hand-off (a dispatch-time ``crash`` /
``device_loss`` fails the chosen replica and requeues the request).
The router owns its clock, so it uses :meth:`FaultPlan.check`, never
``fire``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

import jax
import numpy as np

from repro.core.faults import FaultPlan
from repro.serve.engine import EngineConfig, ServeEngine, ServeRequest
from repro.serve.paged import blocks_for


@dataclasses.dataclass
class RouterConfig:
    """Router knobs on top of the per-replica :class:`EngineConfig`.

    The router does all admission control itself: replicas receive work
    only when they have free capacity, so ``engine.max_queue`` should be
    left ``None`` (the router's ``max_queue`` is the one bound)."""

    replicas: int = 2
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    max_queue: Optional[int] = None   # router admission bound (explicit
    #   rejection over it); None = unbounded
    shed_deadlines: bool = True       # bounce requests whose deadline the
    #   queue-wait estimate already breaks
    heartbeat_misses: int = 3         # progress-free ticks (with work) that
    #   count one replica failure
    quarantine_after: int = 3         # failure streak that retires a replica
    hedge: bool = True                # twin stragglers onto a second replica
    hedge_percentile: float = 95.0    # straggler threshold over observed
    #   service times...
    hedge_min_samples: int = 8        # ...once this many completions exist


class _Replica:
    """One engine replica plus the router's health view of it."""

    def __init__(self, idx: int, engine: ServeEngine, device: Any):
        self.idx = idx
        self.engine = engine
        self.device = device
        self.live = True
        self.fail_streak = 0
        self.misses = 0            # consecutive progress-free busy ticks
        self.last_steps = 0        # decode_steps at the last heartbeat
        self.stalled_until = -1.0  # injected-stall horizon (hidden from
        #                            dispatch: only the heartbeat may react)
        self.restarts = 0

    @property
    def load(self) -> int:
        return len(self.engine.in_flight) + self.engine.queue_depth

    @property
    def free_slots(self) -> int:
        return self.engine.cfg.slots - self.load

    @property
    def free_blocks(self) -> Optional[int]:
        """Free KV-cache blocks (``None`` for dense engines).  The
        router prefers block-rich replicas and skips replicas whose pool
        cannot take a request's prompt — shedding/hedging on *block*
        depth, not just slot counts."""
        return self.engine.free_blocks


class _Flight:
    """One admitted request's dispatch state: which replicas hold a clone
    (one normally, two while hedged), and when it was first dispatched."""

    def __init__(self, req: ServeRequest, primary: int, t_dispatch: float):
        self.req = req
        self.clones: Dict[int, ServeRequest] = {}
        self.primary = primary
        self.t_dispatch = t_dispatch
        self.hedged = False


class ReplicaRouter:
    """Front N ``ServeEngine`` replicas behind one submit/run/drain API."""

    def __init__(self, bundle, params, config: Optional[RouterConfig] = None,
                 *, faults: Optional[FaultPlan] = None,
                 devices: Optional[Sequence[Any]] = None):
        cfg = config or RouterConfig()
        if cfg.replicas < 1:
            raise ValueError("ReplicaRouter needs at least one replica")
        self.cfg = cfg
        self.faults = faults
        self.replicas: List[_Replica] = []
        for i in range(cfg.replicas):
            # device affinity: replica i pins to devices[i % len(devices)]
            # (scheduler idiom) and stages its params there; None = default
            dev = devices[i % len(devices)] if devices else None
            p = params if dev is None else jax.device_put(params, dev)
            self.replicas.append(
                _Replica(i, ServeEngine(bundle, p, cfg.engine), dev))
        self.reset()

    # ------------------------------------------------------------ lifecycle
    def reset(self) -> None:
        """Fresh routing state; replica engines reset too (their jitted
        executables persist, so a warmed router stays warm)."""
        for rep in self.replicas:
            rep.engine.reset()
            rep.live = True
            rep.fail_streak = 0
            rep.misses = 0
            rep.last_steps = 0
            rep.stalled_until = -1.0
            rep.restarts = 0
        self.queue: Deque[ServeRequest] = deque()      # admitted, undispatched
        self._requeue: Deque[ServeRequest] = deque()   # failover evictions
        #   (dispatched first: they were admitted earliest — FCFS holds)
        self.flights: Dict[int, _Flight] = {}
        self.done: List[ServeRequest] = []
        self.shed: List[ServeRequest] = []
        self._service_times: List[float] = []  # dispatch→done, completions
        self.tick_no = 0
        self.stats: Dict[str, Any] = {
            "admitted": 0, "completed": 0, "expired": 0,
            "shed_queue": 0, "shed_deadline": 0,
            "dispatches": 0, "failovers": 0, "restarts": 0,
            "hedges": 0, "hedge_wins": 0, "ticks": 0,
            "shed_blocks": 0,
            "quarantined": [],
        }
        self._min_free_blocks: Optional[int] = None

    # ------------------------------------------------------------ admission
    def _est_wait_s(self) -> Optional[float]:
        """Estimated queueing delay for a request joining the queue now:
        full service rounds ahead of it, priced at the mean observed
        service time.  ``None`` until the first completion — admit
        optimistically rather than shed on a guess."""
        if not self._service_times:
            return None
        svc = float(np.mean(self._service_times))
        slots = sum(r.engine.cfg.slots for r in self.replicas if r.live)
        backlog = len(self.queue) + len(self._requeue)
        return ((backlog + max(slots, 1) - 1) // max(slots, 1)) * svc

    def _shed(self, req: ServeRequest, now: float, why: str) -> bool:
        req.rejected = True
        req.t_done = now
        self.shed.append(req)
        self.stats[f"shed_{why}"] += 1
        return False

    def submit(self, req: ServeRequest, now: float = 0.0) -> bool:
        """Admission control.  Returns ``False`` (request flagged
        ``rejected`` and returned by :meth:`run` unserved) when the
        bounded queue is full or the request's deadline is already
        unmeetable — explicit backpressure, never a silent drop.
        Malformed requests still raise."""
        if len(req.prompt) > self.cfg.engine.cache_len:
            raise ValueError(f"request {req.rid}: prompt length "
                             f"{len(req.prompt)} exceeds cache_len "
                             f"{self.cfg.engine.cache_len}")
        if self.cfg.engine.paged:
            pool = self.replicas[0].engine.pool
            need = pool.blocks_for(len(req.prompt))
            if need > pool.n_blocks:
                raise ValueError(f"request {req.rid}: prompt needs {need} "
                                 f"blocks but the pool only has "
                                 f"{pool.n_blocks}")
        if self.cfg.max_queue is not None \
                and len(self.queue) >= self.cfg.max_queue:
            return self._shed(req, now, "queue")
        if self.cfg.shed_deadlines and req.deadline_s is not None:
            est = self._est_wait_s()
            if est is not None and est >= req.deadline_s:
                return self._shed(req, now, "deadline")
        self.queue.append(req)
        self.stats["admitted"] += 1
        return True

    # -------------------------------------------------------------- faults
    def _check_faults(self, now: float) -> None:
        if self.faults is None:
            return
        for rep in self.replicas:
            if not rep.live:
                continue
            spec = self.faults.check("serve.replica", replica=rep.idx,
                                     tick=self.tick_no,
                                     step=rep.engine.decode_steps)
            if spec is None:
                continue
            if spec.kind == "device_loss":
                self._fail_replica(rep, lost=True)
            elif spec.kind == "crash":
                self._fail_replica(rep, lost=False)
            elif spec.kind in ("stall", "hang"):
                # silent: the replica just stops making progress; only the
                # heartbeat may notice (dispatch must not peek at this)
                rep.stalled_until = now + spec.hang_s

    # ------------------------------------------------- failure and failover
    def _fail_replica(self, rep: _Replica, *, lost: bool) -> None:
        """Handle one replica failure: evict its in-flight work for
        re-dispatch on survivors, then either quarantine the replica
        (``device_loss``, or a failure streak at ``quarantine_after``) or
        restart it.  The last live replica is never quarantined."""
        evicted: List[ServeRequest] = []
        for rid in list(self.flights):
            fl = self.flights[rid]
            if rep.idx not in fl.clones:
                continue
            del fl.clones[rep.idx]
            if not fl.clones:          # no surviving clone: full failover
                del self.flights[rid]
                evicted.append(fl.req)
                self.stats["failovers"] += 1
        # greedy decode is deterministic, so recomputing from the prompt
        # on a survivor reproduces the lost partial output bit for bit
        for req in sorted(evicted, key=lambda r: r.rid, reverse=True):
            self._requeue.appendleft(req)
        rep.engine.reset()
        rep.misses = 0
        rep.last_steps = 0
        rep.stalled_until = -1.0       # a restart clears an injected stall
        rep.fail_streak = self.cfg.quarantine_after if lost \
            else rep.fail_streak + 1
        others = [r for r in self.replicas if r.live and r is not rep]
        if rep.fail_streak >= self.cfg.quarantine_after and others:
            rep.live = False
            self.stats["quarantined"].append(rep.idx)
        else:
            rep.restarts += 1
            self.stats["restarts"] += 1

    # ------------------------------------------------------------- dispatch
    def _place(self, req: ServeRequest, rep: _Replica, now: float) -> None:
        """Hand one request to a replica as a *clone* — the original stays
        with the router so failover and hedging can re-issue it cleanly."""
        clone = ServeRequest(rid=req.rid, prompt=req.prompt,
                             max_new=req.max_new, arrival_s=req.arrival_s,
                             deadline_s=req.deadline_s)
        clone.t_arrival = req.t_arrival
        rep.engine.submit(clone)
        fl = self.flights.get(req.rid)
        if fl is None:
            fl = _Flight(req, rep.idx, now)
            self.flights[req.rid] = fl
        fl.clones[rep.idx] = clone
        self.stats["dispatches"] += 1

    def _need_blocks(self, req: ServeRequest) -> Optional[int]:
        """Blocks this request's prompt needs on a paged replica (``None``
        when the engines are dense)."""
        if not self.cfg.engine.paged:
            return None
        return blocks_for(len(req.prompt), self.cfg.engine.block_size)

    def _pick(self, exclude: Tuple[int, ...] = (),
              need_blocks: Optional[int] = None) -> Optional[_Replica]:
        """Least-loaded live replica with a free slot (ties: deepest free
        block pool, then lowest index).  When ``need_blocks`` is given,
        paged replicas whose pool cannot take the prompt right now are
        skipped — the request waits rather than being admitted to OOM.
        Health here is the *router's* view — a silently stalled replica
        still looks healthy until the heartbeat catches it."""
        cands = [r for r in self.replicas
                 if r.live and r.idx not in exclude and r.free_slots > 0]
        if need_blocks is not None:
            cands = [r for r in cands
                     if r.free_blocks is None or r.free_blocks >= need_blocks]
        if not cands:
            return None
        return min(cands, key=lambda r: (
            r.load,
            -(r.free_blocks if r.free_blocks is not None else 0),
            r.idx))

    def _dispatch(self, now: float, *, draining: bool = False) -> int:
        """Hand queued requests to replicas with free capacity — failover
        evictions first (oldest admissions), then the admission queue
        (skipped while draining).  The head request is peeked before
        placement so the pick can be block-aware; an unplaceable head
        blocks its line (FCFS, matching the engine's head-of-line
        admission)."""
        n = 0
        while True:
            src = self._requeue if self._requeue else \
                (self.queue if self.queue and not draining else None)
            if src is None:
                return n
            rep = self._pick(need_blocks=self._need_blocks(src[0]))
            if rep is None:
                return n
            req = src.popleft()
            if self.faults is not None:
                spec = self.faults.check("router.dispatch", rid=req.rid,
                                         replica=rep.idx, tick=self.tick_no)
                if spec is not None and spec.kind in ("crash",
                                                      "device_loss"):
                    # the hand-off itself surfaced the failure: requeue the
                    # request, fail the replica, try the next candidate
                    src.appendleft(req)
                    self._fail_replica(rep, lost=spec.kind == "device_loss")
                    continue
            self._place(req, rep, now)
            n += 1

    # --------------------------------------------------------------- hedge
    def _hedge(self, now: float) -> None:
        """Twin stragglers: a request in flight longer than the
        ``hedge_percentile`` of observed service times gets a second clone
        on a different replica (free capacity only — hedges never displace
        first dispatches).  First completion wins."""
        if not self.cfg.hedge \
                or len(self._service_times) < self.cfg.hedge_min_samples:
            return
        thresh = float(np.percentile(self._service_times,
                                     self.cfg.hedge_percentile))
        for fl in list(self.flights.values()):
            if fl.hedged or now - fl.t_dispatch <= thresh:
                continue
            rep = self._pick(exclude=tuple(fl.clones),
                             need_blocks=self._need_blocks(fl.req))
            if rep is None:
                continue
            self._place(fl.req, rep, now)
            fl.hedged = True
            self.stats["hedges"] += 1

    # ----------------------------------------------------- step + heartbeat
    def _step_replicas(self, now: float) -> int:
        produced = 0
        for rep in self.replicas:
            if not rep.live or now < rep.stalled_until:
                continue               # an injected stall makes no progress
            produced += int(rep.engine.tick(now)["produced"])
        if self.cfg.engine.paged:
            depth = min((r.free_blocks for r in self.replicas if r.live),
                        default=None)
            if depth is not None and (self._min_free_blocks is None
                                      or depth < self._min_free_blocks):
                self._min_free_blocks = depth
        return produced

    def _heartbeat(self, now: float) -> None:
        """Liveness from decode-step progress: a replica with work whose
        ``decode_steps`` did not advance this tick missed a heartbeat;
        ``heartbeat_misses`` in a row is a failure (evict + restart, or
        quarantine once the streak allows)."""
        for rep in self.replicas:
            if not rep.live:
                continue
            steps = rep.engine.decode_steps
            if rep.engine.has_work and steps == rep.last_steps:
                rep.misses += 1
                if rep.misses >= self.cfg.heartbeat_misses:
                    self._fail_replica(rep, lost=False)
                    continue           # _fail_replica reset the counters
            else:
                rep.misses = 0
            rep.last_steps = steps

    # ------------------------------------------------------------- collect
    def _collect(self, now: float) -> int:
        """Resolve finished clones: first completion wins, other clones are
        withdrawn (hedge loser's slot reclaimed), result copied onto the
        caller's request object."""
        n = 0
        for rep in self.replicas:
            for clone in rep.engine.take_finished():
                fl = self.flights.pop(clone.rid, None)
                if fl is None:
                    continue           # hedge twin of an already-won rid
                req = fl.req
                req.out = clone.out
                req.done = clone.done
                req.expired = clone.expired
                req.t_admit = clone.t_admit
                req.t_first = clone.t_first
                req.t_done = clone.t_done
                req.oom = clone.oom
                req.blocks_held = clone.blocks_held
                if clone.oom:
                    self.stats["shed_blocks"] += 1
                for ridx in fl.clones:
                    if ridx != rep.idx:
                        self.replicas[ridx].engine.cancel(clone.rid)
                if fl.hedged and rep.idx != fl.primary:
                    self.stats["hedge_wins"] += 1
                if clone.expired:
                    self.stats["expired"] += 1
                else:
                    self.stats["completed"] += 1
                    self._service_times.append(clone.t_done - fl.t_dispatch)
                self.done.append(req)
                n += 1
        return n

    def _expire_queued(self, now: float) -> int:
        """Expire undispatched requests whose deadline passed while they
        queued (mirrors the engine's queued-expiry semantics)."""
        n = 0
        for q in (self._requeue, self.queue):
            keep = []
            for req in q:
                if req.deadline_s is not None \
                        and now - req.t_arrival >= req.deadline_s:
                    req.expired = True
                    req.done = True
                    req.t_done = now
                    self.done.append(req)
                    self.stats["expired"] += 1
                    n += 1
                else:
                    keep.append(req)
            q.clear()
            q.extend(keep)
        return n

    # ------------------------------------------------------------------ run
    def _busy(self) -> bool:
        return bool(self.queue or self._requeue or self.flights)

    def run(self, requests: Sequence[ServeRequest], *,
            realtime: bool = False,
            log: Optional[Callable[[str], None]] = None
            ) -> List[ServeRequest]:
        """Serve a workload to completion across the replica set.

        Every submitted request comes back exactly once: completed
        (bit-identical to the single-engine greedy output, faults or not),
        ``expired`` (deadline hit) or ``rejected`` (shed explicitly at
        admission).  :attr:`stats` carries the backpressure/robustness
        summary: shed counts, failovers, restarts, hedges, quarantines."""
        self.reset()
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        t0 = time.monotonic()
        vnow = 0.0
        while pending or self._busy():
            now = (time.monotonic() - t0) if realtime else vnow
            while pending and pending[0].arrival_s <= now:
                req = pending.pop(0)
                req.t_arrival = req.arrival_s
                self.submit(req, now)
            if not realtime and not self._busy() and pending:
                vnow = pending[0].arrival_s  # idle jump to the next arrival
                continue
            self.tick_no += 1
            self._check_faults(now)
            self._expire_queued(now)
            self._dispatch(now)
            self._hedge(now)
            produced = self._step_replicas(now)
            self._heartbeat(now)
            self._collect(now)
            if not realtime:
                vnow += 1.0
            elif produced == 0 and pending and not self._busy():
                gap = pending[0].arrival_s - (time.monotonic() - t0)
                if gap > 0:
                    time.sleep(min(gap, 0.05))
            if log:
                live = sum(r.live for r in self.replicas)
                log(f"[router] t={now:7.3f}s live={live}/"
                    f"{len(self.replicas)} flights={len(self.flights)} "
                    f"queued={len(self.queue) + len(self._requeue)} "
                    f"pending={len(pending)} done={len(self.done)} "
                    f"shed={len(self.shed)}")
        self.stats["ticks"] = self.tick_no
        if self.cfg.engine.paged:
            # shed_blocks is counted at _collect (an engine reset on
            # failover wipes the engine-side counter, the router's is
            # durable); pool peaks survive resets within one run only on
            # live replicas, so take the max over all of them here.
            self.stats["min_free_blocks"] = self._min_free_blocks
            self.stats["peak_blocks_used"] = max(
                r.engine.pool.peak_used for r in self.replicas)
        return sorted(self.done + self.shed, key=lambda r: r.rid)

    # ---------------------------------------------------------------- drain
    def drain(self, *, realtime: bool = False,
              log: Optional[Callable[[str], None]] = None
              ) -> List[ServeRequest]:
        """Graceful shutdown: complete the in-flight requests (failover
        still applies — a replica dying mid-drain re-dispatches its work)
        WITHOUT admitting from the queue; undispatched requests are left
        in :attr:`queue` for the caller to reroute or fail explicitly."""
        t0 = time.monotonic()
        vnow = 0.0
        before = len(self.done)
        while self.flights or self._requeue:
            now = (time.monotonic() - t0) if realtime else vnow
            self.tick_no += 1
            self._check_faults(now)
            self._dispatch(now, draining=True)
            self._step_replicas(now)
            self._heartbeat(now)
            self._collect(now)
            if not realtime:
                vnow += 1.0
            if log:
                log(f"[router] drain t={now:7.3f}s "
                    f"flights={len(self.flights)} "
                    f"queued={len(self.queue)} (held)")
        return self.done[before:]
