"""Block-pool accounting for the paged KV cache (DESIGN.md §15).

Host-side allocator: the device holds the K/V pools and the int32 block
tables; this module owns *which* pool block belongs to *which* serving
slot.  All policies are deterministic — the free list is LIFO and every
operation is driven by the engine's virtual clock — so paged runs are
exactly reproducible.

Invariants:
  * a block belongs to at most one slot at any time;
  * ``table_array()`` rows list a slot's blocks in logical order, padded
    with the sentinel ``n_blocks`` (dropped by ``mode="drop"`` scatters
    and clamped+masked by the kernels);
  * freeing is all-or-nothing per slot (sequences never shrink).
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["BlockPool", "blocks_for"]


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache positions (>= 1)."""
    return -(-max(int(n_tokens), 1) // block_size)


class BlockPool:
    """Fixed-size block allocator with per-slot block tables.

    ``n_blocks`` blocks of ``block_size`` tokens each, shared by
    ``slots`` serving slots; a slot holds at most ``max_blocks_per_slot``
    (= cache_len / block_size) blocks.  ``alloc``/``ensure`` fail
    explicitly (return ``False``) on exhaustion — the engine turns that
    into head-of-line admission blocking or an OOM shed, never a silent
    drop.
    """

    def __init__(self, n_blocks: int, block_size: int, slots: int,
                 max_blocks_per_slot: int):
        if n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.slots = int(slots)
        self.max_blocks_per_slot = int(max_blocks_per_slot)
        self.reset()

    def reset(self) -> None:
        # LIFO free list; pop() hands out block 0 first and reuses the
        # most recently freed blocks — deterministic and cache-friendly.
        self._free = list(range(self.n_blocks - 1, -1, -1))
        self._held: List[List[int]] = [[] for _ in range(self.slots)]
        self.peak_used = 0
        self.allocs = 0
        self.frees = 0

    # ------------------------------------------------------------ queries
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.n_blocks - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for(n_tokens, self.block_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def held(self, slot: int) -> int:
        """Number of blocks currently held by ``slot``."""
        return len(self._held[slot])

    # -------------------------------------------------------- alloc / free
    def alloc(self, slot: int, n: int) -> bool:
        """Grant ``n`` more blocks to ``slot``; all-or-nothing."""
        if n > len(self._free):
            return False
        if len(self._held[slot]) + n > self.max_blocks_per_slot:
            return False
        for _ in range(n):
            self._held[slot].append(self._free.pop())
        self.allocs += n
        self.peak_used = max(self.peak_used, self.used)
        return True

    def ensure(self, slot: int, pos: int) -> bool:
        """Grow ``slot``'s table until it covers token position ``pos``."""
        need = pos // self.block_size + 1 - len(self._held[slot])
        if need <= 0:
            return True
        return self.alloc(slot, need)

    def free_slot(self, slot: int) -> int:
        """Return all of ``slot``'s blocks to the pool; returns the count."""
        blks = self._held[slot]
        n = len(blks)
        self.frees += n
        self._free.extend(reversed(blks))
        self._held[slot] = []
        return n

    # ------------------------------------------------------------- tables
    def table_array(self) -> np.ndarray:
        """(slots, max_blocks_per_slot) int32; sentinel = n_blocks."""
        t = np.full((self.slots, self.max_blocks_per_slot), self.n_blocks,
                    np.int32)
        for s, blks in enumerate(self._held):
            if blks:
                t[s, :len(blks)] = blks
        return t

    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._held[slot])
