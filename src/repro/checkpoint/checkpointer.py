"""Sharded, atomic, async checkpointing with elastic restore.

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf (named by
its tree path) + ``index.json`` (treedef, shapes, dtypes, step).  Writes go
to ``step_<N>.tmp`` and are renamed only when complete — a crash mid-save can
never corrupt the latest checkpoint.  ``keep`` bounds disk usage.

Elastic restore: leaves are stored as plain host arrays with *logical* names,
not device layouts, so a checkpoint written on one mesh restores onto any
other (the caller re-applies shardings via ``device_put``).  On a real
multi-host pod each host would write its leaf shards; the format and the
atomic-rename protocol are unchanged.

Async: ``save_async`` snapshots to host memory synchronously (cheap) and
writes on a daemon thread; ``wait()`` joins before the next save or exit.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize ml_dtypes (bfloat16, fp8) natively: store the raw
# bits with the logical dtype recorded in the index.
_BITCAST_SAVE = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                 "float8_e5m2": np.uint8}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts) or "root"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, state: Any) -> str:
        self.wait()
        return self._write(step, self._snapshot(state))

    def save_async(self, step: int, state: Any) -> None:
        self.wait()
        snap = self._snapshot(state)
        self._thread = threading.Thread(
            target=self._write, args=(step, snap), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, state: Any) -> Tuple[list, Any]:
        leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
        host = [(_path_str(p), np.asarray(x)) for p, x in leaves]
        return host, treedef

    def _write(self, step: int, snap) -> str:
        host, _ = snap
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {"step": step, "leaves": []}
        for name, arr in host:
            fname = re.sub(r"[^A-Za-z0-9_.-]", "_", name) + ".npy"
            logical_dtype = str(arr.dtype)
            if logical_dtype in _BITCAST_SAVE:
                arr = arr.view(_BITCAST_SAVE[logical_dtype])
            np.save(os.path.join(tmp, fname), arr)
            index["leaves"].append({"name": name, "file": fname,
                                    "shape": list(arr.shape),
                                    "dtype": logical_dtype})
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "index.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``like``.  ``shardings`` (optional,
        same structure) re-shards each leaf for the *current* mesh — this is
        the elastic-rescale path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        folder = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(folder, "index.json")) as f:
            index = json.load(f)
        by_name = {l["name"]: l for l in index["leaves"]}
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = None
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda s: s is None
                or isinstance(s, jax.sharding.Sharding))[0]
        out = []
        for i, (p, ref) in enumerate(leaves):
            name = _path_str(p)
            if name not in by_name:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = np.load(os.path.join(folder, by_name[name]["file"]))
            logical = by_name[name]["dtype"]
            if logical in _BITCAST_SAVE:
                arr = arr.view(getattr(ml_dtypes, logical))
            if list(arr.shape) != list(ref.shape):
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {ref.shape}")
            arr = arr.astype(ref.dtype)
            if shard_leaves is not None and shard_leaves[i] is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, out)
