"""Production training driver.

On a real TPU pod this builds the production mesh, installs sharding rules,
and runs the fault-tolerant loop with sharded inputs.  On the CPU box it
falls back to a single-device mesh with a reduced config (``--reduced``),
exercising the identical code path end to end.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.data.lm import LMDataConfig, data_iterator
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models.registry import build_model
from repro.training.loop import LoopConfig, train_loop


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_model(cfg)
    n_dev = len(jax.devices())

    if n_dev >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = rules_for(args.arch, multi_pod=args.multi_pod,
                          global_batch=args.batch)
    else:
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
        rules = rules_for(args.arch, multi_pod=False,
                          global_batch=args.batch)

    data_cfg = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"devices={n_dev} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    with axis_rules(rules, mesh):
        out = train_loop(bundle,
                         lambda s: data_iterator(data_cfg, s), loop_cfg)
    print(f"done: losses {out['losses'][:2]} -> {out['losses'][-2:]} "
          f"restarts={out['restarts']}")


if __name__ == "__main__":
    main()
