import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as its own process (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above runs before any jax import so the CPU platform
exposes 512 placeholder devices for the production meshes:

* single-pod: (16, 16) = 256 chips, axes (data, model)
* multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)

For each cell the appropriate step function (train_step / prefill_step /
decode_step) is jitted with explicit in_shardings, lowered with
ShapeDtypeStruct inputs (no allocation), compiled, and the compiled
artifact's memory_analysis / cost_analysis / collective schedule are
recorded for EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import (
    axis_rules,
    shardings_like,
    spec_for,
)
from repro.launch.mesh import make_production_mesh, rules_for
from repro.launch.roofline import CellReport, terms_from_hlo
from repro.models.registry import build_model
from repro.optim.adamw import AdafactorState, AdamWState
from repro.training.step import TrainState, make_optimizer, make_prefill_step, make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# sharding templates
# ---------------------------------------------------------------------------


def _opt_state_shardings(opt_shapes, params_shapes, param_shardings, mesh):
    """Derive optimizer-state shardings from the parameter shardings."""
    repl = NamedSharding(mesh, P())

    if isinstance(opt_shapes, AdamWState):
        return AdamWState(step=repl, m=param_shardings, v=param_shardings)
    if isinstance(opt_shapes, AdafactorState):
        def vr_sh(p_sds, p_sh):
            if len(p_sds.shape) >= 2:
                return NamedSharding(mesh, P(*p_sh.spec[:-1]))
            return p_sh

        def vc_sh(p_sds, p_sh):
            if len(p_sds.shape) >= 2:
                return NamedSharding(
                    mesh, P(*(tuple(p_sh.spec[:-2]) + (p_sh.spec[-1],))))
            return repl

        vr = jax.tree_util.tree_map(vr_sh, params_shapes, param_shardings)
        vc = jax.tree_util.tree_map(vc_sh, params_shapes, param_shardings)
        return AdafactorState(step=repl, vr=vr, vc=vc)
    raise TypeError(type(opt_shapes))


def _batch_shardings(batch_specs, batch_axes, rules, mesh):
    treedef = jax.tree_util.tree_structure(batch_specs)
    axes_leaves = treedef.flatten_up_to(batch_axes)
    return jax.tree_util.tree_unflatten(
        treedef,
        [NamedSharding(mesh, spec_for(a or (), rules, mesh))
         for a in axes_leaves])


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, multi_pod: bool,
             rule_overrides: Optional[Dict[str, Any]] = None,
             verbose: bool = True,
             cfg_overrides: Optional[Dict[str, Any]] = None) -> CellReport:
    import dataclasses as _dc
    cell = SHAPES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    bundle = build_model(cfg)
    report = CellReport(arch=arch, shape=shape, mesh=mesh_name,
                        kind=cell.kind, ok=False)

    supported, why = bundle.supports(cell)
    if not supported:
        report.note = f"SKIPPED: {why}"
        report.ok = True
        return report

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = rules_for(arch, multi_pod=multi_pod,
                      global_batch=cell.global_batch,
                      overrides=rule_overrides)

    t0 = time.monotonic()
    with axis_rules(rules, mesh):
        params_shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        param_sh = shardings_like(params_shapes, bundle.specs(), rules, mesh)
        batch_specs, batch_axes = bundle.input_specs(cell)
        batch_sh = _batch_shardings(batch_specs, batch_axes, rules, mesh)

        if cell.kind == "train":
            opt = make_optimizer(cfg)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            opt_sh = _opt_state_shardings(opt_shapes, params_shapes,
                                          param_sh, mesh)
            repl = NamedSharding(mesh, P())
            state_tmpl = TrainState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                params=params_shapes, opt_state=opt_shapes)
            state_sh = TrainState(step=repl, params=param_sh,
                                  opt_state=opt_sh)
            train_step, _ = make_train_step(bundle, optimizer=opt)
            fn = jax.jit(train_step,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
            lowered = fn.lower(state_tmpl, batch_specs)
        elif cell.kind == "prefill":
            prefill_step = make_prefill_step(bundle, cache_len=cell.seq_len)
            fn = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh))
            lowered = fn.lower(params_shapes, batch_specs)
        else:  # decode
            cache_shapes = bundle.cache_shapes(cell)
            cache_sh = shardings_like(cache_shapes, bundle.cache_specs(),
                                      rules, mesh)
            fn = jax.jit(bundle.decode_step,
                         in_shardings=(param_sh, cache_sh, batch_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_shapes, cache_shapes, batch_specs)

        compiled = lowered.compile()
    report.compile_s = time.monotonic() - t0

    # ---- memory ---------------------------------------------------------
    ma = compiled.memory_analysis()
    if ma is not None:
        report.arg_bytes = float(getattr(ma, "argument_size_in_bytes", 0))
        report.out_bytes = float(getattr(ma, "output_size_in_bytes", 0))
        report.temp_bytes = float(getattr(ma, "temp_size_in_bytes", 0))
        report.peak_bytes = (report.arg_bytes + report.temp_bytes
                             + float(getattr(ma, "generated_code_size_in_bytes", 0)))

    # ---- roofline --------------------------------------------------------
    hlo = compiled.as_text()
    terms, analysis = terms_from_hlo(hlo, chips)
    report.flops_dev = terms.flops / chips
    report.bytes_dev = terms.bytes_hbm / chips
    report.bytes_dev_min = analysis.bytes_hbm_min
    report.coll_dev = terms.bytes_collective / chips
    report.coll_breakdown = {k: v for k, v in
                             analysis.coll_breakdown.items() if v}
    report.compute_s = terms.compute_s
    report.memory_s = terms.memory_s
    report.collective_s = terms.collective_s
    report.dominant = terms.dominant
    report.top_buffers = [f"{b/2**20:.0f}MiB {desc}"
                          for b, desc in analysis.top_buffers]
    report.note = " | ".join(
        [f"TOPDOT {f/1e12:.2f}TF {d[:80]}" for f, d in analysis.top_dots[:4]]
        + [f"TOPCOLL {b/2**20:.0f}MiB {d[:80]}"
           for b, d in analysis.top_colls[:4]])

    # ---- MODEL_FLOPS (useful work) ---------------------------------------
    n_embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_body = max(cfg.active_param_count() - n_embed, 1)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        if cfg.family == "encdec":
            tokens = cell.global_batch * (cell.seq_len
                                          + cell.seq_len // cfg.dec_ratio)
        report.model_flops = 6.0 * n_body * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        report.model_flops = 2.0 * n_body * tokens
    else:
        report.model_flops = 2.0 * n_body * cell.global_batch
    total_hlo_flops = max(report.flops_dev * chips, 1.0)
    report.useful_fraction = report.model_flops / total_hlo_flops
    report.ok = True

    if verbose:
        print(f"[dryrun] {arch} x {shape} x {mesh_name}: ok "
              f"compile={report.compile_s:.1f}s "
              f"peak/dev={report.peak_bytes/2**30:.2f}GiB "
              f"dominant={report.dominant}", flush=True)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ALL_ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default=None, help="append JSONL report here")
    ap.add_argument("--rules", default=None,
                    help="JSON dict of logical->physical rule overrides")
    ap.add_argument("--config", default=None,
                    help="JSON dict of ModelConfig field overrides "
                         "(e.g. '{\"microbatches\": 4}')")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ALL_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    overrides = json.loads(args.rules) if args.rules else None
    cfg_overrides = json.loads(args.config) if args.config else None

    ok = True
    for arch in archs:
        for shape in shapes:
            try:
                rep = run_cell(arch, shape, args.mesh == "multi", overrides,
                               cfg_overrides=cfg_overrides)
            except Exception:  # noqa: BLE001
                rep = CellReport(arch=arch, shape=shape,
                                 mesh="2x16x16" if args.mesh == "multi"
                                 else "16x16",
                                 kind=SHAPES[shape].kind, ok=False,
                                 error=traceback.format_exc()[-2000:])
                print(f"[dryrun] {arch} x {shape} FAILED:\n{rep.error}",
                      flush=True)
                ok = False
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rep.to_dict()) + "\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
