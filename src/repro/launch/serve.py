"""Serving driver: batched prefill + decode with continuous request slots.

A minimal production-shaped server loop: requests queue up, get packed into
fixed prefill batches, and finished sequences release their slot for the
next request (slot-based continuous batching).  On TPU the same functions
are jitted with the production mesh sharding (launch/dryrun.py proves the
decode-step sharding compiles at 256/512 chips).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 12 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.models.registry import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based continuous batching over (prefill, decode_step)."""

    def __init__(self, bundle, params, *, slots: int = 4,
                 cache_len: int = 256, seed: int = 0):
        self.bundle = bundle
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = bundle.make_cache(slots, cache_len)
        self._decode = jax.jit(bundle.decode_step)

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill one request and splice its caches into the batch cache.

        Production note: real servers prefill in their own batch and merge;
        here we prefill slot-by-slot (batch 1) for clarity, then write the
        slot's cache rows in place."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self.bundle.prefill(
            self.params, {"tokens": toks, "cache_len": self.cache_len})

        def splice(big, one):
            if one.ndim == 0:
                return big
            # batch axis position differs per cache layout; match by size
            for ax in range(one.ndim):
                if one.shape[ax] == 1 and big.shape[ax] == self.slots:
                    idx = [slice(None)] * one.ndim
                    idx[ax] = slice(slot, slot + 1)
                    return big.at[tuple(idx)].set(one)
            return big

        self.cache = jax.tree_util.tree_map(splice, self.cache, cache1)
        # NOTE: cache["len"] is shared across slots in this minimal server —
        # requests are packed per round, so all active slots share a length.
        self.cache["len"] = cache1["len"]
        req.out.append(int(jnp.argmax(logits[0])))

    def run(self, requests: List[Request], log=print) -> List[Request]:
        pending = list(requests)
        finished: List[Request] = []
        round_no = 0
        while pending or any(self.active):
            # fill free slots with a fresh wave of equal-length prompts
            wave = []
            for s in range(self.slots):
                if self.active[s] is None and pending:
                    req = pending.pop(0)
                    self.active[s] = req
                    wave.append((s, req))
            for s, req in wave:
                self._prefill_slot(s, req)
            # decode until every active request finished its budget
            while any(r is not None and not r.done for r in self.active):
                toks = np.zeros((self.slots, 1), np.int32)
                for s, r in enumerate(self.active):
                    if r is not None and r.out:
                        toks[s, 0] = r.out[-1]
                logits, self.cache = self._decode(
                    self.params, self.cache, {"tokens": jnp.asarray(toks)})
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
                for s, r in enumerate(self.active):
                    if r is None or r.done:
                        continue
                    r.out.append(int(nxt[s]))
                    if len(r.out) >= r.max_new:
                        r.done = True
                if int(self.cache["len"]) >= self.cache_len:
                    break
            for s, r in enumerate(self.active):
                if r is not None and r.done:
                    finished.append(r)
                    self.active[s] = None
            round_no += 1
            log(f"[serve] round {round_no}: finished={len(finished)} "
                f"pending={len(pending)}")
            # reset shared cache between waves (slot lengths are shared)
            if any(self.active):
                continue
            self.cache = self.bundle.make_cache(self.slots, self.cache_len)
        return finished


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12).astype(
                        np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    server = BatchedServer(bundle, params, slots=args.slots, cache_len=64)
    t0 = time.time()
    done = server.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
