"""Serving driver: wave-batched baseline + the continuous-batching engine.

:class:`BatchedServer` is the historical wave-barrier loop kept as the
serving baseline (and the benchmark's reference point): requests are packed
into waves, every slot decodes until the whole wave finishes, then the next
wave is admitted.  It now runs on the slot-cache path — each slot owns its
own sequence length — which fixes the old shared-``cache["len"]`` bug
(mixed prompt lengths in one wave conflated slot positions, so decode read
stale cache rows; tests/test_serve.py keeps the regression covered).

The production path is :class:`repro.serve.ServeEngine` (continuous
admission, bucketed prefill, no wave barrier — DESIGN.md §12):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 12 --max-new 16 --engine

and the resilient deployment is the engine behind
:class:`repro.serve.ReplicaRouter` (replicated dispatch with health
checks, failover, load shedding and hedging — DESIGN.md §14):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 12 --max-new 16 --router --replicas 2

Both the engine and the router take ``--paged`` (with ``--block-size``/
``--blocks``) to admit on free KV-cache pool blocks instead of
worst-case dense slots (DESIGN.md §15) — the capacity win on long-tail
prompt mixes.
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced_config
from repro.models.registry import build_model
from repro.serve.engine import EngineConfig, ServeEngine, ServeRequest

# re-export: Request predates ServeRequest and external callers import it
# from here
Request = ServeRequest


class BatchedServer:
    """Wave-barrier batching over the slot-cache (prefill, decode) path.

    Admission happens only between waves (the historical behaviour, kept
    as the baseline the continuous engine is benchmarked against), but
    slot state is correct: per-slot lengths, per-slot masking — a wave may
    mix prompt lengths freely."""

    def __init__(self, bundle, params, *, slots: int = 4,
                 cache_len: int = 256, seed: int = 0):
        if bundle.decode_slotted is None:
            raise ValueError(f"family {bundle.cfg.family!r} has no slotted "
                             f"serving path")
        self.bundle = bundle
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.active: List[Optional[ServeRequest]] = [None] * slots
        self.cache = bundle.make_slot_cache(slots, cache_len)
        self._decode = jax.jit(lambda p, c, t, a: bundle.decode_slotted(
            p, c, {"tokens": t, "active": a}))
        self._prefill = jax.jit(lambda p, t, l: bundle.prefill_slotted(
            p, {"tokens": t, "lens": l, "cache_len": cache_len}))
        self._specs = {k: v for k, v in bundle.cache_specs().items()
                       if k != "len"}

    def _prefill_slot(self, slot: int, req: ServeRequest):
        """Prefill one request (batch 1 — the baseline keeps the historical
        slot-by-slot admission) and splice its cache rows into the slot."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        lens = jnp.asarray([len(req.prompt)], jnp.int32)
        logits, cache1 = self._prefill(self.params, toks, lens)
        idx = jnp.asarray([slot])
        cache = dict(self.cache)
        for key, spec in self._specs.items():
            ax = spec.index("batch")
            sl = (slice(None),) * ax + (idx,)
            cache[key] = cache[key].at[sl].set(cache1[key])
        cache["lens"] = cache["lens"].at[idx].set(cache1["lens"])
        self.cache = cache
        req.out.append(int(jnp.argmax(logits[0])))

    def run(self, requests: List[ServeRequest], log=print
            ) -> List[ServeRequest]:
        pending = list(requests)
        finished: List[ServeRequest] = []
        round_no = 0
        last_tok = np.zeros((self.slots,), np.int32)
        while pending or any(self.active):
            # fill free slots with a fresh wave (barrier: only between waves)
            wave = []
            for s in range(self.slots):
                if self.active[s] is None and pending:
                    req = pending.pop(0)
                    self.active[s] = req
                    wave.append((s, req))
            for s, req in wave:
                self._prefill_slot(s, req)
                last_tok[s] = req.out[-1]
            # decode until every active request finished its budget
            while any(r is not None and not r.done for r in self.active):
                act = np.array([r is not None and not r.done
                                for r in self.active])
                logits, self.cache = self._decode(
                    self.params, self.cache,
                    jnp.asarray(last_tok[:, None]), jnp.asarray(act))
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
                lens = np.asarray(self.cache["lens"])
                for s, r in enumerate(self.active):
                    if r is None or r.done:
                        continue
                    r.out.append(int(nxt[s]))
                    last_tok[s] = nxt[s]
                    if len(r.out) >= r.max_new or \
                            int(lens[s]) >= self.cache_len:
                        r.done = True
            for s, r in enumerate(self.active):
                if r is not None and r.done:
                    finished.append(r)
                    self.active[s] = None
            round_no += 1
            log(f"[serve] round {round_no}: finished={len(finished)} "
                f"pending={len(pending)}")
        return finished


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ALL_ARCHS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--engine", action="store_true",
                    help="use the continuous-batching ServeEngine instead "
                         "of the wave-barrier baseline")
    ap.add_argument("--router", action="store_true",
                    help="front ServeEngine replicas with the ReplicaRouter "
                         "(health checks, failover, shedding, hedging)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="replica count for --router (device-affine across "
                         "jax.devices() when more than one is present)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: admit on free pool blocks instead "
                         "of worst-case dense slots (DESIGN.md §15); applies "
                         "to --engine and --router")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV-cache block for --paged")
    ap.add_argument("--blocks", type=int, default=None,
                    help="pool size in blocks for --paged (default: worst "
                         "case, slots * cache_len / block_size)")
    args = ap.parse_args(argv)
    if args.paged and not (args.engine or args.router):
        ap.error("--paged needs --engine or --router (the wave-barrier "
                 "baseline is dense-only)")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(rid=i,
                         prompt=rng.integers(0, cfg.vocab_size, 12).astype(
                             np.int32),
                         max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    ecfg = EngineConfig(slots=args.slots, cache_len=64,
                        pad_to=8 if bundle.prefill_pads else 1,
                        paged=args.paged, block_size=args.block_size,
                        n_blocks=args.blocks)
    if args.router:
        from repro.serve.router import ReplicaRouter, RouterConfig
        devices = jax.devices()
        router = ReplicaRouter(bundle, params, RouterConfig(
            replicas=args.replicas, engine=ecfg),
            devices=devices if len(devices) > 1 else None)
        done = router.run(reqs)
        print(f"router stats: {router.stats}")
    elif args.engine:
        engine = ServeEngine(bundle, params, ecfg)
        done = engine.run(reqs)
        print(f"engine stats: {engine.stats()}")
    else:
        server = BatchedServer(bundle, params, slots=args.slots,
                               cache_len=64)
        done = server.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
