"""Roofline-term extraction from compiled SPMD artifacts.

``compiled.cost_analysis()`` does NOT scale ``while``-loop bodies by their
trip counts (a ``lax.scan`` over 88 layers is costed as one layer), so this
module re-derives FLOPs / HBM bytes / collective bytes by walking the
partitioned HLO text:

* instruction result shapes are recorded per computation, and operand shapes
  are resolved by name (optimized HLO does not annotate operand types);
* computations reached through a ``while`` whose backend_config carries
  ``known_trip_count`` are multiplied by that count (nested loops compose
  through the call graph);
* FLOPs: ``dot`` ops — 2 * prod(result) * prod(lhs contracting dims) —
  counted wherever they appear, including inside fusions;
* HBM bytes: result + operand bytes of ops at fusion boundaries (fusion
  internals are register/VMEM-resident).  Computations reached only via
  ``calls=``/``to_apply=`` (fusion bodies, reduction lambdas) are skipped
  for bytes; ``while``/``conditional`` bodies are real top-level code and
  are counted;
* collective bytes: operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (+ ``-start`` forms),
  trip-scaled.

All quantities are PER DEVICE (the module is the post-SPMD per-device
program).  Hardware constants (v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI — shared with HALF's NAS objectives (repro.core.hw_model).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

from repro.core.cost_backend import TPU_ROOFLINE
from repro.core.hw_model import RooflineTerms

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True))
    + r")\[([0-9,]*)\]")
_TRIP_RE = re.compile(
    r'known_trip_count"?\s*[:=]\s*\{\s*"?n"?\s*[:=]\s*"?(\d+)')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_DOT_LHS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "iota", "partition-id",
    "replica-id",
}


def _shapes_in(s: str) -> List[Tuple[str, List[int]]]:
    return [(d, [int(x) for x in dims.split(",") if x])
            for d, dims in _SHAPE_RE.findall(s)]


def _nbytes_many(shapes: List[Tuple[str, List[int]]]) -> int:
    return sum(_DTYPE_BYTES[d] * math.prod(dims) for d, dims in shapes)


@dataclasses.dataclass
class HloAnalysis:
    flops: float = 0.0
    bytes_hbm: float = 0.0       # fusion-boundary upper bound (CPU fusions)
    bytes_hbm_min: float = 0.0   # ideal-fusion lower bound: dot/gather/
                                 # scatter/slice/collective traffic only
    bytes_collective: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)
    top_buffers: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    top_dots: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    top_colls: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    unresolved_dots: int = 0


def _parse_computations(text: str):
    """-> (dict name -> instruction lines, entry computation name).

    Parameter shapes need no header parsing: optimized HLO re-lists every
    parameter as a ``%p = TYPE parameter(N)`` instruction, so the defs table
    resolves them like any other operand.
    """
    comps: Dict[str, List[str]] = {}
    entry = None
    name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if not line.startswith(" ") and "->" in line and "{" in line:
            m = _COMP_HDR_RE.match(stripped)
            if m:
                name = m.group(1)
                comps[name] = []
                if stripped.startswith("ENTRY"):
                    entry = name
                continue
        if name is not None and stripped not in ("}", "{"):
            comps[name].append(stripped)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def analyze_hlo(text: str, top_k_buffers: int = 8) -> HloAnalysis:
    comps, entry = _parse_computations(text)

    # ---- per-computation defs: instr name -> shapes (list for tuples) ----
    defs: Dict[str, Dict[str, List[Tuple[str, List[int]]]]] = {}
    # param shapes come from the computation header line's param list — but
    # headers were not retained; recover parameter shapes from the
    # "%name = TYPE parameter(N)" instructions that optimized HLO includes.
    for cname, lines in comps.items():
        d: Dict[str, List[Tuple[str, List[int]]]] = {}
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            iname, rhs = m.groups()
            opm = _OP_RE.search(" " + rhs)
            op_at = opm.start(1) - 1 if opm else len(rhs)
            d[iname] = _shapes_in(rhs[:op_at])
        defs[cname] = d

    # ---- call graph -------------------------------------------------------
    # edge kinds: loop bodies (trip-scaled, top-level) vs fused/applied
    trip_edges: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    fused_edges: Dict[str, List[str]] = {c: [] for c in comps}
    for cname, lines in comps.items():
        for ln in lines:
            trip = 1
            tm = _TRIP_RE.search(ln)
            if tm:
                trip = int(tm.group(1))
            for attr, callee in re.findall(
                    r"(body|condition|true_computation|false_computation|"
                    r"branch_computations|calls|to_apply)=\(?%?([\w.\-]+)",
                    ln):
                if callee not in comps:
                    continue
                if attr in ("body", "condition"):
                    trip_edges[cname].append((callee, trip))
                elif attr in ("true_computation", "false_computation",
                              "branch_computations"):
                    trip_edges[cname].append((callee, 1))
                else:
                    fused_edges[cname].append(callee)

    mult: Dict[str, int] = {c: 0 for c in comps}
    internal: Dict[str, bool] = {c: True for c in comps}
    if entry:
        mult[entry] = 1
        internal[entry] = False
        frontier = [entry]
        visited = set(frontier)
        while frontier:
            cur = frontier.pop()
            for callee, trip in trip_edges[cur]:
                mult[callee] = max(mult[callee], mult[cur] * trip)
                internal[callee] = internal[callee] and internal[cur]
                if internal[cur] is False:
                    internal[callee] = False
                if callee not in visited:
                    visited.add(callee)
                    frontier.append(callee)
                else:
                    frontier.append(callee)  # allow multiplier refinement
                    visited.add(callee)
                if len(visited) > 10 * len(comps):
                    break
            for callee in fused_edges[cur]:
                mult[callee] = max(mult[callee], mult[cur])
                # fused: internal regardless of caller
                if callee not in visited:
                    visited.add(callee)
                    frontier.append(callee)

    # simple fixpoint for multipliers (call graphs are small)
    for _ in range(8):
        changed = False
        for cname in comps:
            for callee, trip in trip_edges[cname]:
                v = mult[cname] * trip
                if v > mult[callee]:
                    mult[callee] = v
                    changed = True
                if mult[cname] > 0 and not internal[cname] \
                        and internal[callee]:
                    internal[callee] = False
                    changed = True
            for callee in fused_edges[cname]:
                if mult[cname] > mult[callee]:
                    mult[callee] = mult[cname]
                    changed = True
        if not changed:
            break

    # ---- walk instructions -------------------------------------------------
    out = HloAnalysis(coll_breakdown={k: 0.0 for k in COLLECTIVES})
    buffers: List[Tuple[float, str]] = []
    dots: List[Tuple[float, str]] = []
    colls: List[Tuple[float, str]] = []
    for cname, lines in comps.items():
        m = mult.get(cname, 0)
        if m <= 0:
            continue
        is_internal = internal.get(cname, True)
        d = defs[cname]

        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            _, rhs = im.groups()
            opm = _OP_RE.search(" " + rhs)
            if not opm:
                continue
            op = opm.group(1)
            op_at = opm.start(1) - 1
            result_shapes = _shapes_in(rhs[:op_at])
            # operand list: from the '(' after op name to its match
            paren = rhs.find("(", op_at)
            depth, end = 0, len(rhs)
            for i in range(paren, len(rhs)):
                if rhs[i] == "(":
                    depth += 1
                elif rhs[i] == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_names = _OPERAND_RE.findall(rhs[paren:end])
            operand_shapes: List[Tuple[str, List[int]]] = []
            for on in operand_names:
                operand_shapes.extend(d.get(on, []))

            # ---- flops -----------------------------------------------
            if op == "dot":
                contract = 1
                dm = _DOT_LHS_RE.search(rhs)
                lhs = d.get(operand_names[0], []) if operand_names else []
                if dm and lhs:
                    lhs_dims = lhs[0][1]
                    for idx in [int(x) for x in dm.group(1).split(",") if x]:
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
                else:
                    out.unresolved_dots += 1
                f = 2.0 * math.prod(result_shapes[0][1]) * contract * m
                out.flops += f
                dots.append((f, f"x{m} {cname}: {ln[:110]}"))
            elif op == "convolution" and operand_shapes:
                kernel = operand_shapes[-1][1]
                out.flops += 2.0 * math.prod(result_shapes[0][1]) \
                    * math.prod(kernel[:-1] or [1]) * m

            # ---- collectives ----------------------------------------
            coll = None
            for c in COLLECTIVES:
                if op == c or op == c + "-start":
                    coll = c
                    break
            if coll:
                b = _nbytes_many(operand_shapes) or _nbytes_many(
                    result_shapes)
                out.coll_breakdown[coll] += b * m
                out.bytes_collective += b * m
                colls.append((b * m, f"x{m} {cname}: {ln[:110]}"))

            # ---- HBM bytes at fusion boundaries ----------------------
            if not is_internal and op not in _SKIP_BYTES_OPS \
                    and not op.endswith("-done"):
                rb = _nbytes_many(result_shapes)
                ob = _nbytes_many(operand_shapes)
                if op == "dynamic-update-slice" or (
                        op == "fusion" and "dynamic-update-slice" in ln):
                    # in-place slice update: traffic = read + write of the
                    # UPDATE, not the whole aliased buffer (XLA aliases the
                    # input buffer; counting the full result per loop
                    # iteration overstates a scan's residual stacking by
                    # the trip count).
                    per_op = [math.prod(dims) * _DTYPE_BYTES[d]
                              for d, dims in operand_shapes]
                    big = max(per_op) if per_op else 0
                    b = 2 * max(ob - big, rb // max(m, 1) if m else rb)
                elif op == "dynamic-slice" or (
                        op == "fusion" and "dynamic-slice" in ln):
                    b = 2 * rb   # read slice + write result
                else:
                    b = rb + ob
                out.bytes_hbm += b * m
                if b > 0:
                    buffers.append((b * m, f"x{m} {cname}: {ln[:100]}"))
                # lower bound: traffic an ideal fusion cannot avoid
                if (op in ("dot", "convolution", "gather", "scatter",
                           "dynamic-slice", "dynamic-update-slice", "sort",
                           "copy") or coll
                        or (op == "fusion" and any(
                            t in ln for t in ("dynamic-update-slice",
                                              "dynamic-slice", "gather",
                                              "scatter")))):
                    out.bytes_hbm_min += b * m

    buffers.sort(key=lambda t: -t[0])
    dots.sort(key=lambda t: -t[0])
    colls.sort(key=lambda t: -t[0])
    out.top_buffers = buffers[:top_k_buffers]
    out.top_dots = dots[:top_k_buffers]
    out.top_colls = colls[:top_k_buffers]
    return out


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    a = analyze_hlo(hlo_text)
    return a.bytes_collective, a.coll_breakdown


def terms_from_hlo(hlo_text: str, chips: int) -> Tuple[RooflineTerms,
                                                       HloAnalysis]:
    a = analyze_hlo(hlo_text)
    return TPU_ROOFLINE.roofline_terms(
        a.flops * chips, a.bytes_hbm * chips,
        a.bytes_collective * chips, chips), a


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    kind: str
    ok: bool
    error: str = ""
    compile_s: float = 0.0
    # memory (per device)
    arg_bytes: float = 0.0
    out_bytes: float = 0.0
    temp_bytes: float = 0.0
    peak_bytes: float = 0.0
    # roofline (per device per step)
    flops_dev: float = 0.0
    bytes_dev: float = 0.0
    bytes_dev_min: float = 0.0   # ideal-fusion lower bound
    coll_dev: float = 0.0
    coll_breakdown: Optional[Dict[str, float]] = None
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_fraction: float = 0.0   # MODEL_FLOPS / (flops_dev * chips)
    top_buffers: Optional[List[str]] = None
    note: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)
