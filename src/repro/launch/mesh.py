"""Production mesh construction + per-arch sharding-rule overrides.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run must set XLA_FLAGS before jax initializes.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax

from repro.distributed.sharding import Physical, default_rules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def local_search_devices(max_devices: Optional[int] = None) -> List:
    """The accelerators the search orchestrator may shard signature buckets
    across (DESIGN.md §11) — one scheduler worker group per entry.

    A FUNCTION for the same reason as :func:`make_production_mesh`: calling
    it initializes the jax backend, so it must only run after any
    ``XLA_FLAGS`` staging (``--xla_force_host_platform_device_count=N``
    simulates an N-device host for tests/benchmarks).
    """
    devs = list(jax.local_devices())
    return devs[:max_devices] if max_devices else devs


# Divisibility-driven deviations from the defaults (DESIGN.md §5):
# * whisper-tiny / mamba2-780m: vocab (51865 / 50280) is not divisible by the
#   16-way model axis.  Sharding the embedding's d_model axis instead trips
#   an XLA SPMD gather bug under the microbatch loop ("Slice dim size 1536
#   greater than dynamic slice dimension: 96"), so these small tables
#   (<= 160 MB bf16) are simply replicated.
ARCH_RULE_OVERRIDES: Dict[str, Dict[str, Physical]] = {
    "whisper-tiny": {"vocab": None, "embed_unsharded": None},
    "mamba2-780m": {"vocab": None, "embed_unsharded": None},
}


def rules_for(arch: str, *, multi_pod: bool, global_batch: int,
              overrides: Optional[Dict[str, Physical]] = None
              ) -> Dict[str, Physical]:
    rules = default_rules(multi_pod)
    rules.update(ARCH_RULE_OVERRIDES.get(arch, {}))
    if global_batch == 1:
        rules["batch"] = None   # degenerate long-context cells
    if overrides:
        rules.update(overrides)
    return rules
