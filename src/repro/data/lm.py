"""Synthetic LM token pipeline: deterministic, host-sharded, restartable.

A Markov-ish token stream with Zipf unigram statistics and local structure
(so small models have signal to fit).  Each host generates exactly its data
shard from (seed, step, host_index) — no cross-host IO, and restarting at
step N regenerates the identical batch (checkpoint/restart safe).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


def _batch_rng(cfg: LMDataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_index]))


def make_batch(cfg: LMDataConfig, step: int) -> Dict[str, np.ndarray]:
    """tokens/labels: (local_batch, seq_len) int32. labels = next token."""
    assert cfg.global_batch % cfg.host_count == 0
    local = cfg.global_batch // cfg.host_count
    rng = _batch_rng(cfg, step)
    v = cfg.vocab_size
    # Zipf-ish unigrams over a capped alphabet for fast sampling
    alpha = min(v, 4096)
    ranks = np.arange(1, alpha + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    base = rng.choice(alpha, size=(local, cfg.seq_len + 1), p=probs)
    # local structure: with p=0.3 copy the token from 2 positions back
    copy_mask = rng.random((local, cfg.seq_len + 1)) < 0.3
    base[:, 2:] = np.where(copy_mask[:, 2:], base[:, :-2], base[:, 2:])
    data = (base % v).astype(np.int32)
    return {"tokens": data[:, :-1], "labels": data[:, 1:]}


def data_iterator(cfg: LMDataConfig, start_step: int = 0
                  ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1
