"""Synthetic ECG arrhythmia dataset — stand-in for the private Charité data.

Paper §VI: "16000 samples, with 2 channels and a length of 60000 each",
balanced binary classification (atrial fibrillation vs. normal sinus rhythm).

The generator plants the clinically relevant morphology differences:

* normal sinus rhythm (label 0): regular R-R intervals (small jitter),
  P-wave before each QRS complex, stable baseline.
* atrial fibrillation (label 1): irregularly-irregular R-R intervals
  (high variance), absent P-waves, fibrillatory baseline oscillation
  (4-9 Hz wavelets).

Channel 2 is a scaled, phase-shifted projection of channel 1 with independent
noise (two-lead recording).  All shapes match the paper; the *clinical*
numbers do not transfer (see DESIGN.md §7 honesty ledger).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

FS = 250.0  # Hz sampling rate; 60000 samples = 4 minutes


def _gaussian(t: np.ndarray, mu: float, sigma: float) -> np.ndarray:
    return np.exp(-0.5 * ((t - mu) / sigma) ** 2)


def _one_record(rng: np.random.Generator, length: int, af: bool) -> np.ndarray:
    """Generate one 2-channel record of `length` samples."""
    t = np.arange(length, dtype=np.float32)
    sig = np.zeros(length, dtype=np.float32)

    # --- beat train -------------------------------------------------------
    hr = rng.uniform(55.0, 95.0)  # bpm
    mean_rr = 60.0 / hr * FS      # samples per beat
    pos = rng.uniform(0, mean_rr)
    beat_positions = []
    while pos < length - 40:
        beat_positions.append(pos)
        if af:
            # irregularly irregular: heavy-tailed RR jitter
            rr = mean_rr * rng.uniform(0.55, 1.6)
        else:
            rr = mean_rr * (1.0 + rng.normal(0.0, 0.03))
        pos += max(rr, 0.25 * mean_rr)

    qrs_w = rng.uniform(8.0, 14.0)     # QRS width (samples)
    r_amp = rng.uniform(0.8, 1.3)
    for bp in beat_positions:
        # QRS complex: R spike with small Q/S deflections
        sig += r_amp * _gaussian(t, bp, qrs_w * 0.35)
        sig -= 0.25 * r_amp * _gaussian(t, bp - qrs_w * 0.8, qrs_w * 0.4)
        sig -= 0.3 * r_amp * _gaussian(t, bp + qrs_w * 0.9, qrs_w * 0.45)
        # T wave
        sig += 0.3 * r_amp * _gaussian(t, bp + qrs_w * 4.0, qrs_w * 1.6)
        if not af:
            # P wave precedes QRS only in sinus rhythm
            sig += 0.18 * r_amp * _gaussian(t, bp - qrs_w * 3.0, qrs_w * 1.1)

    # --- baseline ----------------------------------------------------------
    if af:
        # fibrillatory waves: 4-9 Hz narrowband oscillation, drifting phase
        f_fib = rng.uniform(4.0, 9.0) / FS
        phase = np.cumsum(rng.normal(0, 0.05, length)).astype(np.float32)
        sig += 0.12 * np.sin(2 * np.pi * f_fib * t + phase).astype(np.float32)
    # respiration drift + mains-like hum (both classes)
    sig += 0.05 * np.sin(2 * np.pi * 0.25 / FS * t + rng.uniform(0, 6.28))
    sig += rng.normal(0.0, 0.03, length).astype(np.float32)

    ch2 = (rng.uniform(0.5, 0.9) * np.roll(sig, int(rng.uniform(1, 5)))
           + rng.normal(0.0, 0.03, length)).astype(np.float32)
    return np.stack([sig.astype(np.float32), ch2], axis=-1)  # (L, 2)


def make_ecg_dataset(
    seed: int,
    n_samples: int = 16000,
    length: int = 60000,
    decimation: int = 32,
) -> Tuple[np.ndarray, np.ndarray]:
    """Balanced dataset. Returns (x: (N, length//decimation, 2), y: (N,)).

    ``decimation`` reproduces the paper's input downsampling (Fig. 4 shows
    NAS inputs of (1875, 2) = 60000/32 and (3750, 2) = 60000/16).
    Records are generated directly at the decimated length with an
    equivalently scaled sampling rate, which is numerically identical to
    decimating a full-rate record with an ideal low-pass.
    """
    rng = np.random.default_rng(seed)
    dec_len = length // decimation
    x = np.empty((n_samples, dec_len, 2), dtype=np.float32)
    y = np.empty((n_samples,), dtype=np.int32)
    # generate at the decimated rate: scale time constants by 1/decimation
    global FS
    fs_orig = FS
    FS = fs_orig / decimation
    try:
        for i in range(n_samples):
            af = i % 2 == 1  # balanced, deterministic interleave
            x[i] = _one_record(rng, dec_len, af)
            y[i] = int(af)
    finally:
        FS = fs_orig
    # per-record standardization (the usual ECG preprocessing)
    mu = x.mean(axis=1, keepdims=True)
    sd = x.std(axis=1, keepdims=True) + 1e-6
    return (x - mu) / sd, y


def train_val_split(x: np.ndarray, y: np.ndarray, val_frac: float = 0.2,
                    seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n_val = int(len(x) * val_frac)
    va, tr = idx[:n_val], idx[n_val:]
    return (x[tr], y[tr]), (x[va], y[va])
