"""Data pipelines: synthetic ECG (Charité stand-in) and LM token streams."""
from repro.data.ecg import make_ecg_dataset  # noqa: F401
