"""Fault-tolerant training loop: checkpoint/restart, failure containment.

The loop owns the full restart contract (DESIGN.md §5):

* checkpoint every ``ckpt_every`` steps (async, atomic);
* any exception inside a step (device loss, preemption, injected fault)
  rolls back to the latest complete checkpoint and replays — the data
  pipeline is (seed, step)-deterministic so replayed batches are identical;
* ``max_restarts`` bounds the retry budget;
* elastic: on restart the checkpoint re-shards onto whatever mesh is ambient
  (leaves are stored mesh-agnostically).

``fail_injector(step)`` exists for tests: raising from it simulates a node
failure at an exact step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.models.registry import ModelBundle
from repro.training.step import TrainState, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    log_every: int = 10


def init_state(bundle: ModelBundle, opt, rng: jax.Array) -> TrainState:
    params = bundle.init(rng)
    return TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))


def train_loop(
    bundle: ModelBundle,
    data_factory: Callable[[int], Iterator[Dict[str, Any]]],
    loop_cfg: LoopConfig,
    *,
    rng: Optional[jax.Array] = None,
    train_step=None,
    opt=None,
    fail_injector: Optional[Callable[[int], None]] = None,
    log: Callable[[str], None] = print,
    jit: bool = True,
) -> Dict[str, Any]:
    """Run to ``total_steps`` with restart-on-failure.  Returns summary."""
    if train_step is None or opt is None:
        train_step, opt = make_train_step(bundle)
    step_fn = jax.jit(train_step, donate_argnums=(0,)) if jit else train_step
    ckpt = Checkpointer(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    restarts = 0
    losses: List[float] = []
    state = None
    while True:
        try:
            # ---- (re)start: restore latest or init fresh -----------------
            if state is None:
                template = jax.eval_shape(
                    lambda: init_state(bundle, opt, rng))
                if ckpt.latest_step() is not None:
                    start, state = ckpt.restore(template)
                    log(f"[loop] restored step {start}")
                else:
                    state = init_state(bundle, opt, rng)
                    start = 0
            else:
                start = int(state.step)

            data = data_factory(start)
            for step in range(start, loop_cfg.total_steps):
                if fail_injector is not None:
                    fail_injector(step)
                batch = {k: jnp.asarray(v) for k, v in next(data).items()}
                t0 = time.monotonic()
                state, metrics = step_fn(state, batch)
                if step % loop_cfg.log_every == 0:
                    loss = float(metrics["loss"])
                    losses.append(loss)
                    log(f"[loop] step {step:5d} loss={loss:.4f} "
                        f"({time.monotonic() - t0:.2f}s)")
                if (step + 1) % loop_cfg.ckpt_every == 0:
                    ckpt.save_async(step + 1, state)
            ckpt.save(loop_cfg.total_steps, state)
            return {"state": state, "losses": losses, "restarts": restarts}
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — failure containment is the point
            restarts += 1
            log(f"[loop] step failure ({type(e).__name__}: {e}); "
                f"restart {restarts}/{loop_cfg.max_restarts}")
            if restarts > loop_cfg.max_restarts:
                raise
            ckpt.wait()
            state = None  # force restore from latest checkpoint
