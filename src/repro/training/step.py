"""train_step / serve_step factories over a ModelBundle.

* next-token (or teacher-forced) cross-entropy with z-loss and the MoE
  load-balance auxiliary;
* microbatched gradient accumulation (``cfg.microbatches``) via ``lax.scan``
  — the activation live-set shrinks by the microbatch factor while the HLO
  stays one fused loop;
* gradient clipping + optional gradient compression hook;
* AdamW or Adafactor per config (1T models cannot afford AdamW state).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.compression import CompressionConfig, compress_grads
from repro.models.registry import ModelBundle
from repro.optim import adafactor, adamw, apply_updates, clip_by_global_norm

Z_LOSS_COEF = 1e-4
MOE_AUX_COEF = 1e-2


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def make_optimizer(cfg: ModelConfig, lr=3e-4):
    if cfg.optimizer == "adafactor":
        return adafactor(lr)
    return adamw(lr, b1=0.9, b2=0.95, weight_decay=0.1)


def _label_key(cfg: ModelConfig) -> str:
    return "labels"


N_LOSS_CHUNKS = 8


def _xent_terms(logits: jnp.ndarray, labels: jnp.ndarray):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse, gold


def loss_fn(params, batch: Dict[str, Any], bundle: ModelBundle
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    labels = batch[_label_key(bundle.cfg)]
    s = labels.shape[1]
    if (bundle.cfg.chunked_loss and bundle.apply_hidden is not None
            and bundle.unembed_chunk is not None
            and s % N_LOSS_CHUNKS == 0 and s >= 2 * N_LOSS_CHUNKS):
        # §Perf iteration C2': fused chunked unembed + xent. The backbone
        # returns (B, S, D) hidden states; each sequence chunk is unembedded
        # and soft-maxed inside a jax.checkpoint, so only (B, S/8, V)
        # logits ever exist (saved residuals: lse/gold, (B, S) f32).
        x, aux = bundle.apply_hidden(params, batch)

        def chunk_terms(params_, xc, lc):
            return _xent_terms(bundle.unembed_chunk(params_, xc), lc)

        chunk = s // N_LOSS_CHUNKS
        terms = [jax.checkpoint(chunk_terms)(
            params, x[:, i * chunk:(i + 1) * chunk],
            labels[:, i * chunk:(i + 1) * chunk])
            for i in range(N_LOSS_CHUNKS)]
        lse = jnp.concatenate([t[0] for t in terms], axis=1)
        gold = jnp.concatenate([t[1] for t in terms], axis=1)
    else:
        logits, aux = bundle.apply_train(params, batch)
        lse, gold = _xent_terms(logits, labels)
    nll = (lse - gold).mean()
    z_loss = Z_LOSS_COEF * jnp.square(lse).mean()
    total = nll + z_loss + MOE_AUX_COEF * aux
    return total, {"loss": nll, "z_loss": z_loss, "moe_aux": aux}


def _split_microbatches(batch: Dict[str, Any], m: int) -> Dict[str, Any]:
    """Reshape each leaf's batch dim into (m, b/m). 'positions' is (3,B,S)."""
    def split(key, x):
        axis = 1 if key == "positions" else 0
        b = x.shape[axis]
        assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
        new_shape = x.shape[:axis] + (m, b // m) + x.shape[axis + 1:]
        x = x.reshape(new_shape)
        return jnp.moveaxis(x, axis, 0)

    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(
    bundle: ModelBundle,
    optimizer=None,
    *,
    compression: Optional[CompressionConfig] = None,
    clip_norm: float = 1.0,
) -> Callable[[TrainState, Dict[str, Any]], Tuple[TrainState, Dict]]:
    cfg = bundle.cfg
    opt = optimizer or make_optimizer(cfg)
    m = max(cfg.microbatches, 1)
    grad_fn = jax.value_and_grad(functools.partial(loss_fn, bundle=bundle),
                                 has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, Any]):
        if m == 1:
            (_, metrics), grads = grad_fn(state.params, batch)
        else:
            micro = _split_microbatches(batch, m)

            def acc_body(carry, mb):
                g_acc, met_acc = carry
                (_, met), g = grad_fn(state.params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                met_acc = jax.tree_util.tree_map(jnp.add, met_acc, met)
                return (g_acc, met_acc), None

            acc_dt = jnp.dtype(cfg.grad_acc_dtype)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params)
            met0 = {"loss": jnp.zeros(()), "z_loss": jnp.zeros(()),
                    "moe_aux": jnp.zeros(())}
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, met0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)
            metrics = jax.tree_util.tree_map(lambda v: v / m, metrics)

        if compression is not None:
            grads = compress_grads(grads, compression)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(state.step + 1, params, opt_state), metrics

    return train_step, opt


def make_eval_step(bundle: ModelBundle):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, bundle)
        return metrics
    return eval_step


def make_prefill_step(bundle: ModelBundle, cache_len: int):
    def prefill_step(params, batch):
        batch = dict(batch, cache_len=cache_len)
        return bundle.prefill(params, batch)
    return prefill_step


def make_decode_step(bundle: ModelBundle):
    def decode_step(params, cache, batch):
        return bundle.decode_step(params, cache, batch)
    return decode_step
