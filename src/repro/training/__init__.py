"""Training/serving step factories and the fault-tolerant outer loop."""
from repro.training.step import (  # noqa: F401
    TrainState,
    make_decode_step,
    make_eval_step,
    make_prefill_step,
    make_train_step,
)
