"""Dynamic workload scheduler (paper §III-A).

"We handle the large training workload by implementing a dynamic workload
scheduler, which leverages parallel processing on HPC systems."

On a real cluster a *worker* is a host owning a device group; here a worker
is a thread (jit'd candidate training releases the GIL inside XLA).  The
scheduler adds the failure semantics required at 1000-node scale
(DESIGN.md §5):

* **re-dispatch on failure** — a job whose worker raised (or timed out) is
  retried up to ``max_retries`` times;
* **straggler mitigation** — the slowest still-running jobs are
  speculatively duplicated (first result wins);
* **heartbeat** — jobs report liveness via a timestamp the scheduler
  inspects; silent workers past ``timeout_s`` are declared dead.

A *job* is any independent unit of work — the NAS dispatches whole
signature buckets (one bucket = one vmap-stacked training, DESIGN.md §9),
so retry and speculation operate on buckets, exactly as they previously
operated on single candidates.

Everything is event-driven: workers block on a condition variable (no
dequeue polling), and the straggler watcher sleeps until the earliest
moment a running job can exceed ``timeout_s`` — or until any state change
wakes it.  Speculation stays gated on "no unfinished job is waiting for a
worker", but that backlog test and the per-job queued/inflight/started-at
state are now read under the same lock the workers write them under — a
worker dequeuing concurrently can no longer fabricate the transient
non-empty-queue observations that the old ``qsize() > 0`` early-continue
used to skip (and thereby postpone) speculation on.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence


@dataclasses.dataclass
class JobResult:
    job_id: int
    ok: bool
    value: Any = None
    error: str = ""
    attempts: int = 1
    elapsed_s: float = 0.0
    worker: int = -1


class DynamicScheduler:
    """Run a batch of independent jobs with retries + speculative execution."""

    def __init__(self, n_workers: int = 4, max_retries: int = 2,
                 timeout_s: float = 3600.0, speculate: bool = True):
        self.n_workers = max(1, n_workers)
        self.max_retries = max_retries
        self.timeout_s = timeout_s
        self.speculate = speculate

    def run(self, jobs: Sequence[Callable[[], Any]],
            on_result: Optional[Callable[[JobResult], None]] = None
            ) -> List[JobResult]:
        n = len(jobs)
        if n == 0:
            return []
        results: Dict[int, JobResult] = {}
        cond = threading.Condition()
        attempts: Dict[int, int] = {i: 0 for i in range(n)}
        started_at: Dict[int, float] = {}
        inflight: Dict[int, int] = {}   # job_id -> live attempt count
        pending: Deque[int] = deque(range(n))  # dispatchable job ids

        alive = [0]  # live worker count; 0 with results missing => give up

        def worker(widx: int):
            try:
                _worker_loop(widx)
            finally:
                with cond:
                    alive[0] -= 1
                    cond.notify_all()

        def _worker_loop(widx: int):
            while True:
                with cond:
                    while not pending and len(results) < n:
                        cond.wait()
                    if len(results) == n:
                        return
                    jid = pending.popleft()
                    if jid in results:  # stale twin of a finished job
                        continue
                    attempts[jid] += 1
                    att = attempts[jid]
                    inflight[jid] = inflight.get(jid, 0) + 1
                    started_at[jid] = time.monotonic()
                    cond.notify_all()  # job left the queue: watcher re-arms
                t0 = time.monotonic()
                try:
                    value = jobs[jid]()
                    res = JobResult(jid, True, value=value, attempts=att,
                                    elapsed_s=time.monotonic() - t0,
                                    worker=widx)
                except Exception:  # noqa: BLE001 — worker failure is data
                    res = JobResult(jid, False, error=traceback.format_exc(),
                                    attempts=att,
                                    elapsed_s=time.monotonic() - t0,
                                    worker=widx)
                with cond:
                    inflight[jid] -= 1
                    if jid in results and results[jid].ok:
                        cond.notify_all()
                        continue  # lost the speculation race
                    if res.ok:
                        results[jid] = res
                        if on_result:
                            on_result(res)
                    else:
                        if att <= self.max_retries:
                            pending.append(jid)  # re-dispatch
                        else:
                            results[jid] = res
                            if on_result:
                                on_result(res)
                    cond.notify_all()

        with ThreadPoolExecutor(self.n_workers) as pool:
            alive[0] = self.n_workers
            for w in range(self.n_workers):
                pool.submit(worker, w)
            # straggler watch: once no unfinished job is waiting for a
            # worker, a job past timeout_s with a single live attempt gets
            # duplicated — first result wins.  The backlog test and the
            # per-job state are read under the same lock the workers write
            # them under, so a concurrent dequeue can no longer produce the
            # transient queue states that used to postpone speculation.
            # If every worker died (e.g. an on_result callback raised), stop
            # waiting and return the partial results, like the old
            # futures-done loop did — never deadlock on a missing notify.
            with cond:
                while len(results) < n and alive[0] > 0:
                    wait_s: Optional[float] = None
                    backlog = any(jid not in results for jid in pending)
                    if self.speculate and not backlog:
                        now = time.monotonic()
                        for jid in range(n):
                            if jid in results or jid in pending:
                                continue
                            if inflight.get(jid, 0) != 1:
                                continue
                            run_s = now - started_at.get(jid, now)
                            if run_s > self.timeout_s:
                                attempts[jid] = 0  # fresh budget for the twin
                                pending.append(jid)
                                cond.notify_all()
                            else:
                                rest = self.timeout_s - run_s
                                wait_s = rest if wait_s is None \
                                    else min(wait_s, rest)
                    cond.wait(timeout=wait_s)
                cond.notify_all()  # release workers parked on the queue
        # deterministic order
        return [results[i] for i in sorted(results)]
