"""Dynamic workload scheduler (paper §III-A).

"We handle the large training workload by implementing a dynamic workload
scheduler, which leverages parallel processing on HPC systems."

On a real cluster a *worker* is a host owning a device group; here a worker
is a thread (jit'd candidate training releases the GIL inside XLA).  The
scheduler adds the failure semantics required at 1000-node scale
(DESIGN.md §5, §13):

* **re-dispatch on failure** — a job whose worker raised (or timed out) is
  retried up to ``max_retries`` times, with exponential backoff plus
  seeded jitter between attempts (an immediately-retried transient fault
  usually recurs; synchronized retries stampede);
* **straggler mitigation** — the slowest still-running jobs are
  speculatively duplicated (first result wins);
* **heartbeat** — jobs report liveness via a timestamp the scheduler
  inspects; silent workers past ``timeout_s`` are declared dead;
* **device quarantine** — ``quarantine_after`` consecutive failures on one
  device (or a single :class:`~repro.core.faults.DeviceLost`) retire that
  device: its workers exit and its queued jobs rebalance onto the
  surviving devices.  The last live device is never quarantined — partial
  progress beats none.

A *job* is any independent unit of work — the NAS dispatches whole
signature buckets (one bucket = one vmap-stacked training, DESIGN.md §9),
so retry and speculation operate on buckets, exactly as they previously
operated on single candidates.

Two orchestration axes added for the overlapped search pipeline
(DESIGN.md §11):

* **Device affinity** — construct with ``devices=[...]`` (one opaque token
  per accelerator, e.g. ``jax.local_devices()``) and each worker thread is
  pinned to ``devices[widx % len(devices)]``; jobs are then invoked as
  ``job(device)`` so the payload can place its data on its worker's
  accelerator.  A speculative twin is *banned* from the straggling
  attempt's device (a straggler is as likely a sick device as a sick
  input), falling back to any worker when no other device has a live
  worker.  Retries carry no ban — any device may pick them up.
* **Asynchronous submission** — :meth:`DynamicScheduler.submit` starts the
  batch in background threads and returns a :class:`SchedulerRun` handle;
  the caller overlaps host-side work with the running jobs and collects
  with :meth:`SchedulerRun.wait`.  :meth:`DynamicScheduler.run` is the
  blocking composition ``submit(...).wait()``.

Load balance: ``submit(jobs, sizes=...)`` dispatches largest-first (LPT) —
with device-affine workers pulling from one queue, the big signature
buckets land first and the small ones fill the tail, so per-device busy
time stays level instead of one device finishing a giant bucket after the
rest went idle (the ``device_busy_s`` rebalancing signal, DESIGN.md §11).

Fault injection (DESIGN.md §13): pass ``faults=`` a
:class:`~repro.core.faults.FaultPlan` and every attempt consults the
``"scheduler.job"`` inject point before running its payload — crashes,
hangs and device loss are exercised through this explicit hook, never by
monkeypatching.

Everything is event-driven: workers block on a condition variable (no
dequeue polling; backoff-delayed retries bound the wait timeout), and the
straggler watcher sleeps until the earliest moment a running job can
exceed ``timeout_s`` — or until any state change wakes it.  Speculation
stays gated on "no unfinished job is waiting for a worker", with the
backlog test and the per-job queued/inflight/started-at state read under
the same lock the workers write them under.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
import traceback
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.faults import DeviceLost, FaultPlan


@dataclasses.dataclass
class JobResult:
    job_id: int
    ok: bool
    value: Any = None
    error: str = ""
    attempts: int = 1
    elapsed_s: float = 0.0
    worker: int = -1
    device: Any = None   # the winning attempt's device affinity (None =
    #                      scheduler constructed without device affinity)


# one pending dispatch: (job_id, banned_device, earliest_dispatch_time).
# ban != None only on speculative twins; ready_at > now only on backoff-
# delayed retries.
_PendingEntry = Tuple[int, Any, float]


class SchedulerRun:
    """One submitted batch of jobs executing in background threads.

    Returned by :meth:`DynamicScheduler.submit`; the submitting thread is
    free to do host-side work (the search pipeline's overlap window) until
    it calls :meth:`wait`.  All shared state lives behind one condition
    variable; worker threads and the straggler watcher exit on their own
    once every job has a result (or every worker died), so an abandoned
    handle does not leak threads.
    """

    def __init__(self, jobs: Sequence[Callable[..., Any]], *,
                 n_workers: int, max_retries: int, timeout_s: float,
                 speculate: bool,
                 devices: Optional[Sequence[Any]],
                 on_result: Optional[Callable[[JobResult], None]],
                 sizes: Optional[Sequence[float]] = None,
                 backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0,
                 quarantine_after: int = 3,
                 faults: Optional[FaultPlan] = None,
                 seed: int = 0):
        self._jobs = list(jobs)
        self._n = len(self._jobs)
        self._max_retries = max_retries
        self._timeout_s = timeout_s
        self._speculate = speculate
        self._on_result = on_result
        self._devices = list(devices) if devices else None
        self._backoff_base_s = backoff_base_s
        self._backoff_cap_s = backoff_cap_s
        self._quarantine_after = max(1, quarantine_after)
        self._faults = faults
        self._rng = random.Random(seed)  # backoff jitter only (wall time,
        #                                  never results)

        self._cond = threading.Condition()
        self._results: Dict[int, JobResult] = {}
        self._attempts: Dict[int, int] = {i: 0 for i in range(self._n)}
        self._started_at: Dict[int, float] = {}
        self._inflight: Dict[int, int] = {}      # job_id -> live attempts
        self._running_dev: Dict[int, Any] = {}   # job_id -> device of the
        #                                          single live attempt
        # largest-first (LPT) initial dispatch when sizes are known; a
        # stable sort keeps submission order inside one size class
        order = range(self._n) if sizes is None else \
            sorted(range(self._n), key=lambda i: -float(sizes[i]))
        self._pending: Deque[_PendingEntry] = deque(
            (i, None, 0.0) for i in order)
        self._alive = 0
        self._alive_devices: Dict[int, Any] = {}  # widx -> device
        self._fail_streak: Dict[str, int] = {}    # device key -> streak
        self._quarantined: set = set()            # device keys
        self.quarantined: List[Any] = []          # device tokens (stats)
        self.stats: Dict[str, float] = {"retries": 0, "backoff_s": 0.0,
                                        "quarantined": 0}

        if self._n == 0:
            return
        self._alive = n_workers
        # Register every worker's device BEFORE starting any thread: an
        # eagerly-scheduled first worker can fail (even DeviceLost) while
        # later workers are still being spawned, and the quarantine logic
        # must see the full device set or it mistakes the failing device
        # for the last live one and refuses to retire it.
        for w in range(n_workers):
            self._alive_devices[w] = self._devices[w % len(self._devices)] \
                if self._devices else None
        for w in range(n_workers):
            threading.Thread(target=self._worker,
                             args=(w, self._alive_devices[w]),
                             daemon=True, name=f"sched-worker-{w}").start()
        if speculate:
            threading.Thread(target=self._watcher, daemon=True,
                             name="sched-watcher").start()

    # ----------------------------------------------------------- public API
    def done(self) -> bool:
        with self._cond:
            return len(self._results) >= self._n or self._alive == 0

    def wait(self, timeout: Optional[float] = None) -> List[JobResult]:
        """Block until every job has a result (or every worker died, in
        which case the partial results are returned — the caller aligns by
        ``job_id``).  Results come back sorted by job id."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while len(self._results) < self._n and self._alive > 0:
                rest = None if deadline is None \
                    else max(0.0, deadline - time.monotonic())
                if rest == 0.0:
                    break
                self._cond.wait(timeout=rest)
            return [self._results[i] for i in sorted(self._results)]

    # -------------------------------------------------------------- helpers
    @staticmethod
    def _dev_key(device: Any) -> str:
        return str(device)

    def _backoff(self, attempt: int) -> float:
        """Exponential backoff with jitter before retry ``attempt + 1``:
        ``base * 2**(attempt-1)`` capped, times a uniform [1, 2) jitter."""
        if self._backoff_base_s <= 0.0:
            return 0.0
        raw = min(self._backoff_base_s * (2.0 ** (attempt - 1)),
                  self._backoff_cap_s)
        return raw * (1.0 + self._rng.random())

    def _note_failure(self, device: Any, device_lost: bool) -> None:
        """Track consecutive failures per device; quarantine a device that
        keeps failing (or reported lost) so its queued work rebalances onto
        the survivors.  Caller holds the lock."""
        if device is None:
            return
        k = self._dev_key(device)
        if device_lost:
            self._fail_streak[k] = self._quarantine_after
        else:
            self._fail_streak[k] = self._fail_streak.get(k, 0) + 1
        if self._fail_streak[k] < self._quarantine_after \
                or k in self._quarantined:
            return
        live = {self._dev_key(d) for d in self._alive_devices.values()
                if d is not None} - self._quarantined
        if live <= {k}:
            return  # never quarantine the last live device
        self._quarantined.add(k)
        self.quarantined.append(device)
        self.stats["quarantined"] += 1
        self._cond.notify_all()  # pinned workers wake up and exit

    # -------------------------------------------------------------- workers
    def _eligible(self, ban: Any, device: Any) -> bool:
        """May a worker pinned to ``device`` take this pending entry?  A
        twin's device ban applies only while some *other* live worker could
        honor it — affinity must never deadlock the queue."""
        if ban is None or device is None or ban != device:
            return True
        return not any(d != ban for d in self._alive_devices.values())

    def _take(self, device: Any, now: float
              ) -> Tuple[Optional[int], Optional[float]]:
        """Pop the first eligible, *ready* pending job id (stale twins of
        finished jobs are dropped on the way).  Returns ``(job_id, None)``
        or ``(None, wait_s)`` where ``wait_s`` bounds the sleep until the
        earliest backoff-delayed entry becomes ready (``None`` = nothing
        schedulable, wait for a state change).  Caller holds the lock."""
        soonest: Optional[float] = None
        for _ in range(len(self._pending)):
            entry = self._pending.popleft()
            jid, ban, ready_at = entry
            if jid in self._results and self._results[jid].ok:
                continue  # stale twin of a finished job
            if ready_at > now:
                rest = ready_at - now
                soonest = rest if soonest is None else min(soonest, rest)
                self._pending.append(entry)  # backoff not elapsed
                continue
            if self._eligible(ban, device):
                return jid, None
            self._pending.append(entry)  # rotate: not for this worker
        return None, soonest

    def _worker(self, widx: int, device: Any) -> None:
        try:
            self._worker_loop(widx, device)
        finally:
            with self._cond:
                self._alive -= 1
                self._alive_devices.pop(widx, None)
                self._cond.notify_all()

    def _worker_loop(self, widx: int, device: Any) -> None:
        while True:
            with self._cond:
                while True:
                    if len(self._results) >= self._n:
                        return
                    if device is not None \
                            and self._dev_key(device) in self._quarantined:
                        return  # retired with its device
                    jid, wait_s = self._take(device, time.monotonic())
                    if jid is not None:
                        break
                    self._cond.wait(timeout=wait_s)
                self._attempts[jid] += 1
                att = self._attempts[jid]
                self._inflight[jid] = self._inflight.get(jid, 0) + 1
                if self._inflight[jid] == 1:
                    self._running_dev[jid] = device
                self._started_at[jid] = time.monotonic()
                self._cond.notify_all()  # job left the queue: watcher re-arms
            t0 = time.monotonic()
            device_lost = False
            try:
                if self._faults is not None:
                    self._faults.fire("scheduler.job", job_id=jid,
                                      attempt=att, worker=widx,
                                      device=None if device is None
                                      else self._dev_key(device))
                value = self._jobs[jid](device) if self._devices is not None \
                    else self._jobs[jid]()
                res = JobResult(jid, True, value=value, attempts=att,
                                elapsed_s=time.monotonic() - t0,
                                worker=widx, device=device)
            except Exception as e:  # noqa: BLE001 — worker failure is data
                device_lost = isinstance(e, DeviceLost)
                res = JobResult(jid, False, error=traceback.format_exc(),
                                attempts=att,
                                elapsed_s=time.monotonic() - t0,
                                worker=widx, device=device)
            with self._cond:
                self._inflight[jid] -= 1
                if jid in self._results and self._results[jid].ok:
                    self._cond.notify_all()
                    continue  # lost the speculation race
                if res.ok:
                    if device is not None:
                        self._fail_streak[self._dev_key(device)] = 0
                    self._results[jid] = res
                    if self._on_result:
                        self._on_result(res)
                else:
                    self._note_failure(device, device_lost)
                    if att <= self._max_retries:
                        delay = self._backoff(att)
                        self.stats["retries"] += 1
                        self.stats["backoff_s"] += delay
                        self._pending.append(
                            (jid, None, time.monotonic() + delay))
                    else:
                        self._results[jid] = res
                        if self._on_result:
                            self._on_result(res)
                self._cond.notify_all()

    # -------------------------------------------------------------- watcher
    def _watcher(self) -> None:
        """Straggler watch: once no unfinished job is waiting for a worker,
        a job past ``timeout_s`` with a single live attempt gets duplicated
        — first result wins.  The twin is banned from the straggling
        attempt's device so it lands on a different accelerator when one
        has a live worker."""
        with self._cond:
            while len(self._results) < self._n and self._alive > 0:
                wait_s: Optional[float] = None
                backlog = any(jid not in self._results
                              for jid, _, _ in self._pending)
                if not backlog:
                    now = time.monotonic()
                    for jid in range(self._n):
                        if jid in self._results \
                                or any(p == jid
                                       for p, _, _ in self._pending):
                            continue
                        if self._inflight.get(jid, 0) != 1:
                            continue
                        run_s = now - self._started_at.get(jid, now)
                        if run_s > self._timeout_s:
                            self._attempts[jid] = 0  # fresh twin budget
                            self._pending.append(
                                (jid, self._running_dev.get(jid), 0.0))
                            self._cond.notify_all()
                        else:
                            rest = self._timeout_s - run_s
                            wait_s = rest if wait_s is None \
                                else min(wait_s, rest)
                self._cond.wait(timeout=wait_s)
            self._cond.notify_all()


class DynamicScheduler:
    """Run batches of independent jobs with retries + speculative execution.

    ``devices`` (optional) turns on device-affine dispatch: one opaque
    token per accelerator; worker ``w`` is pinned to
    ``devices[w % len(devices)]`` and jobs are invoked as ``job(device)``
    instead of ``job()`` so the payload can stage its data there.

    Failure knobs (DESIGN.md §13): retries back off exponentially from
    ``backoff_base_s`` (doubling per attempt, capped at ``backoff_cap_s``,
    jittered); ``quarantine_after`` consecutive failures on one device —
    or one :class:`~repro.core.faults.DeviceLost` — retire it for the rest
    of the batch (never the last live device).  ``faults`` wires a
    :class:`~repro.core.faults.FaultPlan` into every attempt's
    ``"scheduler.job"`` inject point.
    """

    def __init__(self, n_workers: int = 4, max_retries: int = 2,
                 timeout_s: float = 3600.0, speculate: bool = True,
                 devices: Optional[Sequence[Any]] = None,
                 backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0,
                 quarantine_after: int = 3,
                 faults: Optional[FaultPlan] = None,
                 seed: int = 0):
        self.n_workers = max(1, n_workers)
        self.max_retries = max_retries
        self.timeout_s = timeout_s
        self.speculate = speculate
        self.devices = list(devices) if devices else None
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.quarantine_after = quarantine_after
        self.faults = faults
        self.seed = seed

    def submit(self, jobs: Sequence[Callable[..., Any]],
               on_result: Optional[Callable[[JobResult], None]] = None,
               sizes: Optional[Sequence[float]] = None) -> SchedulerRun:
        """Start ``jobs`` in the background; returns the run handle.  The
        caller may overlap host-side work until :meth:`SchedulerRun.wait`.
        ``on_result`` fires under the scheduler lock as each job finishes
        (first ok attempt, or the final failed retry) — keep it short and
        never let it raise (a raising callback kills its worker).
        ``sizes`` (one weight per job) turns on largest-first dispatch."""
        return SchedulerRun(
            jobs, n_workers=self.n_workers, max_retries=self.max_retries,
            timeout_s=self.timeout_s, speculate=self.speculate,
            devices=self.devices, on_result=on_result, sizes=sizes,
            backoff_base_s=self.backoff_base_s,
            backoff_cap_s=self.backoff_cap_s,
            quarantine_after=self.quarantine_after,
            faults=self.faults, seed=self.seed)

    def run(self, jobs: Sequence[Callable[..., Any]],
            on_result: Optional[Callable[[JobResult], None]] = None,
            sizes: Optional[Sequence[float]] = None) -> List[JobResult]:
        if len(jobs) == 0:
            return []
        return self.submit(jobs, on_result=on_result, sizes=sizes).wait()
