"""Dynamic workload scheduler (paper §III-A).

"We handle the large training workload by implementing a dynamic workload
scheduler, which leverages parallel processing on HPC systems."

On a real cluster a *worker* is a host owning a device group; here a worker
is a thread (jit'd candidate training releases the GIL inside XLA).  The
scheduler adds the failure semantics required at 1000-node scale
(DESIGN.md §5):

* **re-dispatch on failure** — a job whose worker raised (or timed out) is
  retried up to ``max_retries`` times;
* **straggler mitigation** — when the queue drains, the slowest
  still-running jobs are speculatively duplicated (first result wins);
* **heartbeat** — jobs report liveness via a timestamp the scheduler
  inspects; silent workers past ``timeout_s`` are declared dead.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class JobResult:
    job_id: int
    ok: bool
    value: Any = None
    error: str = ""
    attempts: int = 1
    elapsed_s: float = 0.0
    worker: int = -1


class DynamicScheduler:
    """Run a batch of independent jobs with retries + speculative execution."""

    def __init__(self, n_workers: int = 4, max_retries: int = 2,
                 timeout_s: float = 3600.0, speculate: bool = True):
        self.n_workers = max(1, n_workers)
        self.max_retries = max_retries
        self.timeout_s = timeout_s
        self.speculate = speculate

    def run(self, jobs: Sequence[Callable[[], Any]],
            on_result: Optional[Callable[[JobResult], None]] = None
            ) -> List[JobResult]:
        n = len(jobs)
        results: Dict[int, JobResult] = {}
        lock = threading.Lock()
        attempts: Dict[int, int] = {i: 0 for i in range(n)}
        started_at: Dict[int, float] = {}
        inflight: Dict[int, int] = {}  # job_id -> live attempt count
        work: "queue.Queue[int]" = queue.Queue()
        for i in range(n):
            work.put(i)

        done_event = threading.Event()

        def worker(widx: int):
            while not done_event.is_set():
                try:
                    jid = work.get(timeout=0.05)
                except queue.Empty:
                    # stay alive: the straggler watcher may enqueue
                    # speculative twins for jobs still in flight
                    with lock:
                        if len(results) == n:
                            done_event.set()
                            return
                    continue
                with lock:
                    if jid in results:  # speculative twin already finished
                        continue
                    attempts[jid] += 1
                    att = attempts[jid]
                    inflight[jid] = inflight.get(jid, 0) + 1
                    started_at[jid] = time.monotonic()
                t0 = time.monotonic()
                try:
                    value = jobs[jid]()
                    res = JobResult(jid, True, value=value, attempts=att,
                                    elapsed_s=time.monotonic() - t0,
                                    worker=widx)
                except Exception:  # noqa: BLE001 — worker failure is data
                    res = JobResult(jid, False, error=traceback.format_exc(),
                                    attempts=att,
                                    elapsed_s=time.monotonic() - t0,
                                    worker=widx)
                with lock:
                    inflight[jid] -= 1
                    if jid in results and results[jid].ok:
                        continue  # lost the speculation race
                    if res.ok:
                        results[jid] = res
                        if on_result:
                            on_result(res)
                    else:
                        if att <= self.max_retries:
                            work.put(jid)  # re-dispatch
                        else:
                            results[jid] = res
                            if on_result:
                                on_result(res)

        with ThreadPoolExecutor(self.n_workers) as pool:
            futs = [pool.submit(worker, w) for w in range(self.n_workers)]
            # straggler watch: when the queue is empty but jobs are missing,
            # duplicate the longest-running ones so a hung worker cannot
            # stall the generation.
            while any(not f.done() for f in futs):
                time.sleep(0.05)
                if not self.speculate:
                    continue
                with lock:
                    if work.qsize() > 0:
                        continue
                    missing = [i for i in range(n) if i not in results]
                    now = time.monotonic()
                    for jid in missing:
                        run_s = now - started_at.get(jid, now)
                        if (inflight.get(jid, 0) == 1
                                and run_s > self.timeout_s):
                            attempts[jid] = 0  # reset budget for the twin
                            work.put(jid)
        # deterministic order
        return [results[i] for i in sorted(results)]
