"""Multi-objective machinery: non-dominated sorting, crowding, hypervolume.

All objectives are MINIMIZED.  Callers negate "higher is better" metrics
(e.g. detection rate) before handing them in.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray, eps: float = 0.0) -> bool:
    """a epsilon-dominates b: a <= b + eps everywhere, strictly < somewhere."""
    return bool(np.all(a <= b + eps) and np.any(a < b - eps))


def non_dominated_sort(points: np.ndarray) -> List[np.ndarray]:
    """Fast non-dominated sort (Deb et al.). Returns fronts of indices,
    front 0 = Pareto-optimal."""
    n = len(points)
    if n == 0:
        return []
    dominated_by: List[List[int]] = [[] for _ in range(n)]
    dom_count = np.zeros(n, dtype=np.int64)
    for i in range(n):
        for j in range(i + 1, n):
            if dominates(points[i], points[j]):
                dominated_by[i].append(j)
                dom_count[j] += 1
            elif dominates(points[j], points[i]):
                dominated_by[j].append(i)
                dom_count[i] += 1
    fronts: List[np.ndarray] = []
    current = np.nonzero(dom_count == 0)[0]
    while len(current):
        fronts.append(current)
        nxt: List[int] = []
        for i in current:
            for j in dominated_by[i]:
                dom_count[j] -= 1
                if dom_count[j] == 0:
                    nxt.append(j)
        current = np.asarray(sorted(nxt), dtype=np.int64)
    return fronts


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated points."""
    fronts = non_dominated_sort(points)
    return fronts[0] if fronts else np.asarray([], dtype=np.int64)


def crowding_distance(points: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance within one front (inf at the boundary)."""
    n, m = points.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for k in range(m):
        order = np.argsort(points[:, k], kind="stable")
        span = points[order[-1], k] - points[order[0], k]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        gaps = (points[order[2:], k] - points[order[:-2], k]) / span
        dist[order[1:-1]] += gaps
    return dist


def environmental_selection(points: np.ndarray, capacity: int) -> np.ndarray:
    """Keep `capacity` indices: fill whole fronts, break ties by crowding."""
    keep: List[int] = []
    for front in non_dominated_sort(points):
        if len(keep) + len(front) <= capacity:
            keep.extend(front.tolist())
        else:
            need = capacity - len(keep)
            cd = crowding_distance(points[front])
            order = np.argsort(-cd, kind="stable")
            keep.extend(front[order[:need]].tolist())
            break
    return np.asarray(sorted(keep), dtype=np.int64)


def hypervolume_2d(points: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume (minimization) w.r.t. reference point."""
    front = points[pareto_front(points)]
    front = front[np.argsort(front[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in front:
        if x >= ref[0] or y >= prev_y:
            continue
        hv += (ref[0] - x) * (prev_y - y)
        prev_y = y
    return float(hv)


def normalize(points: np.ndarray) -> np.ndarray:
    """Per-objective min-max normalization (degenerate dims -> 0)."""
    lo = points.min(axis=0)
    hi = points.max(axis=0)
    span = np.where(hi - lo > 1e-12, hi - lo, 1.0)
    return (points - lo) / span
