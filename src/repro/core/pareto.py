"""Multi-objective machinery: non-dominated sorting, crowding, hypervolume.

All objectives are MINIMIZED.  Callers negate "higher is better" metrics
(e.g. detection rate) before handing them in.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray, eps: float = 0.0) -> bool:
    """a epsilon-dominates b: a <= b + eps everywhere, strictly < somewhere."""
    return bool(np.all(a <= b + eps) and np.any(a < b - eps))


def non_dominated_sort_reference(points: np.ndarray) -> List[np.ndarray]:
    """Pure-Python fast non-dominated sort (Deb et al.).

    O(N²) with a Python inner loop — kept as the executable reference that
    the vectorized :func:`non_dominated_sort` is property-tested against
    (tests/test_pareto.py) and that benchmarks/nas_loop_bench.py times the
    array-resident loop against.
    """
    n = len(points)
    if n == 0:
        return []
    dominated_by: List[List[int]] = [[] for _ in range(n)]
    dom_count = np.zeros(n, dtype=np.int64)
    for i in range(n):
        for j in range(i + 1, n):
            if dominates(points[i], points[j]):
                dominated_by[i].append(j)
                dom_count[j] += 1
            elif dominates(points[j], points[i]):
                dominated_by[j].append(i)
                dom_count[i] += 1
    fronts: List[np.ndarray] = []
    current = np.nonzero(dom_count == 0)[0]
    while len(current):
        fronts.append(current)
        nxt: List[int] = []
        for i in current:
            for j in dominated_by[i]:
                dom_count[j] -= 1
                if dom_count[j] == 0:
                    nxt.append(j)
        current = np.asarray(sorted(nxt), dtype=np.int64)
    return fronts


def domination_matrix(points: np.ndarray, row_chunk: int = 256) -> np.ndarray:
    """``(N, N)`` bool, ``[i, j]`` = point i dominates point j.

    Built in row chunks, accumulating the all-``<=`` / any-``<`` conditions
    one objective column at a time: the intermediates stay 2-D and
    contiguous (cache-friendly, memory-bounded) instead of a ``(chunk, N,
    M)`` broadcast with a strided last-axis reduction.
    """
    points = np.asarray(points, dtype=np.float64)
    n, m = points.shape
    cols = [np.ascontiguousarray(points[:, k]) for k in range(m)]
    dom = np.empty((n, n), dtype=bool)
    for s in range(0, n, row_chunk):
        e = min(n, s + row_chunk)
        le = np.ones((e - s, n), dtype=bool)   # all(a <= b)
        lt = np.zeros((e - s, n), dtype=bool)  # any(a < b)
        for c in cols:
            blk = c[s:e, None]
            le &= blk <= c[None, :]
            lt |= blk < c[None, :]
        dom[s:e] = le & lt
    return dom


def domination_matrices(points: np.ndarray,
                        col_groups: Sequence[Sequence[int]],
                        row_chunk: int = 256) -> List[np.ndarray]:
    """Domination matrices for several objective-column subsets in one pass.

    ``out[g][i, j]`` = point i dominates point j *restricted to columns
    ``col_groups[g]``* — the objective-subset views behind per-platform and
    goal-conditioned Pareto fronts.  The per-column ``<=`` / ``<``
    comparison blocks are computed once per row chunk and folded into every
    group containing the column, so K subset matrices cost one matrix's
    worth of comparisons plus K cheap boolean folds (instead of K full
    :func:`domination_matrix` passes).
    """
    points = np.asarray(points, dtype=np.float64)
    n, _ = points.shape
    groups = [np.asarray(g, dtype=np.int64) for g in col_groups]
    if any(len(g) == 0 for g in groups):
        raise ValueError("empty objective-column group")
    needed = sorted({int(c) for g in groups for c in g})
    cols = {k: np.ascontiguousarray(points[:, k]) for k in needed}
    doms = [np.empty((n, n), dtype=bool) for _ in groups]
    for s in range(0, n, row_chunk):
        e = min(n, s + row_chunk)
        le_blk: dict = {}
        lt_blk: dict = {}
        for k in needed:
            c = cols[k]
            blk = c[s:e, None]
            le_blk[k] = blk <= c[None, :]
            lt_blk[k] = blk < c[None, :]
        for g, dom in zip(groups, doms):
            le = np.ones((e - s, n), dtype=bool)
            lt = np.zeros((e - s, n), dtype=bool)
            for k in g:
                le &= le_blk[int(k)]
                lt |= lt_blk[int(k)]
            dom[s:e] = le & lt
    return doms


class PartialDomination:
    """A domination matrix split into an early and a late column fold.

    The search pipeline's host-overlap window (DESIGN.md §11) builds the
    cheap-column ``all(<=)`` / ``any(<)`` accumulators for the merged
    population *while the generation's buckets train on the devices*; when
    the expensive objectives land, :meth:`finish` folds just those columns
    in.  Boolean ``&=`` / ``|=`` folds are order-independent, so the result
    is bit-identical to ``domination_matrix(np.concatenate([early, late],
    axis=1))`` — the overlapped pipeline's selection is exactly the
    synchronous loop's.
    """

    def __init__(self, early: np.ndarray, row_chunk: int = 256):
        early = np.asarray(early, dtype=np.float64)
        self._n = early.shape[0]
        self._row_chunk = row_chunk
        n = self._n
        self._le = np.empty((n, n), dtype=bool)
        self._lt = np.empty((n, n), dtype=bool)
        cols = [np.ascontiguousarray(early[:, k])
                for k in range(early.shape[1])]
        for s in range(0, n, row_chunk):
            e = min(n, s + row_chunk)
            le = np.ones((e - s, n), dtype=bool)
            lt = np.zeros((e - s, n), dtype=bool)
            for c in cols:
                blk = c[s:e, None]
                le &= blk <= c[None, :]
                lt |= blk < c[None, :]
            self._le[s:e] = le
            self._lt[s:e] = lt

    def finish(self, late: np.ndarray) -> np.ndarray:
        """Fold the late columns and return the full domination matrix.
        Consumes the accumulators in place (call once)."""
        late = np.asarray(late, dtype=np.float64)
        if late.shape[0] != self._n:
            raise ValueError(f"late columns have {late.shape[0]} rows; "
                             f"early fold had {self._n}")
        n, row_chunk = self._n, self._row_chunk
        cols = [np.ascontiguousarray(late[:, k])
                for k in range(late.shape[1])]
        for s in range(0, n, row_chunk):
            e = min(n, s + row_chunk)
            for c in cols:
                blk = c[s:e, None]
                self._le[s:e] &= blk <= c[None, :]
                self._lt[s:e] |= blk < c[None, :]
        return self._le & self._lt


def _peel_fronts(dom: np.ndarray):
    """Yield fronts from a domination matrix (Deb peeling, vectorized).

    Each round takes the zero-domination-count survivors as the next front
    and subtracts their column counts.  Yields exactly the reference fronts,
    ascending index order within each; lazy so callers that stop early
    (environmental selection at capacity) skip the remaining rounds.
    """
    n = len(dom)
    dom_count = dom.sum(axis=0)
    assigned = np.zeros(n, dtype=bool)
    n_done = 0
    while n_done < n:
        current = np.nonzero((dom_count == 0) & ~assigned)[0]
        yield current
        assigned[current] = True
        n_done += len(current)
        dom_count -= dom[current].sum(axis=0)


def non_dominated_sort(points: np.ndarray,
                       dom: Optional[np.ndarray] = None) -> List[np.ndarray]:
    """Fast non-dominated sort (Deb et al.). Returns fronts of indices,
    front 0 = Pareto-optimal.

    Vectorized: one domination matrix plus front peeling.  Produces exactly
    the same fronts (including the ascending index order within each front)
    as :func:`non_dominated_sort_reference`.  Pass a precomputed ``dom``
    (:func:`domination_matrix`) to share it across calls.
    """
    if len(points) == 0:
        return []
    return list(_peel_fronts(domination_matrix(points) if dom is None
                             else dom))


def pareto_front(points: np.ndarray,
                 dom: Optional[np.ndarray] = None) -> np.ndarray:
    """Indices of the non-dominated points (front 0 only — no peeling)."""
    if len(points) == 0:
        return np.asarray([], dtype=np.int64)
    if dom is None:
        dom = domination_matrix(points)
    return np.nonzero(dom.sum(axis=0) == 0)[0]


def crowding_distance(points: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance within one front (inf at the boundary)."""
    n, m = points.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for k in range(m):
        order = np.argsort(points[:, k], kind="stable")
        span = points[order[-1], k] - points[order[0], k]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        gaps = (points[order[2:], k] - points[order[:-2], k]) / span
        dist[order[1:-1]] += gaps
    return dist


def environmental_selection(points: np.ndarray, capacity: int,
                            dom: Optional[np.ndarray] = None) -> np.ndarray:
    """Keep `capacity` indices: fill whole fronts, break ties by crowding.

    Fronts are peeled lazily, so rounds past capacity are never computed.
    Pass a precomputed ``dom`` matrix to share it across calls.
    """
    if len(points) == 0:
        return np.asarray([], dtype=np.int64)
    if dom is None:
        dom = domination_matrix(points)
    keep: List[int] = []
    for front in _peel_fronts(dom):
        if len(keep) + len(front) <= capacity:
            keep.extend(front.tolist())
        else:
            need = capacity - len(keep)
            cd = crowding_distance(points[front])
            order = np.argsort(-cd, kind="stable")
            keep.extend(front[order[:need]].tolist())
            break
    return np.asarray(sorted(keep), dtype=np.int64)


def hypervolume_2d(points: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume (minimization) w.r.t. reference point."""
    front = points[pareto_front(points)]
    front = front[np.argsort(front[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in front:
        if x >= ref[0] or y >= prev_y:
            continue
        hv += (ref[0] - x) * (prev_y - y)
        prev_y = y
    return float(hv)


def normalize(points: np.ndarray) -> np.ndarray:
    """Per-objective min-max normalization (degenerate dims -> 0)."""
    lo = points.min(axis=0)
    hi = points.max(axis=0)
    span = np.where(hi - lo > 1e-12, hi - lo, 1.0)
    return (points - lo) / span
