"""LEMONADE-style Bayesian two-step selection (paper §III-A, via Elsken'18).

"For the selection strategy, we use a similar, bayesian-based method as [13],
which explores the Pareto Frontier of DNN candidates efficiently in a
two-step procedure, preselecting candidates based on computationally
inexpensive objectives first."

Mechanics: a kernel-density estimate (KDE) is fit over the *cheap* objective
values of the current population.  (1) Parents are sampled with probability
proportional to 1/density — favoring sparse regions of the cheap-objective
space; (2) generated children are preselected for *expensive* evaluation with
the same inverse-density weighting, so training budget flows to candidates
that extend the frontier rather than duplicate it.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.pareto import normalize


# Cap on the transient (chunk, n) kernel matrix inside GaussianKDE.density.
# The naive (m, n, d) broadcast is tens of GB at population 10k+; densities
# are instead computed chunk-by-chunk over the query axis with a GEMM for
# the pairwise distances, so memory stays bounded at any population size.
_DENSITY_CHUNK_BYTES = 64 * 1024 * 1024


class GaussianKDE:
    """Minimal Gaussian KDE with Scott's-rule bandwidth (no scipy on box)."""

    def __init__(self, data: np.ndarray):
        data = np.atleast_2d(np.asarray(data, dtype=np.float64))
        if data.ndim != 2:
            raise ValueError("data must be (n, d)")
        self.data = data
        n, d = data.shape
        sigma = data.std(axis=0)
        sigma = np.where(sigma > 1e-9, sigma, 1.0)
        self.h = sigma * max(n, 2) ** (-1.0 / (d + 4))  # Scott's rule
        self._zd = data / self.h            # bandwidth-standardized data
        self._zd_sq = np.einsum("nd,nd->n", self._zd, self._zd)

    def density(self, x: np.ndarray, chunk: Optional[int] = None
                ) -> np.ndarray:
        """Density at each query row.

        Pairwise squared distances come from the GEMM identity
        ``|zx - zd|^2 = |zx|^2 + |zd|^2 - 2 zx.zd^T`` (clipped at 0 against
        cancellation), and queries are processed in chunks sized to keep the
        ``(chunk, n)`` kernel matrix under ``_DENSITY_CHUNK_BYTES`` (pass
        ``chunk`` to override) — memory-bounded at population 10k+.
        """
        x = np.atleast_2d(np.asarray(x, dtype=np.float64))
        n, d = self.data.shape
        if chunk is None:
            chunk = max(1, _DENSITY_CHUNK_BYTES // (n * 8))
        norm = np.prod(self.h) * (2 * np.pi) ** (d / 2)
        out = np.empty(len(x), dtype=np.float64)
        for s in range(0, len(x), chunk):
            zx = x[s:s + chunk] / self.h
            d2 = (np.einsum("md,md->m", zx, zx)[:, None] + self._zd_sq[None, :]
                  - 2.0 * (zx @ self._zd.T))
            out[s:s + chunk] = np.exp(-0.5 * np.maximum(d2, 0.0)).sum(axis=1)
        return out / (n * norm) + 1e-300


def inverse_density_weights(pop_cheap: np.ndarray,
                            query_cheap: Optional[np.ndarray] = None,
                            cols: Optional[np.ndarray] = None
                            ) -> np.ndarray:
    """Normalized sampling weights ∝ 1/KDE-density in cheap-objective space.

    ``cols`` restricts the KDE to an objective-column subset (a
    goal-conditioned view of the schema-shaped cheap matrix): density — and
    therefore exploration pressure — is then measured only along the
    deployment goal's objectives.  ``None`` keeps the full space.
    """
    if cols is not None:
        pop_cheap = pop_cheap[:, cols]
        if query_cheap is not None:
            query_cheap = query_cheap[:, cols]
    pop_n = normalize(pop_cheap)
    kde = GaussianKDE(pop_n)
    if query_cheap is None:
        q = pop_n
    else:
        # normalize queries with the population's scaling
        lo = pop_cheap.min(axis=0)
        hi = pop_cheap.max(axis=0)
        span = np.where(hi - lo > 1e-12, hi - lo, 1.0)
        q = (query_cheap - lo) / span
    w = 1.0 / kde.density(q)
    w = np.where(np.isfinite(w), w, 0.0)
    s = w.sum()
    if s <= 0:
        return np.full(len(q), 1.0 / len(q))
    return w / s


def sample_parents(rng: np.random.Generator, pop_cheap: np.ndarray,
                   n: int, cols: Optional[np.ndarray] = None) -> np.ndarray:
    """Indices of `n` parents sampled inverse-density (with replacement).
    ``cols`` = goal-conditioned objective subset (None = all columns)."""
    w = inverse_density_weights(pop_cheap, cols=cols)
    return rng.choice(len(pop_cheap), size=n, replace=True, p=w)


def preselect_children(rng: np.random.Generator, pop_cheap: np.ndarray,
                       child_cheap: np.ndarray, n_accept: int,
                       cols: Optional[np.ndarray] = None) -> np.ndarray:
    """Step 2: pick children for expensive evaluation, inverse-density
    weighted against the *current population's* cheap-objective KDE.
    ``cols`` = goal-conditioned objective subset (None = all columns)."""
    if len(child_cheap) <= n_accept:
        return np.arange(len(child_cheap))
    w = inverse_density_weights(pop_cheap, child_cheap, cols=cols)
    if not np.all(np.isfinite(w)) or w.sum() <= 0:
        return rng.choice(len(child_cheap), size=n_accept, replace=False)
    return rng.choice(len(child_cheap), size=n_accept, replace=False, p=w)
