"""Bucketed vmap-stacked candidate training (DESIGN.md §9).

The expensive-objective stage trains every surviving child to measure
detection / false-alarm rates.  Candidates are tiny 1D-CNNs, so a scalar
`train_candidate` loop is dominated by per-step dispatch overhead, not
compute.  This module amortizes that overhead: children are bucketed by
*shape signature* — the static tuple that determines a compiled jaxpr — and
each bucket's per-candidate parameters are stacked into leading-axis pytrees
so the whole bucket trains inside ONE `jax.vmap`-ed, `lax.scan`-stepped XLA
dispatch sharing a single on-device dataset.

Parity contract: per-candidate results match the scalar
:func:`~repro.core.trainer.train_candidate` under matched seeds.  The pieces
that guarantee it:

* init vmaps :func:`~repro.core.trainer.init_candidate` over the same
  per-candidate PRNG keys (threefry is deterministic, vmapped or not);
* minibatch/calibration indices come from the shared
  :func:`~repro.core.trainer.presample_indices` stream, transferred once
  (no per-step host→device copies);
* the scan body IS :func:`~repro.core.trainer.train_step_pure`, the same
  traceable step the scalar path jits;
* quantization bit widths ride along as stacked per-candidate *data* (not
  part of the signature): :func:`~repro.hwlib.quant.fake_quant` is
  vmap-clean for traced bits, so candidates differing only in precision
  share one bucket and one compiled program.

Singleton buckets fall back to the scalar path (vmap over one candidate
buys nothing and would double-compile).

Device affinity (DESIGN.md §11): pass ``device=`` and the bucket's staged
dataset, stacked index/key/bit arrays and eval batches are committed to
that accelerator with ``jax.device_put`` — different signature buckets of
one generation then train concurrently on different devices.  The staging
cache is keyed per ``(input_length, device)`` and the compile cache per
``(signature, steps, batch, lr, device)``, so device-affine dispatch never
thrashes either.  Numerics are device-independent: the same compiled
program runs wherever the data lives, so results are bit-identical across
devices (asserted in tests/test_multi_device.py).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genome import Genome
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import (
    TrainResult,
    detection_rates,
    forward,
    init_candidate,
    prep_inputs,
    presample_indices,
    refresh_bn_pure,
    train_candidate,
    train_step_pure,
)
from repro.hwlib.layers import LayerSpec
from repro.hwlib.quant import QuantConfig
from repro.optim import adamw

ShapeSignature = Tuple[Tuple[Tuple, ...], int, bool]


def shape_signature(genome: Genome, space: SearchSpace = DEFAULT_SPACE,
                    use_quant: bool = True) -> ShapeSignature:
    """The static tuple that determines a candidate's compiled jaxpr:
    per-layer kernel signatures (kind, channels, kernel, stride, BN), the
    input length (decimation gene) and whether fake-quant is traced at all.

    Quantization *bit widths* are deliberately absent: they enter the
    batched trainer as stacked per-candidate data, so genomes that differ
    only in precision hash to the same signature and train in one bucket.
    """
    specs = genome.phenotype(space)
    return (tuple(s.signature() for s in specs),
            genome.input_length(space),
            bool(use_quant))


def bucket_by_signature(genomes: Sequence[Genome],
                        space: SearchSpace = DEFAULT_SPACE,
                        use_quant: bool = True
                        ) -> Dict[ShapeSignature, List[int]]:
    """Group candidate indices by :func:`shape_signature` (insertion-ordered,
    so dispatch order is deterministic given the input order)."""
    buckets: Dict[ShapeSignature, List[int]] = {}
    for i, g in enumerate(genomes):
        buckets.setdefault(shape_signature(g, space, use_quant), []).append(i)
    return buckets


# ---------------------------------------------------------------------------
# Compile cache: one (train, eval) function pair per signature + hyperparams.
# jit re-specializes on the bucket's leading axis internally; this cache
# avoids re-tracing/rebuilding the python closures per generation.
# ---------------------------------------------------------------------------

_CACHE_LOCK = threading.Lock()
_BUCKET_FN_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_CACHE_STATS = {"hits": 0, "misses": 0}
_CACHE_MAX = 128  # LRU-evicted: long-lived processes must not pin every
#                   signature's jitted executables forever


def compile_cache_stats() -> Dict[str, int]:
    with _CACHE_LOCK:
        return {**_CACHE_STATS, "size": len(_BUCKET_FN_CACHE)}


def reset_compile_cache() -> None:
    with _CACHE_LOCK:
        _BUCKET_FN_CACHE.clear()
        _CACHE_STATS.update(hits=0, misses=0)


def _build_bucket_fns(specs: Sequence[LayerSpec], use_quant: bool,
                      opt) -> tuple:
    """(train_bucket, eval_bucket) for one signature.

    ``train_bucket(keys, idx, calib_idx, bits, x_tr, y_tr)`` runs the whole
    bucket's training — init, `steps` scanned SGD steps, BN re-estimation —
    in one dispatch and returns the stacked trained params.
    ``eval_bucket(params, bits, xb, yb)`` forwards one shared eval batch
    through every candidate, returning per-candidate NLL sums and argmax
    predictions (device-resident; the caller accumulates).
    """

    def _quant(bits):
        if not use_quant:
            return None
        return QuantConfig(weight_bits=bits[0], act_bits=bits[1],
                           input_bits=bits[2])

    def _train_one(key, idx, calib_idx, bits, x_tr, y_tr):
        quant = _quant(bits)
        params = init_candidate(key, specs)
        opt_state = opt.init(params)

        def body(carry, idx_row):
            params, opt_state = carry
            params, opt_state, loss = train_step_pure(
                params, opt_state, x_tr[idx_row], y_tr[idx_row],
                specs=specs, quant=quant, opt=opt)
            return (params, opt_state), loss

        (params, _), _ = jax.lax.scan(body, (params, opt_state), idx)
        return refresh_bn_pure(params, specs, x_tr[calib_idx], quant)

    train_bucket = jax.jit(jax.vmap(_train_one,
                                    in_axes=(0, 0, 0, 0, None, None)))

    def _eval_one(params, bits, xb, yb):
        logits = forward(params, specs, xb, _quant(bits), train=False)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, yb[:, None], axis=1).sum()
        return nll, jnp.argmax(logits, axis=-1)

    eval_bucket = jax.jit(jax.vmap(_eval_one, in_axes=(0, 0, None, None)))
    return train_bucket, eval_bucket


def _bucket_fns(sig: ShapeSignature, specs: Sequence[LayerSpec],
                steps: int, batch_size: int, lr: float,
                device=None) -> tuple:
    key = (sig, steps, batch_size, float(lr), device)
    with _CACHE_LOCK:
        fns = _BUCKET_FN_CACHE.get(key)
        if fns is not None:
            _CACHE_STATS["hits"] += 1
            _BUCKET_FN_CACHE.move_to_end(key)
            return fns
        _CACHE_STATS["misses"] += 1
    opt = adamw(lr, b1=0.9, b2=0.99, weight_decay=1e-4)
    fns = _build_bucket_fns(specs, use_quant=sig[2], opt=opt)
    with _CACHE_LOCK:
        # lost a build race: keep the first pair so its jit cache wins
        fns = _BUCKET_FN_CACHE.setdefault(key, fns)
        _BUCKET_FN_CACHE.move_to_end(key)
        while len(_BUCKET_FN_CACHE) > _CACHE_MAX:
            _BUCKET_FN_CACHE.popitem(last=False)
    return fns


# ---------------------------------------------------------------------------
# Bucket training
# ---------------------------------------------------------------------------

def _put(x, device=None) -> jnp.ndarray:
    """Commit ``x`` to ``device`` (default device when None).  device_put
    with an explicit device yields a *committed* array, so every jit that
    consumes it compiles for and executes on that accelerator."""
    return jnp.asarray(x) if device is None else jax.device_put(x, device)


def _train_bucket(genomes: List[Genome], seeds: Sequence[int],
                  sig: ShapeSignature, space: SearchSpace,
                  x_tr: jnp.ndarray, y_tr: jnp.ndarray,
                  x_va: np.ndarray, y_va: np.ndarray,
                  steps: int, batch_size: int, lr: float,
                  eval_batch: int, device=None) -> List[TrainResult]:
    specs = genomes[0].phenotype(space)
    train_bucket, eval_bucket = _bucket_fns(sig, specs, steps, batch_size,
                                            lr, device)

    n = int(x_tr.shape[0])
    idx_rows, calib_rows = zip(*(presample_indices(s, n, steps, batch_size)
                                 for s in seeds))
    idx = _put(np.stack(idx_rows), device)       # (N, steps, B)
    calib = _put(np.stack(calib_rows), device)   # (N, C)
    keys = _put(np.stack([np.asarray(jax.random.PRNGKey(s))
                          for s in seeds]), device)
    if sig[2]:
        bits = _put(np.stack(
            [(q.weight_bits, q.act_bits, q.input_bits)
             for q in (g.quant(space) for g in genomes)]).astype(np.int32),
            device)
    else:
        bits = _put(np.zeros((len(genomes), 3), np.int32), device)

    params = train_bucket(keys, idx, calib, bits, x_tr, y_tr)

    # chunked eval mirrors the scalar `evaluate` exactly (the input
    # fake-quant scale is a per-chunk max, so chunk boundaries are part of
    # the numerics contract); accumulation stays on device until the end.
    nll_parts, preds = [], []
    for i in range(0, len(x_va), eval_batch):
        nll, pred = eval_bucket(params, bits,
                                _put(x_va[i:i + eval_batch], device),
                                _put(y_va[i:i + eval_batch], device))
        nll_parts.append(nll)
        preds.append(pred)
    pred = np.asarray(jnp.concatenate(preds, axis=1))       # (N, n_va)
    nll = np.asarray(jnp.sum(jnp.stack(nll_parts), axis=0))  # (N,)

    out = []
    for k in range(len(genomes)):
        det, fa = detection_rates(pred[k], y_va)
        vl = float(nll[k]) / len(y_va)
        if not np.isfinite(vl):
            # per-candidate quarantine (DESIGN.md §13): one diverged
            # candidate (NaN/inf loss poisons its NLL) must not fail the
            # whole vmap bucket — it alone reports pessimistic rates (its
            # argmax predictions are garbage) while its bucket-mates keep
            # their real results.  The non-finite val_loss rides along so
            # the search driver maps it to the schema-pessimistic row.
            det, fa = 0.0, 1.0
        out.append(TrainResult(detection_rate=det, false_alarm_rate=fa,
                               val_loss=vl, steps=steps))
    return out


def train_candidates_batched(
    genomes: Sequence[Genome],
    data_train: Tuple[np.ndarray, np.ndarray],
    data_val: Tuple[np.ndarray, np.ndarray],
    *,
    space: SearchSpace = DEFAULT_SPACE,
    steps: int = 300,
    batch_size: int = 64,
    lr: float = 3e-3,
    seed: int = 0,
    seeds: Optional[Sequence[int]] = None,
    use_quant: bool = True,
    eval_batch: int = 256,
    min_bucket: int = 2,
    stage_cache: Optional[Dict[tuple, tuple]] = None,
    device=None,
) -> List[TrainResult]:
    """Train a whole child generation, bucketed by shape signature.

    Returns one :class:`TrainResult` per input genome, in input order.
    ``seeds`` optionally gives per-candidate training seeds (default: the
    single ``seed`` for all, matching the search driver's scalar behavior).
    Buckets smaller than ``min_bucket`` take the scalar
    :func:`train_candidate` path.  ``stage_cache`` ((want_len, device) →
    staged arrays) lets a long-lived caller keep the prepped dataset
    resident on device across calls — the search driver passes one per
    search, so concurrently dispatched buckets don't re-upload the
    training set.  ``device`` pins every bucket of this call to one
    accelerator (the device-affine scheduler passes its worker's device);
    ``None`` keeps today's default-device behavior.
    """
    genomes = list(genomes)
    if seeds is None:
        seeds = [seed] * len(genomes)
    elif len(seeds) != len(genomes):
        raise ValueError("seeds must align with genomes")
    results: List[Optional[TrainResult]] = [None] * len(genomes)

    staged = stage_cache if stage_cache is not None else {}

    def stage(want_len: int) -> tuple:
        got = staged.get((want_len, device))
        if got is None:  # setdefault: concurrent stagers agree on one copy
            got = staged.setdefault((want_len, device), (
                _put(prep_inputs(data_train[0], want_len), device),
                _put(data_train[1], device),
                prep_inputs(data_val[0], want_len),
                data_val[1]))
        return got

    for sig, rows in bucket_by_signature(genomes, space, use_quant).items():
        if len(rows) < min_bucket:
            for i in rows:
                if device is not None:
                    with jax.default_device(device):
                        results[i] = train_candidate(
                            genomes[i], data_train, data_val, space=space,
                            steps=steps, batch_size=batch_size, lr=lr,
                            seed=seeds[i], use_quant=use_quant)
                else:
                    results[i] = train_candidate(
                        genomes[i], data_train, data_val, space=space,
                        steps=steps, batch_size=batch_size, lr=lr,
                        seed=seeds[i], use_quant=use_quant)
            continue
        x_tr, y_tr, x_va, y_va = stage(sig[1])
        bucket_results = _train_bucket(
            [genomes[i] for i in rows], [seeds[i] for i in rows], sig,
            space, x_tr, y_tr, x_va, y_va, steps, batch_size, lr,
            eval_batch, device)
        for i, r in zip(rows, bucket_results):
            results[i] = r
    return results  # type: ignore[return-value]
