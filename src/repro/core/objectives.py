"""Objective registry — the paper's §VI objective set.

"The optimization objectives are power, energy and latency each with and
without unrolling, and additionally number of parameters, detection and
false alarm rate.  All objectives are considered at the same time in the
Pareto frontier."

Cheap objectives (no training needed) come from the analytic hardware models
of :mod:`repro.core.hw_model`; expensive objectives (detection / false-alarm
rate) require candidate training.  All values are oriented for MINIMIZATION.

Column layout is described by an
:class:`~repro.core.objective_schema.ObjectiveSchema` (DESIGN.md §10): a
single-platform backend yields the classic 7-column ``CHEAP_NAMES`` matrix,
a :class:`~repro.core.cost_backend.MultiPlatformBackend` a ``K*7``-column
one with per-platform groups.  The canonical names live in
:mod:`repro.core.objective_schema` and are re-exported here.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.cost_backend import BackendSpec, backend_schema, get_backend
from repro.core.genome import Genome, PopulationEncoding
from repro.core.hw_model import FPGA_ZU, HardwareProfile, estimate
from repro.core.objective_schema import (  # noqa: F401  (re-exports)
    ALL_NAMES,
    CHEAP_NAMES,
    Constraints,
    EXPENSIVE_NAMES,
    LEGACY_CHEAP_SCHEMA,
    ObjectiveSchema,
    pessimistic_expensive,
)
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import TrainResult


def cheap_objectives(g: Genome, *, profile: HardwareProfile = FPGA_ZU,
                     space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
    """The 7 analytic objectives: {power, energy, latency} x {min,max alpha}
    + parameter count."""
    est_min = estimate(g, strategy="min", profile=profile, space=space)
    est_max = estimate(g, strategy="max", profile=profile, space=space)
    return np.asarray([
        est_min.p_total_w,
        est_max.p_total_w,
        est_min.e_total_j,
        est_max.e_total_j,
        est_min.latency_s,
        est_max.latency_s,
        float(est_min.params),
    ], dtype=np.float64)


def cheap_objectives_batch(
    genomes, *,
    backend: Optional[BackendSpec] = None,
    profile: HardwareProfile = FPGA_ZU,
    space: SearchSpace = DEFAULT_SPACE,
) -> np.ndarray:
    """Batched :func:`cheap_objectives`: ``(N, C)`` in backend-schema order
    (``C = 7`` for a single platform, ``K*7`` for a multi-platform backend).

    ``genomes`` is a sequence of :class:`Genome` or a ready
    :class:`PopulationEncoding`.  Evaluation routes through a pluggable
    :class:`~repro.core.cost_backend.CostBackend`; by default the vectorized
    Eq. 1-4 analytic backend for ``profile`` (bit-for-bit consistent with the
    scalar path — this is the search's hot loop, DESIGN.md §2).
    """
    be = get_backend(profile if backend is None else backend)
    if not isinstance(genomes, PopulationEncoding):
        if len(genomes) == 0:
            return np.zeros((0, len(backend_schema(be))), dtype=np.float64)
        genomes = PopulationEncoding.from_genomes(list(genomes))
    return be.evaluate_batch(genomes, space=space)


def expensive_objectives(result: TrainResult) -> np.ndarray:
    """(miss rate, false-alarm rate) — both minimized; miss = 1 - detection."""
    return np.asarray([1.0 - result.detection_rate,
                       result.false_alarm_rate], dtype=np.float64)


PESSIMISTIC_EXPENSIVE = np.asarray([1.0, 1.0])  # untrained placeholder


@dataclasses.dataclass
class Candidate:
    """A genome plus every objective value the search knows about."""

    genome: Genome
    cheap: np.ndarray
    expensive: Optional[np.ndarray] = None        # None until trained
    train_result: Optional[TrainResult] = None
    phash: str = ""
    generation: int = 0

    def objective_vector(self) -> np.ndarray:
        exp = self.expensive if self.expensive is not None \
            else PESSIMISTIC_EXPENSIVE
        return np.concatenate([self.cheap, exp])

    @property
    def trained(self) -> bool:
        return self.expensive is not None

    def meets_constraints(self,
                          det_min: Union[None, float, Constraints] = None,
                          fa_max: Optional[float] = None) -> bool:
        """Hard acceptance limits; pass a :class:`Constraints` or the
        legacy ``(det_min, fa_max)`` floats (default: paper limits)."""
        if self.expensive is None:
            return False
        return bool(Constraints.coerce(det_min, fa_max)
                    .ok_rows(self.expensive[None, :])[0])


def objective_matrix(pop: Sequence[Candidate]) -> np.ndarray:
    return np.stack([c.objective_vector() for c in pop])


def cheap_matrix(pop: Sequence[Candidate]) -> np.ndarray:
    return np.stack([c.cheap for c in pop])


# ---------------------------------------------------------------------------
# Struct-of-arrays population (DESIGN.md §8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PopulationArrays:
    """A whole population as stacked arrays — the search's resident state.

    Bundles the gene arrays (:class:`~repro.core.genome.PopulationEncoding`)
    with the cheap/expensive objective matrices, phenotype hashes and birth
    generations, so every generation-step operation (parent sampling,
    preselection, non-dominated sort, environmental selection) runs over
    arrays; :class:`Candidate` objects are materialized only at the edges
    (training dispatch, checkpoints, reports).  ``expensive`` rows are NaN
    until the member is trained; :meth:`objective_matrix` substitutes the
    pessimistic placeholder exactly like ``Candidate.objective_vector``.

    ``schema`` names the cheap columns (platform-tagged for multi-platform
    backends); ``None`` means the legacy single-platform 7-column layout.
    """

    enc: "PopulationEncoding"
    cheap: np.ndarray       # (N, C) float64 — cheap-schema column order
    expensive: np.ndarray   # (N, 2) float64 — NaN rows = untrained
    phash: np.ndarray       # (N,) object — phenotype-hash dedup keys
    born: np.ndarray        # (N,) int64 — generation each member was created
    schema: Optional[ObjectiveSchema] = None   # cheap columns; None = legacy

    def __len__(self) -> int:
        return len(self.enc)

    @property
    def cheap_schema(self) -> ObjectiveSchema:
        """The cheap-column schema (legacy 7-column layout when unset)."""
        if self.schema is not None:
            return self.schema
        if self.cheap.shape[1] == len(LEGACY_CHEAP_SCHEMA):
            return LEGACY_CHEAP_SCHEMA
        raise ValueError(
            f"schema-less cheap matrix with {self.cheap.shape[1]} columns "
            f"(legacy layout has {len(LEGACY_CHEAP_SCHEMA)})")

    @property
    def full_schema(self) -> ObjectiveSchema:
        """Cheap + expensive columns — :meth:`objective_matrix`'s layout."""
        return self.cheap_schema.with_expensive()

    @property
    def trained_mask(self) -> np.ndarray:
        return np.isfinite(self.expensive).all(axis=1)

    def objective_matrix(self) -> np.ndarray:
        """(N, C+E) full objective matrix (``full_schema`` column order),
        pessimistic where untrained.  The placeholder row is derived from
        the schema's expensive columns (width and worst-case values), so a
        schema with a non-default expensive set stays consistent."""
        worst = pessimistic_expensive(self.full_schema)
        exp = np.where(np.isfinite(self.expensive), self.expensive,
                       worst[None, :])
        return np.concatenate([self.cheap, exp], axis=1)

    def feasible_mask(self,
                      det_min: Union[None, float, Constraints] = None,
                      fa_max: Optional[float] = None) -> np.ndarray:
        """Vectorized ``Candidate.meets_constraints`` (untrained = False).
        Pass a :class:`Constraints` or the legacy float pair."""
        cons = Constraints.coerce(det_min, fa_max)
        return self.trained_mask & cons.ok_rows(self.expensive)

    def take(self, idx) -> "PopulationArrays":
        idx = np.asarray(idx)
        return PopulationArrays(
            enc=self.enc.take(idx), cheap=self.cheap[idx],
            expensive=self.expensive[idx], phash=self.phash[idx],
            born=self.born[idx], schema=self.schema)

    @classmethod
    def concat(cls, parts: Sequence["PopulationArrays"]
               ) -> "PopulationArrays":
        parts = [p for p in parts if len(p)]
        if len(parts) == 1:
            return parts[0]
        return cls(
            enc=PopulationEncoding.concatenate([p.enc for p in parts]),
            cheap=np.concatenate([p.cheap for p in parts]),
            expensive=np.concatenate([p.expensive for p in parts]),
            phash=np.concatenate([p.phash for p in parts]),
            born=np.concatenate([p.born for p in parts]),
            schema=parts[0].schema)

    # ------------------------------------------------------- object edges
    def candidate(self, i: int) -> Candidate:
        """Materialize one member as a :class:`Candidate`."""
        trained = bool(np.isfinite(self.expensive[i]).all())
        return Candidate(
            genome=self.enc.genome(i), cheap=self.cheap[i].copy(),
            expensive=self.expensive[i].copy() if trained else None,
            phash=str(self.phash[i]), generation=int(self.born[i]))

    def to_candidates(self) -> List[Candidate]:
        return [self.candidate(i) for i in range(len(self))]

    @classmethod
    def from_candidates(cls, cands: Sequence[Candidate],
                        schema: Optional[ObjectiveSchema] = None
                        ) -> "PopulationArrays":
        exp = np.full((len(cands), len(EXPENSIVE_NAMES)), np.nan)
        for i, c in enumerate(cands):
            if c.expensive is not None:
                exp[i] = c.expensive
        return cls(
            enc=PopulationEncoding.from_genomes([c.genome for c in cands]),
            cheap=np.stack([np.asarray(c.cheap, np.float64) for c in cands]),
            expensive=exp,
            phash=np.asarray([c.phash for c in cands], dtype=object),
            born=np.asarray([c.generation for c in cands], dtype=np.int64),
            schema=schema)
