"""Objective registry — the paper's §VI objective set.

"The optimization objectives are power, energy and latency each with and
without unrolling, and additionally number of parameters, detection and
false alarm rate.  All objectives are considered at the same time in the
Pareto frontier."

Cheap objectives (no training needed) come from the analytic hardware models
of :mod:`repro.core.hw_model`; expensive objectives (detection / false-alarm
rate) require candidate training.  All values are oriented for MINIMIZATION.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_backend import BackendSpec, get_backend
from repro.core.genome import Genome, PopulationEncoding
from repro.core.hw_model import FPGA_ZU, HardwareProfile, estimate
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import TrainResult

# canonical ordering of the 9 paper objectives
CHEAP_NAMES: Tuple[str, ...] = (
    "power_min_alpha_w", "power_max_alpha_w",
    "energy_min_alpha_j", "energy_max_alpha_j",
    "latency_min_alpha_s", "latency_max_alpha_s",
    "n_params",
)
EXPENSIVE_NAMES: Tuple[str, ...] = ("miss_rate", "false_alarm_rate")
ALL_NAMES: Tuple[str, ...] = CHEAP_NAMES + EXPENSIVE_NAMES


def cheap_objectives(g: Genome, *, profile: HardwareProfile = FPGA_ZU,
                     space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
    """The 7 analytic objectives: {power, energy, latency} x {min,max alpha}
    + parameter count."""
    est_min = estimate(g, strategy="min", profile=profile, space=space)
    est_max = estimate(g, strategy="max", profile=profile, space=space)
    return np.asarray([
        est_min.p_total_w,
        est_max.p_total_w,
        est_min.e_total_j,
        est_max.e_total_j,
        est_min.latency_s,
        est_max.latency_s,
        float(est_min.params),
    ], dtype=np.float64)


def cheap_objectives_batch(
    genomes, *,
    backend: Optional[BackendSpec] = None,
    profile: HardwareProfile = FPGA_ZU,
    space: SearchSpace = DEFAULT_SPACE,
) -> np.ndarray:
    """Batched :func:`cheap_objectives`: ``(N, 7)`` in ``CHEAP_NAMES`` order.

    ``genomes`` is a sequence of :class:`Genome` or a ready
    :class:`PopulationEncoding`.  Evaluation routes through a pluggable
    :class:`~repro.core.cost_backend.CostBackend`; by default the vectorized
    Eq. 1-4 analytic backend for ``profile`` (bit-for-bit consistent with the
    scalar path — this is the search's hot loop, DESIGN.md §2).
    """
    if not isinstance(genomes, PopulationEncoding):
        if len(genomes) == 0:
            return np.zeros((0, len(CHEAP_NAMES)), dtype=np.float64)
        genomes = PopulationEncoding.from_genomes(list(genomes))
    be = get_backend(profile if backend is None else backend)
    return be.evaluate_batch(genomes, space=space)


def expensive_objectives(result: TrainResult) -> np.ndarray:
    """(miss rate, false-alarm rate) — both minimized; miss = 1 - detection."""
    return np.asarray([1.0 - result.detection_rate,
                       result.false_alarm_rate], dtype=np.float64)


PESSIMISTIC_EXPENSIVE = np.asarray([1.0, 1.0])  # untrained placeholder


@dataclasses.dataclass
class Candidate:
    """A genome plus every objective value the search knows about."""

    genome: Genome
    cheap: np.ndarray
    expensive: Optional[np.ndarray] = None        # None until trained
    train_result: Optional[TrainResult] = None
    phash: str = ""
    generation: int = 0

    def objective_vector(self) -> np.ndarray:
        exp = self.expensive if self.expensive is not None \
            else PESSIMISTIC_EXPENSIVE
        return np.concatenate([self.cheap, exp])

    @property
    def trained(self) -> bool:
        return self.expensive is not None

    def meets_constraints(self, det_min: float = 0.90, fa_max: float = 0.20
                          ) -> bool:
        if self.expensive is None:
            return False
        return (1.0 - self.expensive[0]) >= det_min and \
            self.expensive[1] <= fa_max


def objective_matrix(pop: Sequence[Candidate]) -> np.ndarray:
    return np.stack([c.objective_vector() for c in pop])


def cheap_matrix(pop: Sequence[Candidate]) -> np.ndarray:
    return np.stack([c.cheap for c in pop])
