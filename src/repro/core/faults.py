"""Deterministic fault-injection harness (DESIGN.md §13).

A :class:`FaultPlan` *schedules* faults; components *expose inject points*
(explicit hooks — never monkeypatching) and consult the plan at each one.
A fault fires when its :class:`FaultSpec` matches the site's hit counter
(``at`` / ``every``) and its context predicate (``when``).  Everything is
deterministic given the plan: counters advance one per hook call, the only
randomness is the plan's own seeded generator (used by helpers like
:func:`FaultPlan.corrupt_file`), so two runs wired to equal plans see the
same faults at the same points.

Inject points in this repo (the component calls the hook; the table is
normative — see DESIGN.md §13):

====================  ======================================================
site                  where / context keys
====================  ======================================================
``scheduler.job``     worker about to execute a job attempt
                      (``job_id``, ``attempt``, ``worker``, ``device``)
``trainer.result``    one trained candidate's result is being recorded
                      (``phash``, ``generation``)
``search.generation`` top of a resumable search's generation loop
                      (``generation``)
``ckpt.save``         a checkpoint was just written (``path``)
``serve.decode``      serve engine about to run a decode step (``step``)
``serve.replica``     router health-checks a live serving replica at the
                      top of a tick (``replica``, ``tick``, ``step``)
``router.dispatch``   router about to hand a request to a replica
                      (``rid``, ``replica``, ``tick``)
====================  ======================================================

Fault kinds and their actions under :meth:`FaultPlan.fire`:

* ``crash``       — raise :class:`InjectedCrash` (a failed worker attempt);
* ``device_loss`` — raise :class:`DeviceLost` (the scheduler quarantines
  the attempt's device immediately);
* ``hang``        — sleep ``hang_s`` then return (a stalled worker: the
  straggler watcher / pytest-timeout see a silent job);
* ``preempt``     — raise :class:`Preemption` (a ``KeyboardInterrupt``
  subclass: SIGTERM/ctrl-C semantics, exercised by ``run_resumable``);
* ``nonfinite`` / ``corrupt`` / any data kind — no action; the spec is
  *returned to the caller*, which applies the corruption itself (a NaN
  training result, a truncated checkpoint file, a serve-decode stall).

:meth:`FaultPlan.check` is the pure variant: it counts the hit and returns
the matching spec without acting — for callers that must stay in control
of time (the serve engine's virtual clock advances instead of sleeping).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """Base class for every exception the harness raises on purpose."""


class InjectedCrash(InjectedFault):
    """A worker attempt dying mid-job (process kill, OOM, assert)."""


class DeviceLost(InjectedCrash):
    """An accelerator disappearing under a job (XID error, preempted VM).

    The scheduler treats this as *device* failure, not job failure: the
    device is quarantined immediately and the job retries elsewhere.
    """


class Preemption(KeyboardInterrupt):
    """Injected SIGTERM/ctrl-C — a ``KeyboardInterrupt`` subclass so the
    graceful-preemption path in ``run_resumable`` handles real and
    injected preemptions identically."""


#: kinds whose action is raising from inside :meth:`FaultPlan.fire`
RAISING_KINDS = ("crash", "device_loss", "preempt")
#: kinds the caller applies itself (fire/check just return the spec)
DATA_KINDS = ("nonfinite", "corrupt", "stall")
KINDS = RAISING_KINDS + DATA_KINDS + ("hang",)


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: *where* (site), *what* (kind), *when* (hit
    pattern + optional context predicate).

    Hit counters are 1-based and per-site: ``at=(3,)`` fires on the site's
    third hook call, ``every=4`` on every fourth.  ``times`` caps the total
    number of fires (``None`` = unlimited).  ``when`` sees the hook call's
    context dict and must also hold for the fault to fire — use it for
    concurrency-safe matching (e.g. ``job_id``-keyed crashes are
    deterministic regardless of worker interleaving; raw counters at a
    multi-threaded site are not).
    """

    site: str
    kind: str
    every: int = 0
    at: Tuple[int, ...] = ()
    times: Optional[int] = None
    hang_s: float = 0.0
    when: Optional[Callable[[Dict[str, Any]], bool]] = None

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(kinds: {KINDS})")
        if not self.every and not self.at and self.when is None:
            raise ValueError(
                "FaultSpec needs a trigger: every=, at=, or when=")

    def matches(self, hit: int, ctx: Dict[str, Any]) -> bool:
        if self.when is not None and not self.when(ctx):
            return False
        if self.at and hit in self.at:
            return True
        if self.every and hit % self.every == 0:
            return True
        # pure-predicate spec: every hit the predicate accepts
        return self.when is not None and not self.at and not self.every


@dataclasses.dataclass
class FaultEvent:
    """One fired fault — the plan's audit log entry."""

    site: str
    hit: int
    kind: str
    ctx: Dict[str, Any]


class FaultPlan:
    """A seeded, deterministic schedule of faults over named inject points.

    Thread-safe: sites are hit from scheduler worker threads.  The plan is
    inert unless a component was handed it explicitly (``faults=`` kwargs
    throughout the repo); a ``None`` plan means production behavior.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.events: List[FaultEvent] = []
        self._hits: Dict[str, int] = {}
        self._fires: Dict[int, int] = {}  # spec index -> fires so far
        self._lock = threading.Lock()

    # ------------------------------------------------------------- matching
    def hits(self, site: str) -> int:
        with self._lock:
            return self._hits.get(site, 0)

    def fired(self, site: Optional[str] = None,
              kind: Optional[str] = None) -> List[FaultEvent]:
        """Audit-log query for test assertions."""
        with self._lock:
            return [e for e in self.events
                    if (site is None or e.site == site)
                    and (kind is None or e.kind == kind)]

    def check(self, site: str, **ctx: Any) -> Optional[FaultSpec]:
        """Count a hit at ``site``; return the scheduled fault (if any)
        WITHOUT acting on it.  First matching spec wins per hit."""
        with self._lock:
            self._hits[site] = hit = self._hits.get(site, 0) + 1
            for si, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if spec.times is not None \
                        and self._fires.get(si, 0) >= spec.times:
                    continue
                if spec.matches(hit, ctx):
                    self._fires[si] = self._fires.get(si, 0) + 1
                    self.events.append(FaultEvent(site, hit, spec.kind,
                                                  dict(ctx)))
                    return spec
        return None

    def fire(self, site: str, **ctx: Any) -> Optional[FaultSpec]:
        """Count a hit and ACT on the scheduled fault: raising kinds raise,
        ``hang`` sleeps, data kinds are returned for the caller to apply
        (``None`` when nothing fires)."""
        spec = self.check(site, **ctx)
        if spec is None:
            return None
        what = f"injected {spec.kind} at {site} (hit {self._hits[site]})"
        if spec.kind == "hang":
            time.sleep(spec.hang_s)
            return spec
        if spec.kind == "device_loss":
            raise DeviceLost(what)
        if spec.kind == "crash":
            raise InjectedCrash(what)
        if spec.kind == "preempt":
            raise Preemption(what)
        return spec

    # ------------------------------------------------------------- actions
    def corrupt_file(self, path: str, mode: str = "truncate") -> None:
        """Deterministically damage a file on disk (the ``corrupt`` kind's
        payload, applied by the caller that owns the path).  ``truncate``
        keeps the first half; ``garbage`` overwrites the tail with bytes
        drawn from the plan's seeded generator."""
        with open(path, "rb") as f:
            data = f.read()
        keep = len(data) // 2
        if mode == "truncate":
            blob = data[:keep]
        elif mode == "garbage":
            tail = self.rng.integers(0, 256, max(len(data) - keep, 1),
                                     dtype=np.uint8).tobytes()
            blob = data[:keep] + tail
        else:
            raise ValueError(f"unknown corruption mode {mode!r}")
        with open(path, "wb") as f:
            f.write(blob)


def _job_keyed(n: int, kind: str, site: str, first_attempt_only: bool,
               times: Optional[int]) -> FaultSpec:
    """Job-keyed drill spec: fire ``kind`` on every ``n``-th job's first
    attempt.  Keyed on the context's ``job_id``/``attempt`` (not the raw
    hit counter), so the schedule is deterministic under any worker
    interleaving."""
    def when(ctx: Dict[str, Any]) -> bool:
        jid = ctx.get("job_id")
        if jid is None or (jid + 1) % n != 0:
            return False
        return not first_attempt_only or ctx.get("attempt", 1) == 1
    return FaultSpec(site=site, kind=kind, when=when, times=times)


def crash_every(n: int, *, site: str = "scheduler.job",
                first_attempt_only: bool = True,
                times: Optional[int] = None) -> FaultSpec:
    """Convenience: crash every ``n``-th *job* at ``site``: job
    ``n-1, 2n-1, ...`` fails its first attempt and succeeds on retry —
    the canonical crash-and-recover drill."""
    return _job_keyed(n, "crash", site, first_attempt_only, times)


def device_loss_every(n: int, *, site: str = "scheduler.job",
                      first_attempt_only: bool = True,
                      times: Optional[int] = None) -> FaultSpec:
    """Convenience: lose the device under every ``n``-th *job* — the
    quarantine-and-rebalance drill (:class:`DeviceLost` retires the
    device instantly; the job retries on a survivor)."""
    return _job_keyed(n, "device_loss", site, first_attempt_only, times)


def stall_every(n: int, hang_s: float, *, site: str = "serve.decode",
                times: Optional[int] = None) -> FaultSpec:
    """Convenience: stall every ``n``-th hit at ``site`` for ``hang_s``
    (virtual seconds on clock-owning components, real sleep elsewhere) —
    the straggler/heartbeat drill.  Counter-keyed: meant for
    single-threaded sites (``serve.decode``, ``serve.replica``) where hit
    order is deterministic."""
    return FaultSpec(site=site, kind="stall", every=n, hang_s=hang_s,
                     times=times)


def nan_candidate_every(n: int, *, times: Optional[int] = None) -> FaultSpec:
    """Convenience: poison every ``n``-th recorded training result with a
    non-finite loss (the per-candidate quarantine drill)."""
    return FaultSpec(site="trainer.result", kind="nonfinite", every=n,
                     times=times)
