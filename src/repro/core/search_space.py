"""HALF search space (paper §VI).

"The search space constitutes of depthwise separable convolutions with 60
different hyperparameter configurations and max pooling with 4 different
strides.  All DNNs end with a global average-pooling layer followed by a
fully-connected layer.  The depth of the topology is chosen by the NAS but
restricted between 2 and 15 layers (final layers not included)."

Hardware-awareness dimension 1 (§III-A): the space is constrained to layers
in the hardware library, including valid hyperparameter combinations and the
quantization of inputs, weights and feature maps.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Sequence, Tuple

from repro.hwlib.layers import DENSE, DWSEP_CONV, GLOBALPOOL, MAXPOOL, LayerSpec
from repro.hwlib.quant import QuantConfig

# 60 depthwise-separable conv configurations: 5 channel counts x 4 kernel
# sizes x 3 strides (Fig. 4's topologies use channels in {2..32}, kernels
# down to size 1 and striding layers).
CONV_CHANNELS: Tuple[int, ...] = (2, 4, 8, 16, 32)
CONV_KERNELS: Tuple[int, ...] = (1, 3, 5, 7)
CONV_STRIDES: Tuple[int, ...] = (1, 2, 4)

# 4 max-pooling strides (window == stride).
POOL_STRIDES: Tuple[int, ...] = (2, 4, 8, 16)

MIN_DEPTH = 2
MAX_DEPTH = 15

# Quantization choices searched by the NAS (inputs / weights / feature maps).
WEIGHT_BITS: Tuple[int, ...] = (4, 8)
ACT_BITS: Tuple[int, ...] = (8, 16)
INPUT_BITS: Tuple[int, ...] = (8, 16)

# Input decimation of the 60000-sample records (Fig. 4: inputs (1875,2) and
# (3750,2) — i.e. decimation 32 and 16 are both reachable by the search).
INPUT_DECIMATIONS: Tuple[int, ...] = (16, 32)

N_CLASSES = 2
RAW_LENGTH = 60000
N_CHANNELS = 2


def build_op_table() -> List[LayerSpec]:
    """The op catalogue indexed by the genome's function genes."""
    ops: List[LayerSpec] = []
    for c, k, s in itertools.product(CONV_CHANNELS, CONV_KERNELS, CONV_STRIDES):
        ops.append(LayerSpec(kind=DWSEP_CONV, out_channels=c, kernel_size=k,
                             stride=s))
    for s in POOL_STRIDES:
        ops.append(LayerSpec(kind=MAXPOOL, stride=s))
    return ops


OP_TABLE: List[LayerSpec] = build_op_table()
N_OPS = len(OP_TABLE)  # 64 = 60 convs + 4 pools
assert N_OPS == 64


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Bundles the op table with genome-level choices."""

    ops: Tuple[LayerSpec, ...] = tuple(OP_TABLE)
    max_depth: int = MAX_DEPTH
    min_depth: int = MIN_DEPTH
    weight_bits: Tuple[int, ...] = WEIGHT_BITS
    act_bits: Tuple[int, ...] = ACT_BITS
    input_bits: Tuple[int, ...] = INPUT_BITS
    input_decimations: Tuple[int, ...] = INPUT_DECIMATIONS
    n_classes: int = N_CLASSES

    @property
    def n_ops(self) -> int:
        return len(self.ops)

    def quant_config(self, w_idx: int, a_idx: int, i_idx: int) -> QuantConfig:
        return QuantConfig(weight_bits=self.weight_bits[w_idx],
                           act_bits=self.act_bits[a_idx],
                           input_bits=self.input_bits[i_idx])

    def input_length(self, dec_idx: int) -> int:
        return RAW_LENGTH // self.input_decimations[dec_idx]

    def head_specs(self) -> Tuple[LayerSpec, LayerSpec]:
        """The fixed GAP + dense head appended to every phenotype.

        Single source of truth for the head's content and order: the
        sentinel op ids ``n_ops + i`` used by the batched engine
        (PopulationEncoding.phenotype_ops, hw_model.table_for_space) index
        into this tuple.
        """
        return (LayerSpec(kind=GLOBALPOOL),
                LayerSpec(kind=DENSE, out_channels=self.n_classes))


DEFAULT_SPACE = SearchSpace()
