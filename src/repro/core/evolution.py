"""The hardware-aware evolutionary NAS loop (paper §III-A, §VI).

Per generation (paper: 100 generations x 20 children on 4 GPUs):

1. sample parents from the population, inverse-KDE-density weighted in
   cheap-objective space (LEMONADE-style exploration of the frontier);
2. produce children by forced-active mutation (+ occasional crossover);
   phenotype-hash dedup implements the dormant-gene shortcut — children whose
   expressed genes are unchanged are never retrained;
3. evaluate the children's cheap objectives analytically (Eqs. 1-4);
4. **two-step preselection**: only ``n_accept`` children, chosen
   inverse-density in cheap space, get expensive evaluation (training) —
   dispatched through the dynamic workload scheduler;
5. environmental selection (non-dominated sort + crowding) trims the merged
   population back to capacity.

The loop is array-resident (DESIGN.md §8): the population lives as a
struct-of-arrays :class:`~repro.core.objectives.PopulationArrays`, children
are produced by the vectorized genetic operators
(:func:`~repro.core.genome.mutate_batch` / ``crossover_batch``), and
:class:`~repro.core.objectives.Candidate` objects are materialized only for
the ``n_accept`` children handed to the trainer (and at the
checkpoint/report edges).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import selection as sel
from repro.core.cost_backend import BackendSpec, backend_schema, get_backend
from repro.core.genome import (
    Genome,
    PopulationEncoding,
    crossover_batch,
    mutate_batch,
    random_population,
)
from repro.core.hw_model import FPGA_ZU, HardwareProfile
from repro.core.objective_schema import (
    Constraints,
    DesignGoal,
    ObjectiveSchema,
    get_goal,
)
from repro.core.objectives import (
    Candidate,
    PopulationArrays,
    expensive_objectives,
)
from repro.core.pareto import (
    domination_matrices,
    domination_matrix,
    environmental_selection,
    pareto_front,
)
from repro.core.scheduler import DynamicScheduler, JobResult
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import TrainResult, train_candidate
from repro.core.trainer_batch import (
    bucket_by_signature,
    train_candidates_batched,
)


@dataclasses.dataclass
class NASConfig:
    generations: int = 100
    children_per_gen: int = 20
    n_accept: int = 8              # expensive-evaluation budget per generation
    population_cap: int = 64
    init_population: int = 16
    mutation_rate: float = 0.1
    crossover_prob: float = 0.25
    train_steps: int = 300
    train_batch: int = 64
    lr: float = 3e-3
    n_workers: int = 4
    seed: int = 0
    profile: HardwareProfile = FPGA_ZU
    backend: Optional[BackendSpec] = None  # cost backend; default = profile
    backends: Optional[Sequence[BackendSpec]] = None  # multi-platform: one
    #   population scored against K platforms (MultiPlatformBackend)
    goal: Union[str, DesignGoal] = "balanced"  # deployment design goal —
    #   selects/weights schema columns for selection + the final report
    det_min: float = 0.90          # paper's hard acceptance limits
    fa_max: float = 0.20
    batch_training: bool = True    # bucketed vmap-stacked training (§9)

    @property
    def constraints(self) -> Constraints:
        return Constraints(self.det_min, self.fa_max)


@dataclasses.dataclass
class NASState:
    pop: PopulationArrays
    generation: int
    evaluated_hashes: Dict[str, np.ndarray]  # phenotype hash -> expensive objs
    history: List[dict]

    @property
    def population(self) -> List[Candidate]:
        """Materialized object view of the population (reports, tests).

        The resident representation is the struct-of-arrays ``pop``; this
        property builds fresh :class:`Candidate` objects on every access —
        mutating them does not write back.
        """
        return self.pop.to_candidates()


class EvolutionarySearch:
    """Reusable search driver; inject a trainer for tests."""

    def __init__(self, config: NASConfig,
                 data_train, data_val,
                 space: SearchSpace = DEFAULT_SPACE,
                 train_fn: Optional[Callable[[Genome], TrainResult]] = None,
                 batch_train_fn: Optional[
                     Callable[[List[Genome]], List[TrainResult]]] = None,
                 log: Callable[[str], None] = print):
        self.cfg = config
        self.space = space
        self.rng = np.random.default_rng(config.seed)
        if config.backends is not None:
            if config.backend is not None:
                raise ValueError("NASConfig.backend and NASConfig.backends "
                                 "are mutually exclusive")
            self.backend = get_backend(list(config.backends))
        else:
            self.backend = get_backend(config.backend if config.backend
                                       is not None else config.profile)
        # the objective layer is schema-described (DESIGN.md §10): cheap
        # columns from the backend, + the expensive pair for selection
        self.schema: ObjectiveSchema = backend_schema(self.backend)
        self.full_schema: ObjectiveSchema = self.schema.with_expensive()
        self.goal: DesignGoal = get_goal(config.goal)
        self.constraints: Constraints = self.goal.effective_constraints(
            config.constraints)
        # goal-conditioned column views; None = all columns (the balanced
        # default — bit-identical to the pre-schema engine)
        sel_cols = self.goal.selection_indices(self.full_schema)
        self._goal_cols = None if len(sel_cols) == len(self.full_schema) \
            else sel_cols
        kde_cols = sel_cols[sel_cols < len(self.schema)]  # cheap part only
        self._kde_cols = None if len(kde_cols) == len(self.schema) \
            else kde_cols
        self.log = log
        self._train_fn = train_fn or (lambda g: train_candidate(
            g, data_train, data_val, space=self.space,
            steps=config.train_steps, batch_size=config.train_batch,
            lr=config.lr, seed=config.seed))
        # bucketed vmap-stacked training (DESIGN.md §9): the default unless
        # a scalar train_fn is injected (tests) or the config opts out.
        if batch_train_fn is not None:
            self._batch_train_fn = batch_train_fn
        elif train_fn is None and config.batch_training:
            stage_cache: Dict[int, tuple] = {}  # device dataset, per search
            self._batch_train_fn = lambda gs: train_candidates_batched(
                gs, data_train, data_val, space=self.space,
                steps=config.train_steps, batch_size=config.train_batch,
                lr=config.lr, seed=config.seed, stage_cache=stage_cache)
        else:
            self._batch_train_fn = None
        self.scheduler = DynamicScheduler(n_workers=config.n_workers,
                                          max_retries=2, timeout_s=1800.0)

    # ------------------------------------------------------------- lifecycle
    def _sample_unique(self, n: int
                       ) -> Tuple[PopulationEncoding, List[str]]:
        """``n`` random valid genomes with pairwise-distinct phenotypes."""
        parts: List[PopulationEncoding] = []
        hashes: List[str] = []
        seen = set()
        while len(hashes) < n:
            enc = random_population(self.rng, n - len(hashes), self.space)
            keep = []
            for i, h in enumerate(enc.batch_phenotype_hash(self.space)):
                if h in seen:
                    continue
                seen.add(h)
                keep.append(i)
                hashes.append(h)
            if keep:
                parts.append(enc.take(keep))
        return PopulationEncoding.concatenate(parts), hashes

    def _score(self, enc: PopulationEncoding, hashes: Sequence[str],
               generation: int) -> PopulationArrays:
        """One batched cheap-objective pass — the only cheap evaluation in a
        generation step (the matrix is cached on the PopulationArrays)."""
        return PopulationArrays(
            enc=enc,
            cheap=self.backend.evaluate_batch(enc, space=self.space),
            expensive=np.full((len(enc), 2), np.nan),
            phash=np.asarray(hashes, dtype=object),
            born=np.full(len(enc), generation, dtype=np.int64),
            schema=self.schema)

    def init_state(self) -> NASState:
        enc, hashes = self._sample_unique(self.cfg.init_population)
        state = NASState(pop=self._score(enc, hashes, generation=0),
                         generation=0, evaluated_hashes={}, history=[])
        self._train_members(state, state.pop, np.arange(len(state.pop)))
        return state

    # ---------------------------------------------------------------- steps
    def _make_children(self, state: NASState
                       ) -> Optional[PopulationArrays]:
        pop = state.pop
        parents_idx = sel.sample_parents(self.rng, pop.cheap,
                                         self.cfg.children_per_gen,
                                         cols=self._kde_cols)
        parents = pop.enc.take(parents_idx)
        if len(pop) > 1:
            xo = self.rng.random(len(parents_idx)) < self.cfg.crossover_prob
        else:
            xo = np.zeros(len(parents_idx), dtype=bool)
        parts: List[PopulationEncoding] = []
        if xo.any():
            mates = pop.enc.take(
                self.rng.integers(0, len(pop), int(xo.sum())))
            crossed = crossover_batch(parents.take(np.nonzero(xo)[0]), mates,
                                      self.rng, self.space)
            parts.append(mutate_batch(crossed, self.rng, self.space,
                                      rate=self.cfg.mutation_rate,
                                      force_active_change=False))
        if not xo.all():
            parts.append(mutate_batch(parents.take(np.nonzero(~xo)[0]),
                                      self.rng, self.space,
                                      rate=self.cfg.mutation_rate,
                                      force_active_change=True))
        children = PopulationEncoding.concatenate(parts)
        # dormant-gene shortcut: drop children whose expressed genes match a
        # population member or an earlier sibling
        hashes = children.batch_phenotype_hash(self.space)
        seen = set(pop.phash)
        keep: List[int] = []
        kept_hashes: List[str] = []
        for i, h in enumerate(hashes):
            if h in seen:
                continue
            seen.add(h)
            keep.append(i)
            kept_hashes.append(h)
        if not keep:
            return None
        return self._score(children.take(keep), kept_hashes,
                           generation=state.generation + 1)

    def _run_scheduled(self, jobs) -> List[JobResult]:
        """scheduler.run with per-job alignment: the scheduler may return
        partial results (every worker died), so match by job_id and mark
        the gaps failed instead of mispairing zip order."""
        by_id = {r.job_id: r for r in self.scheduler.run(jobs)}
        return [by_id.get(i, JobResult(job_id=i, ok=False,
                                       error="no result (workers died)"))
                for i in range(len(jobs))]

    def _run_training_jobs(self, genomes: List[Genome]) -> List[JobResult]:
        """Dispatch training through the scheduler, one job per signature
        bucket when batched training is on (retry/speculation then operate
        on buckets — a failed bucket re-dispatches whole), else one job per
        candidate.  Returns per-candidate results in input order."""
        if self._batch_train_fn is None:
            return self._run_scheduled(
                [(lambda g=g: self._train_fn(g)) for g in genomes])
        buckets = list(bucket_by_signature(genomes, self.space).values())
        bucket_results = self._run_scheduled(
            [(lambda rows=rows: self._batch_train_fn(
                [genomes[j] for j in rows])) for rows in buckets])
        out: List[Optional[JobResult]] = [None] * len(genomes)
        for rows, br in zip(buckets, bucket_results):
            ok = bool(br.ok and br.value is not None
                      and len(br.value) == len(rows))
            error = br.error if not br.ok else (
                "" if ok else "batch trainer returned misaligned results")
            for k, j in enumerate(rows):
                out[j] = JobResult(
                    job_id=j, ok=ok,
                    value=br.value[k] if ok else None,
                    error=error, attempts=br.attempts,
                    elapsed_s=br.elapsed_s, worker=br.worker)
        return out  # type: ignore[return-value]

    def _train_members(self, state: NASState, pop: PopulationArrays,
                       idx: np.ndarray) -> None:
        """Expensive-evaluate rows ``idx`` of ``pop`` (cache-first), writing
        results into ``pop.expensive`` and the dormant-gene cache.  Genome
        objects are materialized here only, for the training jobs."""
        todo: List[int] = []
        for i in idx:
            cached = state.evaluated_hashes.get(str(pop.phash[i]))
            if cached is not None:  # cache hit (dormant genes)
                pop.expensive[i] = cached
            else:
                todo.append(int(i))
        if not todo:
            return
        genomes = [pop.enc.genome(i) for i in todo]
        results = self._run_training_jobs(genomes)
        for i, r in zip(todo, results):
            if r.ok:
                exp = expensive_objectives(r.value)
            else:  # failed after retries: pessimistic objectives, stay in pool
                self.log(f"[nas] candidate {pop.phash[i]} failed: "
                         f"{r.error.splitlines()[-1] if r.error else '?'}")
                exp = np.asarray([1.0, 1.0])
            pop.expensive[i] = exp
            state.evaluated_hashes[str(pop.phash[i])] = exp

    def step(self, state: NASState) -> NASState:
        t0 = time.monotonic()
        children = self._make_children(state)
        if children is not None:
            acc_idx = sel.preselect_children(self.rng, state.pop.cheap,
                                             children.cheap,
                                             self.cfg.n_accept,
                                             cols=self._kde_cols)
            accepted = children.take(acc_idx)
            self._train_members(state, accepted,
                                np.arange(len(accepted)))
            merged = PopulationArrays.concat([state.pop, accepted])
            n_children, n_trained = len(children), len(accepted)
        else:
            merged = state.pop
            n_children = n_trained = 0

        # goal-conditioned objective view (all columns for the balanced
        # default — bit-identical to the pre-schema engine); one domination
        # matrix serves both the environmental selection and the kept
        # population's front-size report
        objs = merged.objective_matrix()
        if self._goal_cols is not None:
            objs = objs[:, self._goal_cols]
        dom = domination_matrix(objs)
        keep = environmental_selection(objs, self.cfg.population_cap, dom=dom)
        new_pop = merged.take(keep)

        state.generation += 1
        front = pareto_front(objs[keep], dom=dom[np.ix_(keep, keep)])
        feasible = new_pop.feasible_mask(self.constraints)
        primary = self.goal.primary_indices(self.schema)
        rec = {
            "generation": state.generation,
            "children": n_children,
            "trained": n_trained,
            "population": len(new_pop),
            "front_size": int(len(front)),
            "feasible": int(feasible.sum()),
            # worst-across-platforms primary objective of the best feasible
            # member (single platform: just its primary objective)
            "best_primary": float(
                new_pop.cheap[np.ix_(feasible, primary)].max(axis=1).min())
            if feasible.any() else float("nan"),
            "elapsed_s": time.monotonic() - t0,
        }
        state.history.append(rec)
        state.pop = new_pop
        self.log(f"[nas] gen {rec['generation']:3d} "
                 f"pop={rec['population']} front={rec['front_size']} "
                 f"feasible={rec['feasible']} "
                 f"best[{self.goal.primary}]={rec['best_primary']:.3e} "
                 f"({rec['elapsed_s']:.1f}s)")
        return state

    def run(self, generations: Optional[int] = None) -> NASState:
        state = self.init_state()
        for _ in range(generations or self.cfg.generations):
            state = self.step(state)
        return state

    # ------------------------------------------------------- checkpointing
    # The paper's search runs two days on a GPU farm; a preempted search
    # must resume mid-generation.  State is plain JSON (genomes are small
    # int tuples) written atomically.  The driver's RNG state rides along so
    # a resumed search is bit-identical to an uninterrupted one.
    def save_state(self, state: NASState, path: str) -> None:
        import json as _json
        import os as _os
        pop = state.pop
        trained = pop.trained_mask
        payload = {
            "generation": state.generation,
            "history": state.history,
            "schema": self.schema.to_json(),
            "evaluated": {k: v.tolist()
                          for k, v in state.evaluated_hashes.items()},
            "rng_state": self.rng.bit_generator.state,
            "population": [{
                "genome": dataclasses.asdict(pop.enc.genome(i)),
                "cheap": pop.cheap[i].tolist(),
                "expensive": pop.expensive[i].tolist()
                if trained[i] else None,
                "phash": str(pop.phash[i]),
                "generation": int(pop.born[i]),
            } for i in range(len(pop))],
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            _json.dump(payload, f)
        _os.replace(tmp, path)

    def load_state(self, path: str) -> NASState:
        """Restore a checkpoint.  Also restores this driver's RNG state (when
        present — older checkpoints load fine without it), so resuming
        reproduces the uninterrupted run bit-for-bit.

        The persisted objective schema is validated against this driver's
        backend: resuming a checkpoint under a different platform set would
        silently misread the cheap matrix, so a mismatch raises.  Pre-schema
        checkpoints are accepted when the column count matches."""
        import json as _json
        with open(path) as f:
            payload = _json.load(f)
        if "schema" in payload:
            saved = ObjectiveSchema.from_json(payload["schema"])
            if saved != self.schema:
                raise ValueError(
                    f"checkpoint objective schema "
                    f"{list(saved.qualified_names)} does not match this "
                    f"search's backend schema "
                    f"{list(self.schema.qualified_names)} — resume with the "
                    f"same backends/goal configuration")
        members = payload["population"]
        if members and len(members[0]["cheap"]) != len(self.schema):
            raise ValueError(
                f"checkpoint cheap matrix has {len(members[0]['cheap'])} "
                f"columns; this search's schema has {len(self.schema)}")
        genomes = [Genome(
            op_genes=tuple(m["genome"]["op_genes"]),
            conn_genes=tuple(m["genome"]["conn_genes"]),
            out_gene=m["genome"]["out_gene"],
            w_bits_gene=m["genome"]["w_bits_gene"],
            a_bits_gene=m["genome"]["a_bits_gene"],
            i_bits_gene=m["genome"]["i_bits_gene"],
            dec_gene=m["genome"]["dec_gene"]) for m in members]
        expensive = np.full((len(members), 2), np.nan)
        for i, m in enumerate(members):
            if m["expensive"] is not None:
                expensive[i] = m["expensive"]
        pop = PopulationArrays(
            enc=PopulationEncoding.from_genomes(genomes),
            cheap=np.asarray([m["cheap"] for m in members], np.float64),
            expensive=expensive,
            phash=np.asarray([m["phash"] for m in members], dtype=object),
            born=np.asarray([m["generation"] for m in members], np.int64),
            schema=self.schema)
        if "rng_state" in payload:
            self.rng.bit_generator.state = payload["rng_state"]
        return NASState(
            pop=pop, generation=payload["generation"],
            evaluated_hashes={k: np.asarray(v)
                              for k, v in payload["evaluated"].items()},
            history=payload["history"])

    def run_resumable(self, ckpt_path: str,
                      generations: Optional[int] = None) -> NASState:
        """Resume from `ckpt_path` if present; checkpoint every generation."""
        import os as _os
        if _os.path.exists(ckpt_path):
            state = self.load_state(ckpt_path)
            self.log(f"[nas] resumed at generation {state.generation}")
        else:
            state = self.init_state()
        target = generations or self.cfg.generations
        while state.generation < target:
            state = self.step(state)
            self.save_state(state, ckpt_path)
        return state

    # ---------------------------------------------------------------- report
    def select_solution(self, state: NASState,
                        objective: str = "energy_max_alpha_j",
                        platform: Optional[str] = None
                        ) -> Optional[Candidate]:
        """Best feasible candidate for a deployment objective (paper §VI-B).

        ``objective`` is a schema query, not a position: pass a bare name
        (single-platform searches), a qualified ``platform:name``, or a bare
        name plus ``platform`` to disambiguate a multi-platform schema.
        """
        idx = self.schema.index(objective, platform=platform)
        feas = state.pop.feasible_mask(self.constraints)
        if not feas.any():
            return None
        rows = np.nonzero(feas)[0]
        return state.pop.candidate(
            int(rows[np.argmin(state.pop.cheap[rows, idx])]))

    def select_for_goal(self, state: NASState,
                        goal: Union[None, str, DesignGoal] = None
                        ) -> Optional[Candidate]:
        """Best feasible candidate under a design goal (default: the
        search's own).  With several platforms in the goal's scope the
        ranking value is the *worst* (max) primary objective across them —
        the robust cross-platform pick."""
        g = self.goal if goal is None else get_goal(goal)
        cols = g.primary_indices(self.schema)
        feas = state.pop.feasible_mask(
            g.effective_constraints(self.cfg.constraints))
        if not feas.any():
            return None
        rows = np.nonzero(feas)[0]
        score = state.pop.cheap[np.ix_(rows, cols)].max(axis=1)
        return state.pop.candidate(int(rows[np.argmin(score)]))

    def pareto_fronts(self, state: NASState) -> Dict[str, np.ndarray]:
        """Per-platform and cross-platform Pareto fronts of the population.

        Returns ``{"cross_platform": idx, <platform>: idx, ...}`` — front
        membership over the full objective matrix and over each platform's
        column group (its cheap columns + the expensive pair).  All fronts
        come from one shared pass over the per-column comparisons
        (:func:`~repro.core.pareto.domination_matrices`).
        """
        objs = state.pop.objective_matrix()
        n_cols = len(self.full_schema)
        # single-platform schemas: every platform group equals the full
        # column set — alias the cross-platform front instead of building
        # identical (N, N) matrices
        groups = {"cross_platform": np.arange(n_cols)}
        for p in self.schema.platforms:
            cols = self.full_schema.platform_group(p)
            if len(cols) < n_cols:
                groups[p] = cols
        doms = domination_matrices(objs, list(groups.values()))
        fronts = {name: np.nonzero(dom.sum(axis=0) == 0)[0]
                  for name, dom in zip(groups, doms)}
        for p in self.schema.platforms:
            fronts.setdefault(p, fronts["cross_platform"])
        return fronts
