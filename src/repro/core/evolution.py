"""The hardware-aware evolutionary NAS loop (paper §III-A, §VI).

Per generation (paper: 100 generations x 20 children on 4 GPUs):

1. sample parents from the population, inverse-KDE-density weighted in
   cheap-objective space (LEMONADE-style exploration of the frontier);
2. produce children by forced-active mutation (+ occasional crossover);
   phenotype-hash dedup implements the dormant-gene shortcut — children whose
   expressed genes are unchanged are never retrained;
3. evaluate the children's cheap objectives analytically (Eqs. 1-4);
4. **two-step preselection**: only ``n_accept`` children, chosen
   inverse-density in cheap space, get expensive evaluation (training) —
   dispatched through the dynamic workload scheduler;
5. environmental selection (non-dominated sort + crowding) trims the merged
   population back to capacity.

The loop is array-resident (DESIGN.md §8): the population lives as a
struct-of-arrays :class:`~repro.core.objectives.PopulationArrays`, children
are produced by the vectorized genetic operators
(:func:`~repro.core.genome.mutate_batch` / ``crossover_batch``), and
:class:`~repro.core.objectives.Candidate` objects are materialized only for
the ``n_accept`` children handed to the trainer (and at the
checkpoint/report edges).

Orchestration (DESIGN.md §11): training dispatches through a device-affine
:class:`~repro.core.scheduler.DynamicScheduler` — one worker group per
visible accelerator, so different signature buckets of a generation train
concurrently on different devices — and ``NASConfig.pipeline`` selects how
much of the loop overlaps with the devices:

* ``"off"`` — the fully synchronous loop (dispatch, block, select).
* ``"host_overlap"`` — training is submitted asynchronously and the host
  folds the merged population's *cheap* domination columns
  (:class:`~repro.core.pareto.PartialDomination`) while the devices train,
  finishing with the expensive columns when results land.  No extra RNG
  draws and a bit-identical domination matrix: the trajectory equals the
  synchronous loop's exactly.
* ``"async"`` — steady-state pipelining: generation N+1's children are
  mutated/cheap-scored/dispatched while generation N still trains (bounded
  by ``NASConfig.lookahead``), and trained results are admitted into the
  dormant-gene cache as each bucket lands (the scheduler's ``on_result``
  hook).  Relaxed semantics — selection folds a generation in only when it
  drains, so parents lag the newest results; the trajectory differs from
  the synchronous loop and the mode is opt-in.
"""
from __future__ import annotations

import dataclasses
import inspect
import threading
import time
from collections import deque
from typing import (
    Any,
    Callable,
    Deque,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import numpy as np

from repro.core import selection as sel
from repro.core.cost_backend import BackendSpec, backend_schema, get_backend
from repro.core.faults import FaultPlan
from repro.core.genome import (
    Genome,
    PopulationEncoding,
    crossover_batch,
    mutate_batch,
    random_population,
)
from repro.core.hw_model import FPGA_ZU, HardwareProfile
from repro.core.objective_schema import (
    Constraints,
    DesignGoal,
    ObjectiveSchema,
    get_goal,
    pessimistic_expensive,
)
from repro.core.objectives import (
    Candidate,
    PopulationArrays,
    expensive_objectives,
)
from repro.core.pareto import (
    PartialDomination,
    domination_matrices,
    domination_matrix,
    environmental_selection,
    pareto_front,
)
from repro.core.scheduler import DynamicScheduler, JobResult, SchedulerRun
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import TrainResult, train_candidate
from repro.core.trainer_batch import (
    bucket_by_signature,
    train_candidates_batched,
)

PIPELINE_MODES = ("off", "host_overlap", "async")

# max/min per-device busy ratio above which a generation's training jobs
# are considered skewed enough to flag (device-affine bucket sharding can
# pin all the big signature buckets to one device — DESIGN.md §11)
DEVICE_IMBALANCE_RATIO = 2.0


def device_imbalance(device_busy: Dict[str, float],
                     *, min_busy_s: float = 1e-3) -> Optional[float]:
    """Max/min busy-time ratio across devices for one generation, or
    ``None`` when imbalance is meaningless (fewer than 2 devices, or the
    generation did next to no device work).  A device that stayed (almost)
    idle while others trained reports ``inf`` — the worst skew."""
    if len(device_busy) < 2:
        return None
    busy = sorted(device_busy.values())
    if busy[-1] < min_busy_s:
        return None
    if busy[0] < min_busy_s:
        return float("inf")
    return busy[-1] / busy[0]


@dataclasses.dataclass
class NASConfig:
    generations: int = 100
    children_per_gen: int = 20
    n_accept: int = 8              # expensive-evaluation budget per generation
    population_cap: int = 64
    init_population: int = 16
    mutation_rate: float = 0.1
    crossover_prob: float = 0.25
    train_steps: int = 300
    train_batch: int = 64
    lr: float = 3e-3
    n_workers: int = 4
    seed: int = 0
    profile: HardwareProfile = FPGA_ZU
    backend: Optional[BackendSpec] = None  # cost backend; default = profile
    backends: Optional[Sequence[BackendSpec]] = None  # multi-platform: one
    #   population scored against K platforms (MultiPlatformBackend)
    goal: Union[str, DesignGoal] = "balanced"  # deployment design goal —
    #   selects/weights schema columns for selection + the final report
    det_min: float = 0.90          # paper's hard acceptance limits
    fa_max: float = 0.20
    batch_training: bool = True    # bucketed vmap-stacked training (§9)
    pipeline: str = "off"          # "off" | "host_overlap" | "async" (§11)
    device_affinity: Optional[bool] = None  # shard signature buckets across
    #   jax.local_devices(); None = auto (on for batched training when >1
    #   device is visible), False = force single-device dispatch
    lookahead: int = 1             # async mode: generations produced ahead
    #   of the oldest still-training one (max lookahead+1 in flight)
    ckpt_every: Optional[int] = None  # run_resumable: generations between
    #   checkpoints.  None = 1 for the deterministic pipelines, and
    #   lookahead+1 for async (each checkpoint is a drain barrier: stop
    #   admitting lookahead work, drain in flight, persist — DESIGN.md §13)

    @property
    def constraints(self) -> Constraints:
        return Constraints(self.det_min, self.fa_max)


@dataclasses.dataclass
class NASState:
    pop: PopulationArrays
    generation: int
    evaluated_hashes: Dict[str, np.ndarray]  # phenotype hash -> expensive objs
    history: List[dict]

    @property
    def population(self) -> List[Candidate]:
        """Materialized object view of the population (reports, tests).

        The resident representation is the struct-of-arrays ``pop``; this
        property builds fresh :class:`Candidate` objects on every access —
        mutating them does not write back.
        """
        return self.pop.to_candidates()


@dataclasses.dataclass
class _TrainPlan:
    """Rows of a population slated for training (cache misses only)."""
    todo: List[int]
    genomes: List[Genome]


@dataclasses.dataclass
class _TrainSubmission:
    """An in-flight training dispatch: the scheduler run plus the job →
    candidate alignment needed to scatter results back."""
    run: SchedulerRun
    n_jobs: int
    buckets: Optional[List[List[int]]]   # None = one job per candidate
    n_genomes: int


class EvolutionarySearch:
    """Reusable search driver; inject a trainer for tests."""

    def __init__(self, config: NASConfig,
                 data_train, data_val,
                 space: SearchSpace = DEFAULT_SPACE,
                 train_fn: Optional[Callable[[Genome], TrainResult]] = None,
                 batch_train_fn: Optional[
                     Callable[[List[Genome]], List[TrainResult]]] = None,
                 log: Callable[[str], None] = print,
                 faults: Optional[FaultPlan] = None):
        self.cfg = config
        # fault injection (DESIGN.md §13): an explicit, seeded plan wired
        # through the scheduler / training-result / checkpoint / generation
        # inject points; None (production) leaves every hook inert
        self.faults = faults
        if config.pipeline not in PIPELINE_MODES:
            raise ValueError(f"unknown pipeline mode {config.pipeline!r} "
                             f"(modes: {PIPELINE_MODES})")
        self.space = space
        self.rng = np.random.default_rng(config.seed)
        if config.backends is not None:
            if config.backend is not None:
                raise ValueError("NASConfig.backend and NASConfig.backends "
                                 "are mutually exclusive")
            self.backend = get_backend(list(config.backends))
        else:
            self.backend = get_backend(config.backend if config.backend
                                       is not None else config.profile)
        # the objective layer is schema-described (DESIGN.md §10): cheap
        # columns from the backend, + the expensive pair for selection
        self.schema: ObjectiveSchema = backend_schema(self.backend)
        self.full_schema: ObjectiveSchema = self.schema.with_expensive()
        # the pessimistic placeholder row (failed/unevaluated candidates) is
        # schema-derived: width and worst-case values follow the expensive
        # columns instead of a hard-coded 2-vector
        self._exp_worst: np.ndarray = pessimistic_expensive(self.full_schema)
        self.goal: DesignGoal = get_goal(config.goal)
        self.constraints: Constraints = self.goal.effective_constraints(
            config.constraints)
        # goal-conditioned column views; None = all columns (the balanced
        # default — bit-identical to the pre-schema engine)
        sel_cols = self.goal.selection_indices(self.full_schema)
        self._goal_cols = None if len(sel_cols) == len(self.full_schema) \
            else sel_cols
        # the cheap part of the selection view — the host-overlap pipeline
        # folds these domination columns while the devices train
        self._sel_cheap_cols = sel_cols[sel_cols < len(self.schema)]
        kde_cols = self._sel_cheap_cols
        self._kde_cols = None if len(kde_cols) == len(self.schema) \
            else kde_cols
        self.log = log
        self._train_fn = train_fn or (lambda g: train_candidate(
            g, data_train, data_val, space=self.space,
            steps=config.train_steps, batch_size=config.train_batch,
            lr=config.lr, seed=config.seed))
        # bucketed vmap-stacked training (DESIGN.md §9): the default unless
        # a scalar train_fn is injected (tests) or the config opts out.
        if batch_train_fn is not None:
            self._batch_train_fn = batch_train_fn
        elif train_fn is None and config.batch_training:
            stage_cache: Dict[tuple, tuple] = {}  # device dataset, per search
            self._batch_train_fn = lambda gs, device=None: \
                train_candidates_batched(
                    gs, data_train, data_val, space=self.space,
                    steps=config.train_steps, batch_size=config.train_batch,
                    lr=config.lr, seed=config.seed, stage_cache=stage_cache,
                    device=device)
        else:
            self._batch_train_fn = None
        self._batch_fn_takes_device = self._fn_takes_device(
            self._batch_train_fn)
        # device-affine scheduling (DESIGN.md §11): one worker group per
        # visible accelerator so signature buckets train concurrently on
        # different devices.  Auto mode stays off for scalar trainers (they
        # cannot place their data) and on single-device hosts — both fall
        # back to the plain thread pool.
        self.devices: Optional[List[Any]] = None
        affinity = config.device_affinity
        if affinity is None:
            affinity = self._batch_train_fn is not None
        if affinity:
            from repro.launch.mesh import local_search_devices
            devs = local_search_devices()
            if len(devs) > 1:
                self.devices = devs
        n_workers = config.n_workers if self.devices is None \
            else max(config.n_workers, len(self.devices))
        self.scheduler = DynamicScheduler(n_workers=n_workers,
                                          max_retries=2, timeout_s=1800.0,
                                          devices=self.devices,
                                          faults=faults,
                                          seed=config.seed)
        # guards evaluated_hashes: the async pipeline's on_result hook
        # admits results from scheduler worker threads
        self._cache_lock = threading.Lock()

    @staticmethod
    def _poison_result(value):
        """Injected-divergence payload: the result's loss goes non-finite
        (the quarantine path then treats it exactly like a real NaN)."""
        try:
            return dataclasses.replace(value, val_loss=float("nan"))
        except TypeError:
            return value

    @staticmethod
    def _fn_takes_device(fn) -> bool:
        if fn is None:
            return False
        try:
            params = inspect.signature(fn).parameters.values()
        except (TypeError, ValueError):
            return False
        return any(p.name == "device" or p.kind == p.VAR_KEYWORD
                   for p in params)

    # ------------------------------------------------------------- lifecycle
    def _sample_unique(self, n: int
                       ) -> Tuple[PopulationEncoding, List[str]]:
        """``n`` random valid genomes with pairwise-distinct phenotypes."""
        parts: List[PopulationEncoding] = []
        hashes: List[str] = []
        seen = set()
        while len(hashes) < n:
            enc = random_population(self.rng, n - len(hashes), self.space)
            keep = []
            for i, h in enumerate(enc.batch_phenotype_hash(self.space)):
                if h in seen:
                    continue
                seen.add(h)
                keep.append(i)
                hashes.append(h)
            if keep:
                parts.append(enc.take(keep))
        return PopulationEncoding.concatenate(parts), hashes

    def _score(self, enc: PopulationEncoding, hashes: Sequence[str],
               generation: int) -> PopulationArrays:
        """One batched cheap-objective pass — the only cheap evaluation in a
        generation step (the matrix is cached on the PopulationArrays)."""
        return PopulationArrays(
            enc=enc,
            cheap=self.backend.evaluate_batch(enc, space=self.space),
            expensive=np.full((len(enc), len(self._exp_worst)), np.nan),
            phash=np.asarray(hashes, dtype=object),
            born=np.full(len(enc), generation, dtype=np.int64),
            schema=self.schema)

    def init_state(self) -> NASState:
        enc, hashes = self._sample_unique(self.cfg.init_population)
        state = NASState(pop=self._score(enc, hashes, generation=0),
                         generation=0, evaluated_hashes={}, history=[])
        self._train_members(state, state.pop, np.arange(len(state.pop)))
        return state

    # ---------------------------------------------------------------- steps
    def _spawn_children(self, state: NASState,
                        extra_seen: Optional[set] = None
                        ) -> Optional[Tuple[PopulationEncoding, List[str]]]:
        """Mutation/crossover + dormant-gene dedup; returns the child gene
        arrays and phenotype hashes (``None`` if every child was a known
        phenotype).  ``extra_seen`` adds hashes to dedup against — the
        async pipeline's still-training generations."""
        pop = state.pop
        parents_idx = sel.sample_parents(self.rng, pop.cheap,
                                         self.cfg.children_per_gen,
                                         cols=self._kde_cols)
        parents = pop.enc.take(parents_idx)
        if len(pop) > 1:
            xo = self.rng.random(len(parents_idx)) < self.cfg.crossover_prob
        else:
            xo = np.zeros(len(parents_idx), dtype=bool)
        parts: List[PopulationEncoding] = []
        if xo.any():
            mates = pop.enc.take(
                self.rng.integers(0, len(pop), int(xo.sum())))
            crossed = crossover_batch(parents.take(np.nonzero(xo)[0]), mates,
                                      self.rng, self.space)
            parts.append(mutate_batch(crossed, self.rng, self.space,
                                      rate=self.cfg.mutation_rate,
                                      force_active_change=False))
        if not xo.all():
            parts.append(mutate_batch(parents.take(np.nonzero(~xo)[0]),
                                      self.rng, self.space,
                                      rate=self.cfg.mutation_rate,
                                      force_active_change=True))
        children = PopulationEncoding.concatenate(parts)
        # dormant-gene shortcut: drop children whose expressed genes match a
        # population member or an earlier sibling
        hashes = children.batch_phenotype_hash(self.space)
        seen = set(pop.phash)
        if extra_seen:
            seen |= extra_seen
        keep: List[int] = []
        kept_hashes: List[str] = []
        for i, h in enumerate(hashes):
            if h in seen:
                continue
            seen.add(h)
            keep.append(i)
            kept_hashes.append(h)
        if not keep:
            return None
        return children.take(keep), kept_hashes

    def _make_children(self, state: NASState
                       ) -> Optional[PopulationArrays]:
        spawned = self._spawn_children(state)
        if spawned is None:
            return None
        return self._score(spawned[0], spawned[1],
                           generation=state.generation + 1)

    # ------------------------------------------------- training dispatch
    def _call_batch_train(self, genomes: List[Genome], device):
        """Invoke the batch trainer, forwarding the worker's device when
        the trainer can place data on it (injected test doubles often
        can't — they simply ignore affinity)."""
        if device is not None and self._batch_fn_takes_device:
            return self._batch_train_fn(genomes, device=device)
        return self._batch_train_fn(genomes)

    def _plan_training(self, state: NASState, pop: PopulationArrays,
                       idx: np.ndarray) -> Optional[_TrainPlan]:
        """Resolve dormant-gene cache hits for rows ``idx`` of ``pop``
        (writing their expensive objectives immediately); the returned plan
        lists the rows that genuinely need training (``None`` if none)."""
        todo: List[int] = []
        with self._cache_lock:
            for i in idx:
                cached = state.evaluated_hashes.get(str(pop.phash[i]))
                if cached is not None:  # cache hit (dormant genes)
                    pop.expensive[i] = cached
                else:
                    todo.append(int(i))
        if not todo:
            return None
        return _TrainPlan(todo=todo,
                          genomes=[pop.enc.genome(i) for i in todo])

    def _submit_training(self, genomes: List[Genome],
                         phashes: Optional[List[str]] = None,
                         admit: Optional[Callable[[str, np.ndarray], None]]
                         = None) -> _TrainSubmission:
        """Dispatch training through the scheduler without blocking: one
        job per signature bucket when batched training is on (retry/
        speculation then operate on buckets — a failed bucket re-dispatches
        whole), else one job per candidate.  ``admit`` (with ``phashes``)
        is called per successful candidate as each bucket lands — the async
        pipeline's early-admission hook."""
        if self._batch_train_fn is None:
            buckets = None
            jobs = [(lambda device=None, g=g: self._train_fn(g))
                    for g in genomes]
        else:
            buckets = list(bucket_by_signature(genomes, self.space).values())
            jobs = [(lambda device=None, rows=rows: self._call_batch_train(
                [genomes[j] for j in rows], device)) for rows in buckets]
        on_result = None
        if admit is not None and phashes is not None:
            def on_result(r: JobResult) -> None:
                # runs under the scheduler lock in a worker thread — only
                # successful, well-formed results are admitted early; the
                # blocking collect handles failures/pessimism
                if not r.ok or r.value is None:
                    return
                rows = buckets[r.job_id] if buckets is not None \
                    else [r.job_id]
                vals = r.value if buckets is not None else [r.value]
                try:
                    if len(vals) != len(rows):
                        return
                except TypeError:
                    return
                for k, j in enumerate(rows):
                    exp = expensive_objectives(vals[k])
                    vl = getattr(vals[k], "val_loss", 0.0)
                    # never admit a diverged (non-finite) result early: the
                    # blocking collect quarantines it with the pessimistic
                    # row, and a poisoned cache entry would leak into later
                    # generations' dormant-gene lookups
                    if np.all(np.isfinite(exp)) and np.isfinite(vl):
                        admit(phashes[j], exp)
        # bucket sizes turn on the scheduler's largest-first dispatch, so
        # device busy times stay level (the device_busy_s rebalancing
        # signal, DESIGN.md §11/§13)
        sizes = [len(rows) for rows in buckets] \
            if buckets is not None else None
        return _TrainSubmission(run=self.scheduler.submit(jobs, on_result,
                                                          sizes=sizes),
                                n_jobs=len(jobs), buckets=buckets,
                                n_genomes=len(genomes))

    def _collect_training(self, sub: _TrainSubmission
                          ) -> Tuple[List[JobResult], List[JobResult]]:
        """Block on a submission; returns (per-candidate results in genome
        order, raw per-job results).  The scheduler may return partial
        results (every worker died), so jobs are matched by job_id and the
        gaps marked failed instead of mispairing zip order."""
        by_id = {r.job_id: r for r in sub.run.wait()}
        raw = [by_id.get(i, JobResult(job_id=i, ok=False,
                                      error="no result (workers died)"))
               for i in range(sub.n_jobs)]
        if sub.buckets is None:
            return raw, raw
        out: List[Optional[JobResult]] = [None] * sub.n_genomes
        for rows, br in zip(sub.buckets, raw):
            ok = bool(br.ok and br.value is not None
                      and len(br.value) == len(rows))
            error = br.error if not br.ok else (
                "" if ok else "batch trainer returned misaligned results")
            for k, j in enumerate(rows):
                out[j] = JobResult(
                    job_id=j, ok=ok,
                    value=br.value[k] if ok else None,
                    error=error, attempts=br.attempts,
                    elapsed_s=br.elapsed_s, worker=br.worker,
                    device=br.device)
        return out, raw  # type: ignore[return-value]

    def _finish_training(self, state: NASState, pop: PopulationArrays,
                         plan: _TrainPlan, sub: _TrainSubmission
                         ) -> Dict[str, float]:
        """Wait on a submission, write expensive objectives (pessimistic on
        failure OR divergence) into ``pop`` + the dormant-gene cache, and
        return the per-device busy time of the dispatched jobs."""
        results, raw = self._collect_training(sub)
        if sub.run.quarantined:
            self.log(f"[nas] WARNING: quarantined device(s) "
                     f"{[str(d) for d in sub.run.quarantined]} after "
                     f"repeated failures — queued buckets rebalanced onto "
                     f"the surviving devices")
        for i, r in zip(plan.todo, results):
            if self.faults is not None:
                spec = self.faults.fire("trainer.result",
                                        phash=str(pop.phash[i]),
                                        generation=state.generation)
                if spec is not None and spec.kind == "nonfinite" and r.ok:
                    r = dataclasses.replace(
                        r, value=self._poison_result(r.value))
            if r.ok:
                exp = expensive_objectives(r.value)
                vl = getattr(r.value, "val_loss", 0.0)
                if not (np.all(np.isfinite(exp)) and np.isfinite(vl)):
                    # per-candidate quarantine: a diverged candidate gets
                    # the schema-pessimistic row; its bucket-mates' results
                    # (already in `results`) are untouched
                    self.log(f"[nas] candidate {pop.phash[i]} diverged "
                             f"(non-finite objectives) — quarantined with "
                             f"pessimistic row")
                    exp = self._exp_worst.copy()
            else:  # failed after retries: pessimistic objectives, stay in
                self.log(f"[nas] candidate {pop.phash[i]} failed: "
                         f"{r.error.splitlines()[-1] if r.error else '?'}")
                exp = self._exp_worst.copy()
            pop.expensive[i] = exp
            with self._cache_lock:
                state.evaluated_hashes[str(pop.phash[i])] = exp
        busy: Dict[str, float] = {}
        for r in raw:
            key = str(r.device) if r.device is not None else "default"
            busy[key] = busy.get(key, 0.0) + r.elapsed_s
        return busy

    def _train_members(self, state: NASState, pop: PopulationArrays,
                       idx: np.ndarray) -> Dict[str, float]:
        """Expensive-evaluate rows ``idx`` of ``pop`` (cache-first),
        blocking until every result is in.  Returns per-device busy time.
        Genome objects are materialized here only, for the training jobs."""
        plan = self._plan_training(state, pop, idx)
        if plan is None:
            return {}
        return self._finish_training(state, pop, plan,
                                     self._submit_training(plan.genomes))

    # ------------------------------------------------------ selection fold
    def _goal_objs(self, merged: PopulationArrays) -> np.ndarray:
        """The goal-conditioned objective view (all columns for the
        balanced default — bit-identical to the pre-schema engine)."""
        objs = merged.objective_matrix()
        if self._goal_cols is not None:
            objs = objs[:, self._goal_cols]
        return objs

    def _select_and_record(self, state: NASState, merged: PopulationArrays,
                           objs: np.ndarray, dom: np.ndarray,
                           n_children: int, n_trained: int,
                           timings: Dict[str, float],
                           device_busy: Dict[str, float],
                           train_jobs: int,
                           pipeline: Optional[str] = None,
                           t0: Optional[float] = None) -> None:
        """Environmental selection + the per-generation history record.
        One domination matrix serves both the environmental selection and
        the kept population's front-size report."""
        t_sel = time.monotonic()
        keep = environmental_selection(objs, self.cfg.population_cap,
                                       dom=dom)
        new_pop = merged.take(keep)
        gen = state.generation + 1
        front = pareto_front(objs[keep], dom=dom[np.ix_(keep, keep)])
        feasible = new_pop.feasible_mask(self.constraints)
        primary = self.goal.primary_indices(self.schema)
        timings["select"] = time.monotonic() - t_sel
        rec = {
            "generation": gen,
            "children": n_children,
            "trained": n_trained,
            "population": len(new_pop),
            "front_size": int(len(front)),
            "feasible": int(feasible.sum()),
            # worst-across-platforms primary objective of the best feasible
            # member (single platform: just its primary objective)
            "best_primary": float(
                new_pop.cheap[np.ix_(feasible, primary)].max(axis=1).min())
            if feasible.any() else float("nan"),
            "elapsed_s": time.monotonic() - (t0 if t0 is not None else t_sel),
            # wall-time split of the generation's phases + per-device busy
            # time of its training jobs (DESIGN.md §11) — how much overlap
            # the pipeline actually achieved is observable per generation
            "timings": dict(timings),
            "device_busy_s": dict(device_busy),
            "train_jobs": train_jobs,
        }
        if pipeline is not None:
            rec["pipeline"] = pipeline
        imb = device_imbalance(device_busy)
        if imb is not None and imb > DEVICE_IMBALANCE_RATIO:
            rec["device_imbalance"] = imb
            busy_fmt = {k: round(v, 3)
                        for k, v in sorted(device_busy.items())}
            self.log(f"[nas] WARNING gen {gen}: device busy "
                     f"imbalance {imb:.1f}x (max/min, threshold "
                     f"{DEVICE_IMBALANCE_RATIO:.1f}x) — signature buckets "
                     f"are skewing onto few devices; busy={busy_fmt}")
        # publish the finished generation as one cut: everything above
        # worked on locals, so a preemption mid-selection leaves `state` at
        # the previous consistent generation (DESIGN.md §13)
        state.pop, state.generation = new_pop, gen
        state.history.append(rec)
        self.log(f"[nas] gen {rec['generation']:3d} "
                 f"pop={rec['population']} front={rec['front_size']} "
                 f"feasible={rec['feasible']} "
                 f"best[{self.goal.primary}]={rec['best_primary']:.3e} "
                 f"({rec['elapsed_s']:.1f}s)")

    def step(self, state: NASState) -> NASState:
        """One generation.  ``pipeline="off"`` dispatches and blocks;
        ``"host_overlap"`` (and ``"async"``, which degenerates to it for a
        single step — cross-generation pipelining needs :meth:`run`) folds
        the merged population's cheap domination columns while the devices
        train.  Both orderings produce bit-identical trajectories."""
        t0 = time.monotonic()
        timings: Dict[str, float] = {}
        spawned = self._spawn_children(state)
        timings["children"] = time.monotonic() - t0
        t = time.monotonic()
        children = None if spawned is None else self._score(
            spawned[0], spawned[1], generation=state.generation + 1)
        timings["cheap_score"] = time.monotonic() - t

        overlap = self.cfg.pipeline in ("host_overlap", "async")
        device_busy: Dict[str, float] = {}
        train_jobs = 0
        t = time.monotonic()
        if children is not None:
            acc_idx = sel.preselect_children(self.rng, state.pop.cheap,
                                             children.cheap,
                                             self.cfg.n_accept,
                                             cols=self._kde_cols)
            accepted = children.take(acc_idx)
            n_children, n_trained = len(children), len(accepted)
            if overlap:
                plan = self._plan_training(state, accepted,
                                           np.arange(len(accepted)))
                sub = None if plan is None \
                    else self._submit_training(plan.genomes)
                # ---- overlap window: while the devices train, fold the
                # merged population's cheap domination columns (boolean
                # folds are order-independent — the finished matrix is
                # bit-identical to the synchronous one)
                merged_cheap = np.concatenate([state.pop.cheap,
                                               accepted.cheap])
                partial = PartialDomination(
                    merged_cheap[:, self._sel_cheap_cols])
                # ---- join: write results, then fold the expensive columns
                if sub is not None:
                    device_busy = self._finish_training(state, accepted,
                                                        plan, sub)
                    train_jobs = sub.n_jobs
                timings["train"] = time.monotonic() - t
                merged = PopulationArrays.concat([state.pop, accepted])
                objs = self._goal_objs(merged)
                dom = partial.finish(objs[:, len(self._sel_cheap_cols):])
            else:
                plan = self._plan_training(state, accepted,
                                           np.arange(len(accepted)))
                if plan is not None:
                    sub = self._submit_training(plan.genomes)
                    device_busy = self._finish_training(state, accepted,
                                                        plan, sub)
                    train_jobs = sub.n_jobs
                timings["train"] = time.monotonic() - t
                merged = PopulationArrays.concat([state.pop, accepted])
                objs = self._goal_objs(merged)
                dom = domination_matrix(objs)
        else:
            timings["train"] = 0.0
            merged = state.pop
            n_children = n_trained = 0
            objs = self._goal_objs(merged)
            dom = domination_matrix(objs)

        self._select_and_record(state, merged, objs, dom, n_children,
                                n_trained, timings, device_busy, train_jobs,
                                t0=t0)
        return state

    def run(self, generations: Optional[int] = None) -> NASState:
        gens = generations or self.cfg.generations
        if self.cfg.pipeline == "async":
            return self._run_async(gens)
        state = self.init_state()
        for _ in range(gens):
            if self.faults is not None:
                self.faults.fire("search.generation",
                                 generation=state.generation)
            state = self.step(state)
        return state

    # --------------------------------------------------- async pipelining
    def _run_async(self, generations: int,
                   state: Optional[NASState] = None,
                   ckpt_path: Optional[str] = None) -> NASState:
        """Steady-state pipelined evolution (``pipeline="async"``).

        Generation N+1's children are mutated, cheap-scored, preselected
        and *dispatched* while generation N's buckets still train — up to
        ``lookahead + 1`` generations in flight.  Each bucket's results are
        admitted into the dormant-gene cache the moment it lands (the
        scheduler's ``on_result`` hook), so later generations never
        retrain a phenotype that finished early; environmental selection
        folds a generation into the population only when it drains, in
        submission order.  Relaxed semantics: parents of generation N+1
        are sampled from the population *before* generation N's survivors
        joined it — the price of never letting the host or the devices
        idle.

        With ``ckpt_path`` the loop checkpoints at *drain barriers*
        (DESIGN.md §13): every ``ckpt_every`` produced generations
        (default ``lookahead + 1``) it stops admitting lookahead work,
        drains every in-flight generation, and persists the then-consistent
        :class:`NASState` — the pipeline refills afterwards.  A search
        resumed from such a cut re-enters with an empty pipeline, exactly
        the state an uninterrupted barrier run had at that point."""
        if state is None:
            state = self.init_state()
        target = state.generation + generations
        produced = state.generation
        saved_gen = state.generation  # run_resumable persisted this cut
        barrier = self.cfg.ckpt_every or (self.cfg.lookahead + 1)
        next_barrier = (state.generation + barrier) \
            if ckpt_path is not None else None

        def admit(phash: str, exp: np.ndarray) -> None:
            with self._cache_lock:
                state.evaluated_hashes[phash] = exp

        empty = state.pop.take(np.asarray([], dtype=np.int64))
        inflight: Deque[dict] = deque()
        inflight_hashes: set = set()
        t_drain = time.monotonic()

        def drain() -> None:
            nonlocal t_drain
            entry = inflight.popleft()
            accepted = entry["accepted"]
            timings = entry["timings"]
            device_busy: Dict[str, float] = {}
            t = time.monotonic()
            if entry["sub"] is not None:
                device_busy = self._finish_training(
                    state, accepted, entry["plan"], entry["sub"])
            timings["train"] = time.monotonic() - t  # wait-time only: the
            #   bucket trained while later generations were produced
            inflight_hashes.difference_update(str(h) for h in accepted.phash)
            merged = PopulationArrays.concat([state.pop, accepted]) \
                if len(accepted) else state.pop
            objs = self._goal_objs(merged)
            dom = domination_matrix(objs)
            self._select_and_record(
                state, merged, objs, dom, entry["n_children"],
                len(accepted), timings, device_busy,
                entry["sub"].n_jobs if entry["sub"] is not None else 0,
                pipeline="async", t0=t_drain)
            t_drain = time.monotonic()

        while state.generation < target:
            if self.faults is not None:
                self.faults.fire("search.generation",
                                 generation=state.generation)
            can_produce = produced < target \
                and len(inflight) <= self.cfg.lookahead
            if next_barrier is not None and produced >= next_barrier:
                can_produce = False  # drain barrier: admit nothing more
            if can_produce:
                t0 = time.monotonic()
                timings: Dict[str, float] = {}
                spawned = self._spawn_children(state,
                                               extra_seen=inflight_hashes)
                timings["children"] = time.monotonic() - t0
                t = time.monotonic()
                accepted, plan, sub, n_children = empty, None, None, 0
                if spawned is not None:
                    children = self._score(spawned[0], spawned[1],
                                           generation=produced + 1)
                    acc_idx = sel.preselect_children(
                        self.rng, state.pop.cheap, children.cheap,
                        self.cfg.n_accept, cols=self._kde_cols)
                    accepted = children.take(acc_idx)
                    n_children = len(children)
                    plan = self._plan_training(state, accepted,
                                               np.arange(len(accepted)))
                    if plan is not None:
                        sub = self._submit_training(
                            plan.genomes,
                            phashes=[str(accepted.phash[i])
                                     for i in plan.todo],
                            admit=admit)
                timings["cheap_score"] = time.monotonic() - t
                inflight_hashes.update(str(h) for h in accepted.phash)
                inflight.append({"accepted": accepted, "plan": plan,
                                 "sub": sub, "n_children": n_children,
                                 "timings": timings})
                produced += 1
                continue
            drain()
            if next_barrier is not None and not inflight \
                    and state.generation >= next_barrier:
                # pipeline fully drained at the barrier: this state is a
                # consistent cut (no lookahead RNG draws beyond it)
                self.save_state(state, ckpt_path)
                saved_gen = state.generation
                next_barrier = state.generation + barrier
        if ckpt_path is not None and state.generation > saved_gen:
            self.save_state(state, ckpt_path)
        return state

    # ------------------------------------------------------- checkpointing
    # The paper's search runs two days on a GPU farm; a preempted search
    # must resume mid-generation.  State is plain JSON (genomes are small
    # int tuples) written atomically.  The driver's RNG state rides along so
    # a resumed search is bit-identical to an uninterrupted one.
    def save_state(self, state: NASState, path: str) -> None:
        import json as _json
        import os as _os
        pop = state.pop
        trained = pop.trained_mask
        payload = {
            "generation": state.generation,
            "history": state.history,
            "schema": self.schema.to_json(),
            "evaluated": {k: v.tolist()
                          for k, v in state.evaluated_hashes.items()},
            "rng_state": self.rng.bit_generator.state,
            "population": [{
                "genome": dataclasses.asdict(pop.enc.genome(i)),
                "cheap": pop.cheap[i].tolist(),
                "expensive": pop.expensive[i].tolist()
                if trained[i] else None,
                "phash": str(pop.phash[i]),
                "generation": int(pop.born[i]),
            } for i in range(len(pop))],
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            _json.dump(payload, f)
        if _os.path.exists(path):
            # rotate: the previous checkpoint survives as `<path>.prev`, so
            # a write that lands corrupt (torn disk, injected fault) still
            # leaves one loadable generation behind (DESIGN.md §13)
            _os.replace(path, path + ".prev")
        _os.replace(tmp, path)
        if self.faults is not None:
            spec = self.faults.fire("ckpt.save", path=path)
            if spec is not None and spec.kind == "corrupt":
                self.faults.corrupt_file(path)

    def load_state(self, path: str) -> NASState:
        """Restore a checkpoint.  Also restores this driver's RNG state (when
        present — older checkpoints load fine without it), so resuming
        reproduces the uninterrupted run bit-for-bit.

        A checkpoint that fails to *parse* (truncated/corrupt JSON — the
        write died mid-flight) falls back to the rotated ``<path>.prev``
        with a warning instead of crashing: losing one generation beats
        losing a days-long search.  When BOTH generations are torn (a
        double fault) the caller gets one clean ``RuntimeError`` naming
        both files and both parse errors — never a raw mid-parse traceback
        from the fallback path.  Configuration errors (schema mismatch)
        still raise — falling back would mask them.

        The persisted objective schema is validated against this driver's
        backend: resuming a checkpoint under a different platform set would
        silently misread the cheap matrix, so a mismatch raises.  Pre-schema
        checkpoints are accepted when the column count matches."""
        import json as _json
        import os as _os
        torn = (_json.JSONDecodeError, KeyError, TypeError, IndexError,
                UnicodeDecodeError)
        try:
            return self._load_checkpoint(path)
        except torn as e:
            prev = path + ".prev"
            if not _os.path.exists(prev):
                raise
            self.log(f"[nas] WARNING: checkpoint {path} is corrupt "
                     f"({type(e).__name__}: {e}) — falling back to the "
                     f"rotated previous checkpoint {prev}")
            try:
                return self._load_checkpoint(prev)
            except torn as e2:
                raise RuntimeError(
                    f"both checkpoints are corrupt: {path} "
                    f"({type(e).__name__}: {e}) and {prev} "
                    f"({type(e2).__name__}: {e2}) — no loadable "
                    f"generation survives; restart the search") from e2

    def _load_checkpoint(self, path: str) -> NASState:
        import json as _json
        with open(path) as f:
            payload = _json.load(f)
        if "schema" in payload:
            saved = ObjectiveSchema.from_json(payload["schema"])
            if saved != self.schema:
                raise ValueError(
                    f"checkpoint objective schema "
                    f"{list(saved.qualified_names)} does not match this "
                    f"search's backend schema "
                    f"{list(self.schema.qualified_names)} — resume with the "
                    f"same backends/goal configuration")
        members = payload["population"]
        if members and len(members[0]["cheap"]) != len(self.schema):
            raise ValueError(
                f"checkpoint cheap matrix has {len(members[0]['cheap'])} "
                f"columns; this search's schema has {len(self.schema)}")
        genomes = [Genome(
            op_genes=tuple(m["genome"]["op_genes"]),
            conn_genes=tuple(m["genome"]["conn_genes"]),
            out_gene=m["genome"]["out_gene"],
            w_bits_gene=m["genome"]["w_bits_gene"],
            a_bits_gene=m["genome"]["a_bits_gene"],
            i_bits_gene=m["genome"]["i_bits_gene"],
            dec_gene=m["genome"]["dec_gene"]) for m in members]
        expensive = np.full((len(members), len(self._exp_worst)), np.nan)
        for i, m in enumerate(members):
            if m["expensive"] is not None:
                expensive[i] = m["expensive"]
        pop = PopulationArrays(
            enc=PopulationEncoding.from_genomes(genomes),
            cheap=np.asarray([m["cheap"] for m in members], np.float64),
            expensive=expensive,
            phash=np.asarray([m["phash"] for m in members], dtype=object),
            born=np.asarray([m["generation"] for m in members], np.int64),
            schema=self.schema)
        if "rng_state" in payload:
            self.rng.bit_generator.state = payload["rng_state"]
        return NASState(
            pop=pop, generation=payload["generation"],
            evaluated_hashes={k: np.asarray(v)
                              for k, v in payload["evaluated"].items()},
            history=payload["history"])

    def run_resumable(self, ckpt_path: str,
                      generations: Optional[int] = None) -> NASState:
        """Resume from `ckpt_path` if present; checkpoint as the search
        progresses (DESIGN.md §13).

        The ``off`` and ``host_overlap`` pipelines checkpoint every
        ``ckpt_every`` generations (default 1; their trajectories are
        identical, so a search may even resume under the other mode).  The
        ``async`` pipeline checkpoints at *drain barriers*: every
        ``ckpt_every`` (default ``lookahead + 1``) generations it stops
        admitting lookahead work, drains the in-flight generations, and
        persists the consistent state — so a preempted async search resumes
        from the last barrier instead of being rejected.

        Preemption is graceful: ``KeyboardInterrupt`` (and ``SIGTERM``,
        translated when running in the main thread) persists the last
        consistent state before re-raising, so the next invocation resumes
        exactly where this one stopped — bit-identically for the
        deterministic pipelines."""
        import os as _os
        import signal as _signal
        target = generations or self.cfg.generations
        if _os.path.exists(ckpt_path):
            state = self.load_state(ckpt_path)
            self.log(f"[nas] resumed at generation {state.generation}")
        else:
            state = self.init_state()
            # persist immediately: a preemption before the first checkpoint
            # must not lose the (expensive) initial population training
            self.save_state(state, ckpt_path)
        saved_gen = state.generation

        def _on_sigterm(signum, frame):
            raise KeyboardInterrupt("SIGTERM")

        installed, old_handler = False, None
        try:
            old_handler = _signal.signal(_signal.SIGTERM, _on_sigterm)
            installed = True
        except ValueError:
            pass  # not the main thread: SIGTERM stays with the host app
        try:
            if self.cfg.pipeline == "async":
                if state.generation < target:
                    state = self._run_async(target - state.generation,
                                            state=state,
                                            ckpt_path=ckpt_path)
                saved_gen = state.generation
            else:
                every = self.cfg.ckpt_every or 1
                while state.generation < target:
                    if self.faults is not None:
                        self.faults.fire("search.generation",
                                         generation=state.generation)
                    state = self.step(state)
                    if state.generation - saved_gen >= every \
                            or state.generation >= target:
                        self.save_state(state, ckpt_path)
                        saved_gen = state.generation
        except KeyboardInterrupt:
            # graceful preemption: the state object always sits at the last
            # *completed* generation (selection publishes atomically), so
            # persist it if the disk is behind, then let the signal
            # propagate to the host
            if state.generation > saved_gen:
                self.save_state(state, ckpt_path)
            self.log(f"[nas] preempted at generation {state.generation}; "
                     f"checkpoint {ckpt_path} holds a consistent resume "
                     f"point")
            raise
        finally:
            if installed:
                _signal.signal(_signal.SIGTERM,
                               old_handler if old_handler is not None
                               else _signal.SIG_DFL)
        return state

    # ---------------------------------------------------------------- report
    def select_solution(self, state: NASState,
                        objective: str = "energy_max_alpha_j",
                        platform: Optional[str] = None
                        ) -> Optional[Candidate]:
        """Best feasible candidate for a deployment objective (paper §VI-B).

        ``objective`` is a schema query, not a position: pass a bare name
        (single-platform searches), a qualified ``platform:name``, or a bare
        name plus ``platform`` to disambiguate a multi-platform schema.
        """
        idx = self.schema.index(objective, platform=platform)
        feas = state.pop.feasible_mask(self.constraints)
        if not feas.any():
            return None
        rows = np.nonzero(feas)[0]
        return state.pop.candidate(
            int(rows[np.argmin(state.pop.cheap[rows, idx])]))

    def select_for_goal(self, state: NASState,
                        goal: Union[None, str, DesignGoal] = None
                        ) -> Optional[Candidate]:
        """Best feasible candidate under a design goal (default: the
        search's own).  With several platforms in the goal's scope the
        ranking value is the *worst* (max) primary objective across them —
        the robust cross-platform pick."""
        g = self.goal if goal is None else get_goal(goal)
        cols = g.primary_indices(self.schema)
        feas = state.pop.feasible_mask(
            g.effective_constraints(self.cfg.constraints))
        if not feas.any():
            return None
        rows = np.nonzero(feas)[0]
        score = state.pop.cheap[np.ix_(rows, cols)].max(axis=1)
        return state.pop.candidate(int(rows[np.argmin(score)]))

    def pareto_fronts(self, state: NASState) -> Dict[str, np.ndarray]:
        """Per-platform and cross-platform Pareto fronts of the population.

        Returns ``{"cross_platform": idx, <platform>: idx, ...}`` — front
        membership over the full objective matrix and over each platform's
        column group (its cheap columns + the expensive pair).  All fronts
        come from one shared pass over the per-column comparisons
        (:func:`~repro.core.pareto.domination_matrices`).
        """
        objs = state.pop.objective_matrix()
        n_cols = len(self.full_schema)
        # single-platform schemas: every platform group equals the full
        # column set — alias the cross-platform front instead of building
        # identical (N, N) matrices
        groups = {"cross_platform": np.arange(n_cols)}
        for p in self.schema.platforms:
            cols = self.full_schema.platform_group(p)
            if len(cols) < n_cols:
                groups[p] = cols
        doms = domination_matrices(objs, list(groups.values()))
        fronts = {name: np.nonzero(dom.sum(axis=0) == 0)[0]
                  for name, dom in zip(groups, doms)}
        for p in self.schema.platforms:
            fronts.setdefault(p, fronts["cross_platform"])
        return fronts
