"""The hardware-aware evolutionary NAS loop (paper §III-A, §VI).

Per generation (paper: 100 generations x 20 children on 4 GPUs):

1. sample parents from the population, inverse-KDE-density weighted in
   cheap-objective space (LEMONADE-style exploration of the frontier);
2. produce children by forced-active mutation (+ occasional crossover);
   phenotype-hash dedup implements the dormant-gene shortcut — children whose
   expressed genes are unchanged are never retrained;
3. evaluate the children's cheap objectives analytically (Eqs. 1-4);
4. **two-step preselection**: only ``n_accept`` children, chosen
   inverse-density in cheap space, get expensive evaluation (training) —
   dispatched through the dynamic workload scheduler;
5. environmental selection (non-dominated sort + crowding) trims the merged
   population back to capacity.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import selection as sel
from repro.core.cost_backend import BackendSpec, get_backend
from repro.core.genome import Genome, crossover, mutate, random_genome
from repro.core.hw_model import FPGA_ZU, HardwareProfile
from repro.core.objectives import (
    Candidate,
    cheap_matrix,
    cheap_objectives_batch,
    expensive_objectives,
    objective_matrix,
)
from repro.core.pareto import environmental_selection, pareto_front
from repro.core.scheduler import DynamicScheduler
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.core.trainer import TrainResult, train_candidate


@dataclasses.dataclass
class NASConfig:
    generations: int = 100
    children_per_gen: int = 20
    n_accept: int = 8              # expensive-evaluation budget per generation
    population_cap: int = 64
    init_population: int = 16
    mutation_rate: float = 0.1
    crossover_prob: float = 0.25
    train_steps: int = 300
    train_batch: int = 64
    lr: float = 3e-3
    n_workers: int = 4
    seed: int = 0
    profile: HardwareProfile = FPGA_ZU
    backend: Optional[BackendSpec] = None  # cost backend; default = profile
    det_min: float = 0.90          # paper's hard acceptance limits
    fa_max: float = 0.20


@dataclasses.dataclass
class NASState:
    population: List[Candidate]
    generation: int
    evaluated_hashes: Dict[str, np.ndarray]  # phenotype hash -> expensive objs
    history: List[dict]


class EvolutionarySearch:
    """Reusable search driver; inject a trainer for tests."""

    def __init__(self, config: NASConfig,
                 data_train, data_val,
                 space: SearchSpace = DEFAULT_SPACE,
                 train_fn: Optional[Callable[[Genome], TrainResult]] = None,
                 log: Callable[[str], None] = print):
        self.cfg = config
        self.space = space
        self.rng = np.random.default_rng(config.seed)
        self.backend = get_backend(config.backend if config.backend
                                   is not None else config.profile)
        self.log = log
        self._train_fn = train_fn or (lambda g: train_candidate(
            g, data_train, data_val, space=self.space,
            steps=config.train_steps, batch_size=config.train_batch,
            lr=config.lr, seed=config.seed))
        self.scheduler = DynamicScheduler(n_workers=config.n_workers,
                                          max_retries=2, timeout_s=1800.0)

    # ------------------------------------------------------------- lifecycle
    def _score_batch(self, genomes: Sequence[Genome],
                     hashes: Sequence[str], generation: int
                     ) -> List[Candidate]:
        """One batched cheap-objective pass over a genome batch."""
        cheap = cheap_objectives_batch(genomes, backend=self.backend,
                                       space=self.space)
        return [Candidate(genome=g, cheap=cheap[i], phash=h,
                          generation=generation)
                for i, (g, h) in enumerate(zip(genomes, hashes))]

    def init_state(self) -> NASState:
        genomes: List[Genome] = []
        hashes: List[str] = []
        seen = set()
        while len(genomes) < self.cfg.init_population:
            g = random_genome(self.rng, self.space)
            h = g.phenotype_hash(self.space)
            if h in seen:
                continue
            seen.add(h)
            genomes.append(g)
            hashes.append(h)
        pop = self._score_batch(genomes, hashes, generation=0)
        state = NASState(population=pop, generation=0,
                         evaluated_hashes={}, history=[])
        self._train_batch(state, pop)
        return state

    # ---------------------------------------------------------------- steps
    def _make_children(self, state: NASState) -> List[Candidate]:
        pop = state.population
        cheap = cheap_matrix(pop)
        parents_idx = sel.sample_parents(self.rng, cheap,
                                         self.cfg.children_per_gen)
        child_genomes: List[Genome] = []
        child_hashes: List[str] = []
        seen = {c.phash for c in pop}
        for pi in parents_idx:
            parent = pop[pi]
            if self.rng.random() < self.cfg.crossover_prob and len(pop) > 1:
                mate = pop[int(self.rng.integers(0, len(pop)))]
                child_g = crossover(parent.genome, mate.genome, self.rng,
                                    self.space)
                child_g = mutate(child_g, self.rng, self.space,
                                 rate=self.cfg.mutation_rate,
                                 force_active_change=False)
            else:
                child_g = mutate(parent.genome, self.rng, self.space,
                                 rate=self.cfg.mutation_rate,
                                 force_active_change=True)
            if not child_g.is_valid(self.space):
                continue
            h = child_g.phenotype_hash(self.space)
            if h in seen:
                continue  # dormant-gene shortcut: identical phenotype
            seen.add(h)
            child_genomes.append(child_g)
            child_hashes.append(h)
        if not child_genomes:
            return []
        return self._score_batch(child_genomes, child_hashes,
                                 generation=state.generation + 1)

    def _train_batch(self, state: NASState, cands: Sequence[Candidate]):
        todo = []
        for c in cands:
            if c.phash in state.evaluated_hashes:  # cache hit (dormant genes)
                c.expensive = state.evaluated_hashes[c.phash]
            else:
                todo.append(c)
        if not todo:
            return
        jobs = [(lambda g=c.genome: self._train_fn(g)) for c in todo]
        results = self.scheduler.run(jobs)
        for c, r in zip(todo, results):
            if r.ok:
                c.train_result = r.value
                c.expensive = expensive_objectives(r.value)
            else:  # failed after retries: pessimistic objectives, stay in pool
                self.log(f"[nas] candidate {c.phash} failed: "
                         f"{r.error.splitlines()[-1] if r.error else '?'}")
                c.expensive = np.asarray([1.0, 1.0])
            state.evaluated_hashes[c.phash] = c.expensive

    def step(self, state: NASState) -> NASState:
        t0 = time.monotonic()
        children = self._make_children(state)
        if children:
            pop_cheap = cheap_matrix(state.population)
            child_cheap = cheap_matrix(children)
            acc_idx = sel.preselect_children(self.rng, pop_cheap, child_cheap,
                                             self.cfg.n_accept)
            accepted = [children[i] for i in acc_idx]
            self._train_batch(state, accepted)
        else:
            accepted = []

        merged = state.population + accepted
        objs = objective_matrix(merged)
        keep = environmental_selection(objs, self.cfg.population_cap)
        new_pop = [merged[i] for i in keep]

        state.generation += 1
        front = pareto_front(objective_matrix(new_pop))
        feasible = [c for c in new_pop if c.meets_constraints(
            self.cfg.det_min, self.cfg.fa_max)]
        rec = {
            "generation": state.generation,
            "children": len(children),
            "trained": len(accepted),
            "population": len(new_pop),
            "front_size": int(len(front)),
            "feasible": len(feasible),
            "best_energy_j": min((c.cheap[3] for c in feasible),
                                 default=float("nan")),
            "elapsed_s": time.monotonic() - t0,
        }
        state.history.append(rec)
        state.population = new_pop
        self.log(f"[nas] gen {rec['generation']:3d} "
                 f"pop={rec['population']} front={rec['front_size']} "
                 f"feasible={rec['feasible']} "
                 f"bestE={rec['best_energy_j']:.3e}J "
                 f"({rec['elapsed_s']:.1f}s)")
        return state

    def run(self, generations: Optional[int] = None) -> NASState:
        state = self.init_state()
        for _ in range(generations or self.cfg.generations):
            state = self.step(state)
        return state

    # ------------------------------------------------------- checkpointing
    # The paper's search runs two days on a GPU farm; a preempted search
    # must resume mid-generation.  State is plain JSON (genomes are small
    # int tuples) written atomically.
    def save_state(self, state: NASState, path: str) -> None:
        import json as _json
        import os as _os
        payload = {
            "generation": state.generation,
            "history": state.history,
            "evaluated": {k: v.tolist()
                          for k, v in state.evaluated_hashes.items()},
            "population": [{
                "genome": dataclasses.asdict(c.genome),
                "cheap": c.cheap.tolist(),
                "expensive": None if c.expensive is None
                else c.expensive.tolist(),
                "phash": c.phash,
                "generation": c.generation,
            } for c in state.population],
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            _json.dump(payload, f)
        _os.replace(tmp, path)

    def load_state(self, path: str) -> NASState:
        import json as _json
        with open(path) as f:
            payload = _json.load(f)
        pop = []
        for c in payload["population"]:
            g = c["genome"]
            genome = Genome(
                op_genes=tuple(g["op_genes"]),
                conn_genes=tuple(g["conn_genes"]),
                out_gene=g["out_gene"], w_bits_gene=g["w_bits_gene"],
                a_bits_gene=g["a_bits_gene"], i_bits_gene=g["i_bits_gene"],
                dec_gene=g["dec_gene"])
            pop.append(Candidate(
                genome=genome, cheap=np.asarray(c["cheap"]),
                expensive=None if c["expensive"] is None
                else np.asarray(c["expensive"]),
                phash=c["phash"], generation=c["generation"]))
        return NASState(
            population=pop, generation=payload["generation"],
            evaluated_hashes={k: np.asarray(v)
                              for k, v in payload["evaluated"].items()},
            history=payload["history"])

    def run_resumable(self, ckpt_path: str,
                      generations: Optional[int] = None) -> NASState:
        """Resume from `ckpt_path` if present; checkpoint every generation."""
        import os as _os
        if _os.path.exists(ckpt_path):
            state = self.load_state(ckpt_path)
            self.log(f"[nas] resumed at generation {state.generation}")
        else:
            state = self.init_state()
        target = generations or self.cfg.generations
        while state.generation < target:
            state = self.step(state)
            self.save_state(state, ckpt_path)
        return state

    # ---------------------------------------------------------------- report
    def select_solution(self, state: NASState, objective: str = "energy_max_alpha_j"
                        ) -> Optional[Candidate]:
        """Best feasible candidate for a deployment objective (paper §VI-B)."""
        from repro.core.objectives import CHEAP_NAMES
        idx = CHEAP_NAMES.index(objective)
        feas = [c for c in state.population
                if c.meets_constraints(self.cfg.det_min, self.cfg.fa_max)]
        if not feas:
            return None
        return min(feas, key=lambda c: c.cheap[idx])
