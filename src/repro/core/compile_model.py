"""Model "compilation" for deployment (paper §III-A last ¶ and §III-B).

Takes a trained candidate and produces the deployable artifact:
batchnorm-folded, weight-quantized parameters plus the per-layer
implementation plan (unrolling factors, accumulator formats) that the
hardware generator would consume.  On the TPU target the plan maps to
per-layer parallelism and the fixed-point metadata is carried for the
int8 serving path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.genome import Genome
from repro.core.hw_model import (
    FPGA_ZU,
    HardwareProfile,
    HwEstimate,
    estimate,
    layer_costs_for,
    resolve_alphas,
)
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.hwlib.layers import LayerSpec
from repro.hwlib.profiler import AccumulatorFormat, profile_accumulators
from repro.hwlib.quant import fold_model, quantize_layer_params


@dataclasses.dataclass
class CompiledModel:
    """The deployable artifact the implementation framework emits."""

    genome: Genome
    specs: List[LayerSpec]
    params: List[Dict[str, Any]]        # BN-folded, fake-quantized
    alphas: List[int]                   # per-layer parallelization plan
    acc_formats: List[AccumulatorFormat]
    estimate_min: HwEstimate
    estimate_max: HwEstimate

    def report(self) -> str:
        lines = ["layer,spec,alpha,acc_bits,params"]
        costs = layer_costs_for(self.genome)
        for i, (s, a, f, c) in enumerate(zip(self.specs, self.alphas,
                                             self.acc_formats, costs)):
            lines.append(f"{i},{s.short()},{a},{f.total_bits},{c.params}")
        return "\n".join(lines)


def compile_candidate(
    genome: Genome,
    params: Sequence[Dict[str, Any]],
    x_calib: jnp.ndarray,
    *,
    strategy: str = "max",
    profile: HardwareProfile = FPGA_ZU,
    space: SearchSpace = DEFAULT_SPACE,
) -> CompiledModel:
    specs = genome.phenotype(space)
    quant = genome.quant(space)

    folded = fold_model(list(params), specs)
    quantized = [quantize_layer_params(p, s, quant)
                 for p, s in zip(folded, specs)]
    acc_formats = profile_accumulators(quantized, specs, x_calib)

    costs = layer_costs_for(genome, space)
    alphas = resolve_alphas(costs, strategy, profile)
    return CompiledModel(
        genome=genome,
        specs=specs,
        params=quantized,
        alphas=list(alphas),
        acc_formats=acc_formats,
        estimate_min=estimate(genome, strategy="min", profile=profile,
                              space=space),
        estimate_max=estimate(genome, strategy="max", profile=profile,
                              space=space),
    )
