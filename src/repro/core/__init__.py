"""HALF's contribution: hardware-aware evolutionary NAS + analytic hw models."""
from repro.core.cost_backend import (  # noqa: F401
    CostBackend,
    FPGAAnalyticBackend,
    TPURooflineBackend,
    get_backend,
)
from repro.core.evolution import EvolutionarySearch, NASConfig  # noqa: F401
from repro.core.genome import (  # noqa: F401
    Genome,
    PopulationEncoding,
    mutate,
    random_genome,
)
from repro.core.hw_model import (  # noqa: F401
    estimate,
    estimate_population,
    roofline,
)
from repro.core.search_space import DEFAULT_SPACE, SearchSpace  # noqa: F401
from repro.core.trainer_batch import (  # noqa: F401
    bucket_by_signature,
    shape_signature,
    train_candidates_batched,
)
