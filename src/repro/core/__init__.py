"""HALF's contribution: hardware-aware evolutionary NAS + analytic hw models."""
from repro.core.evolution import EvolutionarySearch, NASConfig  # noqa: F401
from repro.core.genome import Genome, mutate, random_genome  # noqa: F401
from repro.core.hw_model import estimate, roofline  # noqa: F401
from repro.core.search_space import DEFAULT_SPACE, SearchSpace  # noqa: F401
