"""Pluggable cost backends for batched cheap-objective evaluation.

The search layers never touch Eq. 1-4 (or roofline) math directly: they hand
a :class:`~repro.core.genome.PopulationEncoding` to a :class:`CostBackend`
and get back an objective matrix whose columns are described by the
backend's :class:`~repro.core.objective_schema.ObjectiveSchema` (DESIGN.md
§2, §10).  Implementations:

* :class:`FPGAAnalyticBackend` — the paper's analytic Eq. 1-4 models,
  vectorized over the population, for any :class:`HardwareProfile` (the four
  calibrated profiles in :mod:`repro.core.hw_model`).  ``(N, 7)`` in
  ``CHEAP_NAMES`` order, platform-tagged with the profile name.
* :class:`TPURooflineBackend` — the three-term v5e roofline.  Besides scoring
  genomes it owns the shared :meth:`~TPURooflineBackend.roofline_terms`
  helper consumed by :mod:`repro.core.tpu_codesign` and
  :mod:`repro.launch.roofline`, so the pod-scale roofline math lives in
  exactly one place.
* :class:`MultiPlatformBackend` — a composite that scores one population
  against K member backends in a single call, sharing the decode/tabulation
  and the platform-independent Eq. 1-4 intermediates
  (:class:`~repro.core.hw_model.SharedPopulationEval`); the result is an
  ``(N, K*7)`` matrix whose schema carries per-platform column groups —
  the engine behind cross-platform Pareto fronts.
"""
from __future__ import annotations

import inspect
from typing import Dict, List, Optional, Protocol, Sequence, Union, \
    runtime_checkable

import numpy as np

from repro.core.genome import Genome, PopulationEncoding
from repro.core.hw_model import (
    FPGA_ZU,
    PROFILES,
    TPU_V5E,
    HardwareProfile,
    RooflineTerms,
    SharedPopulationEval,
    batch_estimate,
    population_layer_costs,
    roofline,
)
from repro.core.objective_schema import ObjectiveSchema
from repro.core.search_space import DEFAULT_SPACE, SearchSpace


@runtime_checkable
class CostBackend(Protocol):
    """Scores populations analytically — the search's hot loop."""

    name: str

    def evaluate_batch(self, enc: PopulationEncoding, *,
                       space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        """``(N, C)`` cheap-objective matrix (``schema`` column order)."""
        ...

    def evaluate(self, g: Genome, *,
                 space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        """``(C,)`` objectives for a single genome."""
        ...


def backend_schema(be: CostBackend) -> ObjectiveSchema:
    """The backend's cheap-column schema.

    Backends written before the schema layer (or third-party ones) are
    adopted as one platform of 7 ``CHEAP_NAMES`` columns tagged with their
    ``platform`` attribute (falling back to ``name``).
    """
    schema = getattr(be, "schema", None)
    if schema is not None:
        return schema
    return ObjectiveSchema.cheap(getattr(be, "platform", be.name))


class FPGAAnalyticBackend:
    """Vectorized Eq. 1-4 evaluation against one hardware profile.

    Bit-for-bit consistent with the scalar ``estimate``/``cheap_objectives``
    reference path (tests/test_cost_backend_parity.py), with or without a
    shared evaluation context.
    """

    def __init__(self, profile: HardwareProfile = FPGA_ZU):
        self.profile = profile
        self.platform = profile.name
        self.name = f"fpga_analytic[{profile.name}]"
        self.schema = ObjectiveSchema.cheap(self.platform)

    def evaluate_batch(self, enc: PopulationEncoding, *,
                       space: SearchSpace = DEFAULT_SPACE,
                       shared: Optional[SharedPopulationEval] = None
                       ) -> np.ndarray:
        if shared is None:
            shared = SharedPopulationEval(population_layer_costs(enc, space))
        lo = batch_estimate(shared.costs, strategy="min",
                            profile=self.profile, shared=shared)
        hi = batch_estimate(shared.costs, strategy="max",
                            profile=self.profile, shared=shared)
        return np.stack([
            lo.p_total_w, hi.p_total_w,
            lo.e_total_j, hi.e_total_j,
            lo.latency_s, hi.latency_s,
            lo.params.astype(np.float64),
        ], axis=1)

    def evaluate(self, g: Genome, *,
                 space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        enc = PopulationEncoding.from_genomes([g])
        return self.evaluate_batch(enc, space=space)[0]


class TPURooflineBackend:
    """Three-term roofline cost model (v5e constants) as a CostBackend.

    For genome scoring the mapping is deliberately simple (same altitude as
    Eq. 1-4 — good enough to rank candidates, DESIGN.md §2): the ``min``-α
    column models a fully folded datapath (one MAC per cycle); the ``max``-α
    column is the roofline bound over compute and HBM terms, with the implied
    parallelism driving the power model.
    """

    name = "tpu_roofline"
    platform = "tpu_roofline"

    def __init__(self, profile: HardwareProfile = TPU_V5E):
        self.profile = profile
        self.schema = ObjectiveSchema.cheap(self.platform)

    # ---- the shared pod-roofline helper (codesign + launch consume this)
    def roofline_terms(self, flops: float, bytes_hbm: float,
                       bytes_collective: float, chips: int) -> RooflineTerms:
        return roofline(flops, bytes_hbm, bytes_collective, chips)

    # ---- genome scoring --------------------------------------------------
    def evaluate_batch(self, enc: PopulationEncoding, *,
                       space: SearchSpace = DEFAULT_SPACE,
                       shared: Optional[SharedPopulationEval] = None
                       ) -> np.ndarray:
        if shared is None:
            shared = SharedPopulationEval(population_layer_costs(enc, space))
        costs = shared.costs
        macs = shared.mac_totals.astype(np.float64)
        params = shared.param_totals
        act_vals = np.where(costs.valid, costs.out_len * costs.out_channels,
                            0).sum(axis=1).astype(np.float64)
        w_bits = np.asarray(space.weight_bits, np.float64)[enc.w_bits]
        a_bits = np.asarray(space.act_bits, np.float64)[enc.a_bits]
        bytes_hbm = params * w_bits / 8.0 + act_vals * a_bits / 8.0

        p = self.profile
        lat_min = macs / p.f_clk  # fully folded: one MAC per cycle
        terms = self.roofline_terms(2.0 * macs, bytes_hbm, 0.0, chips=1)
        lat_max = np.maximum(terms.compute_s, terms.memory_s)
        alpha_eff = np.clip(lat_min / np.maximum(lat_max, 1e-30),
                            1.0, float(p.alpha_cap))
        p_min = np.full(len(enc),
                        p.p_static + p.p_idle_unit + p.p_calc_unit)
        p_max = p.p_static + alpha_eff * (p.p_idle_unit + p.p_calc_unit)
        return np.stack([
            p_min, p_max,
            lat_min * p_min, lat_max * p_max,
            lat_min, lat_max,
            params.astype(np.float64),
        ], axis=1)

    def evaluate(self, g: Genome, *,
                 space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        enc = PopulationEncoding.from_genomes([g])
        return self.evaluate_batch(enc, space=space)[0]


class MultiPlatformBackend:
    """Score one population against K backends in a single call.

    The composite decodes and tabulates the population exactly once
    (:class:`~repro.core.hw_model.SharedPopulationEval`) and hands the
    shared context to each member, so the per-member marginal cost is just
    the platform-specific Eq. 1-4 / roofline arithmetic — member columns
    are bit-identical to evaluating that member alone
    (tests/test_multi_platform.py).  The ``(N, K*7)`` result's ``schema``
    concatenates the members' platform-tagged column groups.
    """

    def __init__(self, backends: Sequence[BackendSpec]):
        if not backends:
            raise ValueError("MultiPlatformBackend needs >= 1 backend")
        members: List[CostBackend] = []
        for spec in backends:
            be = get_backend(spec)
            if isinstance(be, MultiPlatformBackend):
                members.extend(be.backends)   # flatten nested composites
            else:
                members.append(be)
        self.backends: tuple = tuple(members)
        # third-party backends may implement only the bare protocol
        # signature — the shared context is an optimization, not a contract
        self._accepts_shared = tuple(
            "shared" in inspect.signature(be.evaluate_batch).parameters
            for be in self.backends)
        # raises on duplicate platform tags — one column group per platform
        self.schema = ObjectiveSchema.concat(
            [backend_schema(be) for be in self.backends])
        self.name = "multi[" + "+".join(self.schema.platforms) + "]"

    def __len__(self) -> int:
        return len(self.backends)

    def evaluate_batch(self, enc: PopulationEncoding, *,
                       space: SearchSpace = DEFAULT_SPACE,
                       shared: Optional[SharedPopulationEval] = None
                       ) -> np.ndarray:
        if shared is None:
            shared = SharedPopulationEval(population_layer_costs(enc, space))
        return np.concatenate(
            [be.evaluate_batch(enc, space=space, shared=shared) if ok
             else be.evaluate_batch(enc, space=space)
             for be, ok in zip(self.backends, self._accepts_shared)],
            axis=1)

    def evaluate(self, g: Genome, *,
                 space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        enc = PopulationEncoding.from_genomes([g])
        return self.evaluate_batch(enc, space=space)[0]


# Shared singleton: every pod-roofline consumer routes through this object.
TPU_ROOFLINE = TPURooflineBackend()

_ANALYTIC_CACHE: Dict[str, FPGAAnalyticBackend] = {}

BackendSpec = Union["CostBackend", HardwareProfile, str,
                    Sequence[Union["CostBackend", HardwareProfile, str]]]


def get_backend(spec: BackendSpec) -> CostBackend:
    """Resolve a backend instance, profile, name, or sequence thereof.

    Accepts a ready CostBackend (returned as-is), a
    :class:`HardwareProfile` (wrapped in a cached FPGAAnalyticBackend), a
    string (one of the profile names in ``PROFILES`` or ``"tpu_roofline"``),
    or a sequence of any of those (wrapped in a
    :class:`MultiPlatformBackend` — the multi-platform scoring pipeline).
    """
    if isinstance(spec, HardwareProfile):
        be = _ANALYTIC_CACHE.get(spec.name)
        if be is None or be.profile is not spec:
            be = FPGAAnalyticBackend(spec)
            _ANALYTIC_CACHE[spec.name] = be
        return be
    if isinstance(spec, str):
        if spec == TPU_ROOFLINE.name:
            return TPU_ROOFLINE
        if spec in PROFILES:
            return get_backend(PROFILES[spec])
        raise KeyError(f"unknown cost backend {spec!r} "
                       f"(profiles: {sorted(PROFILES)}, tpu_roofline)")
    if isinstance(spec, (list, tuple)):
        return MultiPlatformBackend(spec)
    if isinstance(spec, CostBackend):  # runtime-checkable structural match
        return spec
    raise TypeError(f"cannot resolve cost backend from {type(spec).__name__}")
