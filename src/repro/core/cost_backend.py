"""Pluggable cost backends for batched cheap-objective evaluation.

The search layers never touch Eq. 1-4 (or roofline) math directly: they hand
a :class:`~repro.core.genome.PopulationEncoding` to a :class:`CostBackend`
and get back an ``(N, 7)`` objective matrix in ``CHEAP_NAMES`` order
(DESIGN.md §2).  Two implementations ship:

* :class:`FPGAAnalyticBackend` — the paper's analytic Eq. 1-4 models,
  vectorized over the population, for any :class:`HardwareProfile` (the four
  calibrated profiles in :mod:`repro.core.hw_model`).
* :class:`TPURooflineBackend` — the three-term v5e roofline.  Besides scoring
  genomes it owns the shared :meth:`~TPURooflineBackend.roofline_terms`
  helper consumed by :mod:`repro.core.tpu_codesign` and
  :mod:`repro.launch.roofline`, so the pod-scale roofline math lives in
  exactly one place.
"""
from __future__ import annotations

from typing import Dict, Protocol, Union, runtime_checkable

import numpy as np

from repro.core.genome import Genome, PopulationEncoding
from repro.core.hw_model import (
    FPGA_ZU,
    PROFILES,
    TPU_V5E,
    HardwareProfile,
    RooflineTerms,
    batch_estimate,
    population_layer_costs,
    roofline,
)
from repro.core.search_space import DEFAULT_SPACE, SearchSpace


@runtime_checkable
class CostBackend(Protocol):
    """Scores populations analytically — the search's hot loop."""

    name: str

    def evaluate_batch(self, enc: PopulationEncoding, *,
                       space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        """``(N, 7)`` cheap-objective matrix (``CHEAP_NAMES`` order)."""
        ...

    def evaluate(self, g: Genome, *,
                 space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        """``(7,)`` objectives for a single genome."""
        ...


class FPGAAnalyticBackend:
    """Vectorized Eq. 1-4 evaluation against one hardware profile.

    Bit-for-bit consistent with the scalar ``estimate``/``cheap_objectives``
    reference path (tests/test_cost_backend_parity.py).
    """

    def __init__(self, profile: HardwareProfile = FPGA_ZU):
        self.profile = profile
        self.name = f"fpga_analytic[{profile.name}]"

    def evaluate_batch(self, enc: PopulationEncoding, *,
                       space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        costs = population_layer_costs(enc, space)
        lo = batch_estimate(costs, strategy="min", profile=self.profile)
        hi = batch_estimate(costs, strategy="max", profile=self.profile)
        return np.stack([
            lo.p_total_w, hi.p_total_w,
            lo.e_total_j, hi.e_total_j,
            lo.latency_s, hi.latency_s,
            lo.params.astype(np.float64),
        ], axis=1)

    def evaluate(self, g: Genome, *,
                 space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        enc = PopulationEncoding.from_genomes([g])
        return self.evaluate_batch(enc, space=space)[0]


class TPURooflineBackend:
    """Three-term roofline cost model (v5e constants) as a CostBackend.

    For genome scoring the mapping is deliberately simple (same altitude as
    Eq. 1-4 — good enough to rank candidates, DESIGN.md §2): the ``min``-α
    column models a fully folded datapath (one MAC per cycle); the ``max``-α
    column is the roofline bound over compute and HBM terms, with the implied
    parallelism driving the power model.
    """

    name = "tpu_roofline"

    def __init__(self, profile: HardwareProfile = TPU_V5E):
        self.profile = profile

    # ---- the shared pod-roofline helper (codesign + launch consume this)
    def roofline_terms(self, flops: float, bytes_hbm: float,
                       bytes_collective: float, chips: int) -> RooflineTerms:
        return roofline(flops, bytes_hbm, bytes_collective, chips)

    # ---- genome scoring --------------------------------------------------
    def evaluate_batch(self, enc: PopulationEncoding, *,
                       space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        costs = population_layer_costs(enc, space)
        macs = np.where(costs.valid, costs.total_macs, 0).sum(axis=1) \
            .astype(np.float64)
        params = np.where(costs.valid, costs.params, 0).sum(axis=1)
        act_vals = np.where(costs.valid, costs.out_len * costs.out_channels,
                            0).sum(axis=1).astype(np.float64)
        w_bits = np.asarray(space.weight_bits, np.float64)[enc.w_bits]
        a_bits = np.asarray(space.act_bits, np.float64)[enc.a_bits]
        bytes_hbm = params * w_bits / 8.0 + act_vals * a_bits / 8.0

        p = self.profile
        lat_min = macs / p.f_clk  # fully folded: one MAC per cycle
        terms = self.roofline_terms(2.0 * macs, bytes_hbm, 0.0, chips=1)
        lat_max = np.maximum(terms.compute_s, terms.memory_s)
        alpha_eff = np.clip(lat_min / np.maximum(lat_max, 1e-30),
                            1.0, float(p.alpha_cap))
        p_min = np.full(len(enc),
                        p.p_static + p.p_idle_unit + p.p_calc_unit)
        p_max = p.p_static + alpha_eff * (p.p_idle_unit + p.p_calc_unit)
        return np.stack([
            p_min, p_max,
            lat_min * p_min, lat_max * p_max,
            lat_min, lat_max,
            params.astype(np.float64),
        ], axis=1)

    def evaluate(self, g: Genome, *,
                 space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        enc = PopulationEncoding.from_genomes([g])
        return self.evaluate_batch(enc, space=space)[0]


# Shared singleton: every pod-roofline consumer routes through this object.
TPU_ROOFLINE = TPURooflineBackend()

_ANALYTIC_CACHE: Dict[str, FPGAAnalyticBackend] = {}

BackendSpec = Union[CostBackend, HardwareProfile, str]


def get_backend(spec: BackendSpec) -> CostBackend:
    """Resolve a backend instance, profile, or name to a CostBackend.

    Accepts a ready CostBackend (returned as-is), a
    :class:`HardwareProfile` (wrapped in a cached FPGAAnalyticBackend), or a
    string: one of the profile names in ``PROFILES`` or ``"tpu_roofline"``.
    """
    if isinstance(spec, HardwareProfile):
        be = _ANALYTIC_CACHE.get(spec.name)
        if be is None or be.profile is not spec:
            be = FPGAAnalyticBackend(spec)
            _ANALYTIC_CACHE[spec.name] = be
        return be
    if isinstance(spec, str):
        if spec == TPU_ROOFLINE.name:
            return TPU_ROOFLINE
        if spec in PROFILES:
            return get_backend(PROFILES[spec])
        raise KeyError(f"unknown cost backend {spec!r} "
                       f"(profiles: {sorted(PROFILES)}, tpu_roofline)")
    if isinstance(spec, CostBackend):  # runtime-checkable structural match
        return spec
    raise TypeError(f"cannot resolve cost backend from {type(spec).__name__}")
