"""Genetic encoding with dormant genes (paper §III-A, via Suganuma et al. '17).

Cartesian-genetic-programming-style linear encoding: the genome holds
``max_depth`` node slots; each node has a *function gene* (index into the op
table) and a *connection gene* (which earlier node, or the input, feeds it).
The phenotype is decoded by walking back from the *output gene* — nodes not
on that path are **dormant**: they are carried (and mutated) silently and can
be re-activated by a later connection-gene mutation.  This is the paper's
"concept of dormant genes" that boosts the evolutionary search.

Additional genes: quantization (weights / activations / input) and input
decimation, reflecting the paper's hardware-aware search space.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.hwlib.layers import LayerSpec, OpCostTable, out_shape
from repro.hwlib.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class Genome:
    """Immutable genome. All gene values are small ints (numpy-friendly)."""

    op_genes: Tuple[int, ...]      # len == max_depth, values in [0, n_ops)
    conn_genes: Tuple[int, ...]    # node i takes input from conn[i] in [0, i]
    out_gene: int                  # node (1-indexed) feeding the head
    w_bits_gene: int
    a_bits_gene: int
    i_bits_gene: int
    dec_gene: int                  # input decimation index

    # ---------------------------------------------------------------- decode
    def active_nodes(self) -> List[int]:
        """Indices (0-based) of nodes on the input→output path, in order."""
        path: List[int] = []
        node = self.out_gene  # 1-indexed; 0 means "the input" (invalid here)
        while node > 0:
            path.append(node - 1)
            node = self.conn_genes[node - 1]
        return list(reversed(path))

    def phenotype(self, space: SearchSpace = DEFAULT_SPACE) -> List[LayerSpec]:
        """The decoded topology: active ops + the fixed GAP/dense head."""
        specs = [space.ops[self.op_genes[i]] for i in self.active_nodes()]
        specs.extend(space.head_specs())
        return specs

    def depth(self) -> int:
        """Searchable depth (final GAP+dense excluded, as in the paper)."""
        return len(self.active_nodes())

    def quant(self, space: SearchSpace = DEFAULT_SPACE) -> QuantConfig:
        return space.quant_config(self.w_bits_gene, self.a_bits_gene,
                                  self.i_bits_gene)

    def input_length(self, space: SearchSpace = DEFAULT_SPACE) -> int:
        return space.input_length(self.dec_gene)

    def phenotype_hash(self, space: SearchSpace = DEFAULT_SPACE) -> str:
        """Hash of the *expressed* genes only — mutations that touch dormant
        genes leave this unchanged, letting the search skip re-evaluation
        (the dormant-gene shortcut)."""
        parts = [s.short() for s in self.phenotype(space)]
        parts.append(self.quant(space).short())
        parts.append(f"dec{self.dec_gene}")
        return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]

    def is_valid(self, space: SearchSpace = DEFAULT_SPACE) -> bool:
        """Depth bounds + every layer's spatial shape stays >= 1."""
        d = self.depth()
        if not (space.min_depth <= d <= space.max_depth):
            return False
        try:
            shapes = decode_shapes(self, space)
        except ValueError:
            return False
        return all(l >= 1 for l, _ in shapes)


def decode_shapes(g: Genome, space: SearchSpace = DEFAULT_SPACE
                  ) -> List[Tuple[int, int]]:
    """(length, channels) after each phenotype layer."""
    l, c = g.input_length(space), 2
    shapes = []
    for spec in g.phenotype(space):
        l, c = out_shape(spec, l, c)
        shapes.append((l, c))
    return shapes


# ---------------------------------------------------------------------------
# Batched population encoding
# ---------------------------------------------------------------------------

# Sentinel op ids for the fixed head appended to every phenotype.  The op
# table proper occupies ids [0, n_ops); the head layers get the next two ids
# so a whole phenotype is a single integer array (see OpCostTable.for_space).
GAP_OP_OFFSET = 0    # id == space.n_ops
DENSE_OP_OFFSET = 1  # id == space.n_ops + 1


@dataclasses.dataclass(frozen=True)
class PopulationEncoding:
    """A whole population as stacked integer gene arrays.

    Column-for-column the same genes as :class:`Genome`, but shaped ``(N, D)``
    /``(N,)`` so the population can be decoded and costed with vectorized
    numpy instead of per-genome Python loops (DESIGN.md §2).  The encoding is
    immutable; arrays must not be written through.
    """

    op: np.ndarray       # (N, D) int64 — function genes
    conn: np.ndarray     # (N, D) int64 — connection genes
    out: np.ndarray      # (N,)  int64 — output genes (1-indexed)
    w_bits: np.ndarray   # (N,)  int64
    a_bits: np.ndarray   # (N,)  int64
    i_bits: np.ndarray   # (N,)  int64
    dec: np.ndarray      # (N,)  int64

    def __len__(self) -> int:
        return self.op.shape[0]

    @property
    def max_depth(self) -> int:
        return self.op.shape[1]

    @classmethod
    def from_genomes(cls, genomes: Sequence[Genome]) -> "PopulationEncoding":
        if not genomes:
            raise ValueError("empty population")
        return cls(
            op=np.asarray([g.op_genes for g in genomes], dtype=np.int64),
            conn=np.asarray([g.conn_genes for g in genomes], dtype=np.int64),
            out=np.asarray([g.out_gene for g in genomes], dtype=np.int64),
            w_bits=np.asarray([g.w_bits_gene for g in genomes], dtype=np.int64),
            a_bits=np.asarray([g.a_bits_gene for g in genomes], dtype=np.int64),
            i_bits=np.asarray([g.i_bits_gene for g in genomes], dtype=np.int64),
            dec=np.asarray([g.dec_gene for g in genomes], dtype=np.int64),
        )

    def take(self, idx) -> "PopulationEncoding":
        """Row-gather a sub-population (fancy index or boolean mask)."""
        idx = np.asarray(idx)
        return PopulationEncoding(
            op=self.op[idx], conn=self.conn[idx], out=self.out[idx],
            w_bits=self.w_bits[idx], a_bits=self.a_bits[idx],
            i_bits=self.i_bits[idx], dec=self.dec[idx])

    @classmethod
    def concatenate(cls, parts: Sequence["PopulationEncoding"]
                    ) -> "PopulationEncoding":
        parts = [p for p in parts if len(p)]
        if not parts:
            raise ValueError("empty concatenation")
        if len(parts) == 1:
            return parts[0]
        return cls(*(np.concatenate([getattr(p, f.name) for p in parts])
                     for f in dataclasses.fields(cls)))

    def genome(self, i: int) -> Genome:
        return Genome(
            op_genes=tuple(int(v) for v in self.op[i]),
            conn_genes=tuple(int(v) for v in self.conn[i]),
            out_gene=int(self.out[i]),
            w_bits_gene=int(self.w_bits[i]),
            a_bits_gene=int(self.a_bits[i]),
            i_bits_gene=int(self.i_bits[i]),
            dec_gene=int(self.dec[i]),
        )

    def to_genomes(self) -> List[Genome]:
        return [self.genome(i) for i in range(len(self))]

    # ------------------------------------------------------------ decoding
    def decode_paths(self) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized active-path walk for the whole population.

        Returns ``(path, depth)``: ``path`` is ``(N, D)`` with the 0-based
        active node indices in forward (input→output) order, ``-1``-padded;
        ``depth`` is ``(N,)``.  Connection genes satisfy ``conn[i] <= i`` so
        the backward walk terminates within ``D`` steps for every genome.
        """
        n, d = self.op.shape
        ar = np.arange(n)
        rev = np.full((n, d), -1, dtype=np.int64)
        node = self.out.copy()  # 1-indexed; 0 means "the input"
        for t in range(d):
            alive = node > 0
            idx = np.where(alive, node - 1, 0)
            rev[:, t] = np.where(alive, idx, -1)
            node = np.where(alive, self.conn[ar, idx], 0)
        depth = (rev >= 0).sum(axis=1)
        # reverse each row's valid prefix to get forward order
        src = depth[:, None] - 1 - np.arange(d)[None, :]
        fwd = np.take_along_axis(rev, np.maximum(src, 0), axis=1)
        return np.where(src >= 0, fwd, -1), depth

    def phenotype_ops(self, space: SearchSpace = DEFAULT_SPACE
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Padded phenotype op-id arrays for the whole population.

        Returns ``(ops, valid, depth)``: ``ops`` is ``(N, D+2)`` — the active
        ops in forward order followed by the GAP and DENSE head sentinels
        (ids ``n_ops`` and ``n_ops + 1``), ``-1``-padded; ``valid`` is the
        matching boolean mask.
        """
        path, depth = self.decode_paths()
        n, d = self.op.shape
        ops = np.full((n, d + 2), -1, dtype=np.int64)
        gathered = np.take_along_axis(self.op, np.maximum(path, 0), axis=1)
        ops[:, :d] = np.where(path >= 0, gathered, -1)
        ar = np.arange(n)
        ops[ar, depth] = space.n_ops + GAP_OP_OFFSET
        ops[ar, depth + 1] = space.n_ops + DENSE_OP_OFFSET
        return ops, ops >= 0, depth

    def input_lengths(self, space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
        table = np.asarray([space.input_length(i)
                            for i in range(len(space.input_decimations))],
                           dtype=np.int64)
        return table[self.dec]

    def batch_phenotype_hash(self, space: SearchSpace = DEFAULT_SPACE
                             ) -> List[str]:
        """Per-genome expressed-gene hashes, identical to
        :meth:`Genome.phenotype_hash` (the dormant-gene dedup key)."""
        ops, _, _ = self.phenotype_ops(space)
        shorts = [s.short() for s in space.ops]
        shorts += [s.short() for s in space.head_specs()]
        hashes = []
        for i in range(len(self)):
            parts = [shorts[o] for o in ops[i] if o >= 0]
            parts.append(space.quant_config(int(self.w_bits[i]),
                                            int(self.a_bits[i]),
                                            int(self.i_bits[i])).short())
            parts.append(f"dec{int(self.dec[i])}")
            hashes.append(hashlib.sha1(
                "|".join(parts).encode()).hexdigest()[:16])
        return hashes


# ---------------------------------------------------------------------------
# Random construction / mutation / crossover
# ---------------------------------------------------------------------------

def random_genome(rng: np.random.Generator,
                  space: SearchSpace = DEFAULT_SPACE,
                  max_tries: int = 200) -> Genome:
    for _ in range(max_tries):
        n = space.max_depth
        op = tuple(int(v) for v in rng.integers(0, space.n_ops, n))
        # chain-biased connections: mostly the previous node, sometimes a skip
        conn = []
        for i in range(n):
            conn.append(int(rng.integers(0, i + 1)) if rng.random() < 0.25
                        else i)
        g = Genome(
            op_genes=op,
            conn_genes=tuple(conn),
            out_gene=int(rng.integers(space.min_depth, n + 1)),
            w_bits_gene=int(rng.integers(0, len(space.weight_bits))),
            a_bits_gene=int(rng.integers(0, len(space.act_bits))),
            i_bits_gene=int(rng.integers(0, len(space.input_bits))),
            dec_gene=int(rng.integers(0, len(space.input_decimations))),
        )
        if g.is_valid(space):
            return g
    raise RuntimeError("could not sample a valid genome")


def mutate(
    g: Genome,
    rng: np.random.Generator,
    space: SearchSpace = DEFAULT_SPACE,
    rate: float = 0.1,
    force_active_change: bool = True,
    max_tries: int = 200,
) -> Genome:
    """Point mutation. With ``force_active_change`` the mutation loop repeats
    until the *phenotype* changes (Suganuma's forced mutation for children);
    without it, a mutation may hit only dormant genes (neutral drift)."""
    base_hash = g.phenotype_hash(space)
    for _ in range(max_tries):
        op = list(g.op_genes)
        conn = list(g.conn_genes)
        out = g.out_gene
        wq, aq, iq, dq = (g.w_bits_gene, g.a_bits_gene, g.i_bits_gene,
                          g.dec_gene)
        for i in range(len(op)):
            if rng.random() < rate:
                op[i] = int(rng.integers(0, space.n_ops))
            if rng.random() < rate:
                conn[i] = int(rng.integers(0, i + 1))
        if rng.random() < rate:
            out = int(rng.integers(1, len(op) + 1))
        if rng.random() < rate:
            wq = int(rng.integers(0, len(space.weight_bits)))
        if rng.random() < rate:
            aq = int(rng.integers(0, len(space.act_bits)))
        if rng.random() < rate:
            iq = int(rng.integers(0, len(space.input_bits)))
        if rng.random() < rate:
            dq = int(rng.integers(0, len(space.input_decimations)))
        cand = Genome(tuple(op), tuple(conn), out, wq, aq, iq, dq)
        if not cand.is_valid(space):
            continue
        if force_active_change and cand.phenotype_hash(space) == base_hash:
            continue  # mutation was neutral (dormant genes only) — retry
        return cand
    return g  # give up: return parent unchanged


def crossover(a: Genome, b: Genome, rng: np.random.Generator,
              space: SearchSpace = DEFAULT_SPACE,
              max_tries: int = 50) -> Genome:
    """Single-point crossover over the node slots (biology-inspired ops the
    genetic encoding enables, paper §II-A)."""
    n = len(a.op_genes)
    for _ in range(max_tries):
        cut = int(rng.integers(1, n))
        op = a.op_genes[:cut] + b.op_genes[cut:]
        conn = a.conn_genes[:cut] + b.conn_genes[cut:]
        donor = a if rng.random() < 0.5 else b
        cand = Genome(op, conn, donor.out_gene, donor.w_bits_gene,
                      donor.a_bits_gene, donor.i_bits_gene, donor.dec_gene)
        if cand.is_valid(space):
            return cand
    return a


# ---------------------------------------------------------------------------
# Vectorized genetic operators (DESIGN.md §8)
#
# Batch counterparts of random_genome / mutate / crossover / is_valid over a
# whole PopulationEncoding.  Each is a rejection sampler drawing candidate
# gene arrays from exactly the same per-genome proposal distribution as its
# scalar reference (the RNG is consumed in a different order, so streams
# differ, but the output *distributions* match — tested under fixed seeds in
# tests/test_genome_batch_ops.py).  Genomes still unresolved after max_tries
# rounds fall back to their input row, like the scalar operators.
# ---------------------------------------------------------------------------

_COST_TABLE_CACHE: dict = {}


def _cost_table(space: SearchSpace) -> OpCostTable:
    """Op catalogue + head sentinels as an OpCostTable, cached per space."""
    table = _COST_TABLE_CACHE.get(space)
    if table is None:
        table = OpCostTable.from_specs(tuple(space.ops) + space.head_specs())
        _COST_TABLE_CACHE[space] = table
    return table


def is_valid_batch(enc: PopulationEncoding,
                   space: SearchSpace = DEFAULT_SPACE) -> np.ndarray:
    """Vectorized :meth:`Genome.is_valid`: ``(N,)`` bool.

    Depth bounds plus the batched shape decode: a genome is valid iff every
    phenotype layer's input window fits (``in_len >= kernel`` for convs,
    ``in_len >= stride`` for pools — the conditions under which the scalar
    ``out_shape`` raises), which also guarantees every spatial shape >= 1.
    """
    ops, valid, depth = enc.phenotype_ops(space)
    ok = (depth >= space.min_depth) & (depth <= space.max_depth)
    table = _cost_table(space)
    safe = np.maximum(ops, 0)
    ek = table.ek_const[safe]
    ekl = table.ek_is_len[safe]
    es = table.es[safe]
    # only the length trajectory matters: validity never depends on channels
    length = enc.input_lengths(space)
    for t in range(ops.shape[1]):
        window = ek[:, t] + ekl[:, t] * length
        v = valid[:, t]
        ok &= ~v | (length >= window)
        length = np.where(v, (length - window) // es[:, t] + 1, length)
    return ok


def random_population(rng: np.random.Generator, n: int,
                      space: SearchSpace = DEFAULT_SPACE,
                      max_tries: int = 200) -> PopulationEncoding:
    """Vectorized :func:`random_genome`: ``n`` valid genomes in a handful of
    array draws (same chain-biased connection prior, same rejection rule)."""
    d = space.max_depth
    conn_hi = np.arange(1, d + 1)
    chain = np.arange(d)
    parts: List[PopulationEncoding] = []
    got = 0
    for _ in range(max_tries):
        need = n - got
        if need <= 0:
            break
        cand = PopulationEncoding(
            op=rng.integers(0, space.n_ops, (need, d)),
            conn=np.where(rng.random((need, d)) < 0.25,
                          rng.integers(0, conn_hi, (need, d)),
                          chain[None, :]),
            out=rng.integers(space.min_depth, d + 1, need),
            w_bits=rng.integers(0, len(space.weight_bits), need),
            a_bits=rng.integers(0, len(space.act_bits), need),
            i_bits=rng.integers(0, len(space.input_bits), need),
            dec=rng.integers(0, len(space.input_decimations), need),
        )
        ok = is_valid_batch(cand, space)
        if ok.any():
            parts.append(cand.take(np.nonzero(ok)[0]))
            got += int(ok.sum())
    if got < n:
        raise RuntimeError("could not sample a valid population")
    return PopulationEncoding.concatenate(parts).take(np.arange(n))


def mutate_batch(
    enc: PopulationEncoding,
    rng: np.random.Generator,
    space: SearchSpace = DEFAULT_SPACE,
    rate: float = 0.1,
    force_active_change: bool = True,
    max_tries: int = 200,
) -> PopulationEncoding:
    """Vectorized :func:`mutate` over a whole population.

    Every genome independently redraws (from its own parent, like the scalar
    retry loop) until the draw is valid — and, with ``force_active_change``,
    until its phenotype hash differs from the parent's (Suganuma's forced
    mutation).  Rows unresolved after ``max_tries`` rounds stay the parent.
    """
    n, d = enc.op.shape
    base_hash = np.asarray(enc.batch_phenotype_hash(space), dtype=object) \
        if force_active_change else None
    out_enc = {f.name: getattr(enc, f.name).copy()
               for f in dataclasses.fields(PopulationEncoding)}
    conn_hi = np.arange(1, d + 1)
    pending = np.arange(n)
    for _ in range(max_tries):
        if not len(pending):
            break
        m = len(pending)
        op = enc.op[pending].copy()
        conn = enc.conn[pending].copy()
        mask = rng.random((m, d)) < rate
        op[mask] = rng.integers(0, space.n_ops, int(mask.sum()))
        conn = np.where(rng.random((m, d)) < rate,
                        rng.integers(0, conn_hi, (m, d)), conn)
        cand = PopulationEncoding(
            op=op, conn=conn,
            out=np.where(rng.random(m) < rate,
                         rng.integers(1, d + 1, m), enc.out[pending]),
            w_bits=np.where(rng.random(m) < rate,
                            rng.integers(0, len(space.weight_bits), m),
                            enc.w_bits[pending]),
            a_bits=np.where(rng.random(m) < rate,
                            rng.integers(0, len(space.act_bits), m),
                            enc.a_bits[pending]),
            i_bits=np.where(rng.random(m) < rate,
                            rng.integers(0, len(space.input_bits), m),
                            enc.i_bits[pending]),
            dec=np.where(rng.random(m) < rate,
                         rng.integers(0, len(space.input_decimations), m),
                         enc.dec[pending]),
        )
        ok = is_valid_batch(cand, space)
        if force_active_change and ok.any():
            ok_rows = np.nonzero(ok)[0]
            new_hash = np.asarray(
                cand.take(ok_rows).batch_phenotype_hash(space), dtype=object)
            ok[ok_rows] = new_hash != base_hash[pending[ok_rows]]
        acc = pending[ok]
        for name in out_enc:
            out_enc[name][acc] = getattr(cand, name)[ok]
        pending = pending[~ok]
    return PopulationEncoding(**out_enc)


def crossover_batch(a: PopulationEncoding, b: PopulationEncoding,
                    rng: np.random.Generator,
                    space: SearchSpace = DEFAULT_SPACE,
                    max_tries: int = 50) -> PopulationEncoding:
    """Vectorized :func:`crossover` of row-aligned parent populations:
    per-row single-point cut over the node slots, quant/output genes from a
    fair-coin donor, rejection until valid (fallback: parent ``a``)."""
    n, d = a.op.shape
    out_enc = {f.name: getattr(a, f.name).copy()
               for f in dataclasses.fields(PopulationEncoding)}
    pending = np.arange(n)
    for _ in range(max_tries):
        if not len(pending):
            break
        m = len(pending)
        keep_a = np.arange(d)[None, :] < rng.integers(1, d, m)[:, None]
        donor_b = rng.random(m) >= 0.5

        def pick(name, mask=donor_b):
            av, bv = getattr(a, name)[pending], getattr(b, name)[pending]
            return np.where(mask, bv, av)

        cand = PopulationEncoding(
            op=pick("op", ~keep_a), conn=pick("conn", ~keep_a),
            out=pick("out"), w_bits=pick("w_bits"), a_bits=pick("a_bits"),
            i_bits=pick("i_bits"), dec=pick("dec"))
        ok = is_valid_batch(cand, space)
        acc = pending[ok]
        for name in out_enc:
            out_enc[name][acc] = getattr(cand, name)[ok]
        pending = pending[~ok]
    return PopulationEncoding(**out_enc)


def describe(g: Genome, space: SearchSpace = DEFAULT_SPACE) -> str:
    """Fig.-4-style textual rendering of a genome's phenotype."""
    lines = [f"Input ({g.input_length(space)},2)  quant={g.quant(space).short()}"]
    l, c = g.input_length(space), 2
    from repro.hwlib.layers import layer_cost
    for spec in g.phenotype(space):
        cost = layer_cost(spec, l, c)
        l, c = cost.out_len, cost.out_channels
        lines.append(f"  {spec.short():>12s} [{cost.params}] ({l},{c})")
    return "\n".join(lines)
