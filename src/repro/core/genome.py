"""Genetic encoding with dormant genes (paper §III-A, via Suganuma et al. '17).

Cartesian-genetic-programming-style linear encoding: the genome holds
``max_depth`` node slots; each node has a *function gene* (index into the op
table) and a *connection gene* (which earlier node, or the input, feeds it).
The phenotype is decoded by walking back from the *output gene* — nodes not
on that path are **dormant**: they are carried (and mutated) silently and can
be re-activated by a later connection-gene mutation.  This is the paper's
"concept of dormant genes" that boosts the evolutionary search.

Additional genes: quantization (weights / activations / input) and input
decimation, reflecting the paper's hardware-aware search space.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.hwlib.layers import DENSE, GLOBALPOOL, LayerSpec, out_shape
from repro.hwlib.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class Genome:
    """Immutable genome. All gene values are small ints (numpy-friendly)."""

    op_genes: Tuple[int, ...]      # len == max_depth, values in [0, n_ops)
    conn_genes: Tuple[int, ...]    # node i takes input from conn[i] in [0, i]
    out_gene: int                  # node (1-indexed) feeding the head
    w_bits_gene: int
    a_bits_gene: int
    i_bits_gene: int
    dec_gene: int                  # input decimation index

    # ---------------------------------------------------------------- decode
    def active_nodes(self) -> List[int]:
        """Indices (0-based) of nodes on the input→output path, in order."""
        path: List[int] = []
        node = self.out_gene  # 1-indexed; 0 means "the input" (invalid here)
        while node > 0:
            path.append(node - 1)
            node = self.conn_genes[node - 1]
        return list(reversed(path))

    def phenotype(self, space: SearchSpace = DEFAULT_SPACE) -> List[LayerSpec]:
        """The decoded topology: active ops + the fixed GAP/dense head."""
        specs = [space.ops[self.op_genes[i]] for i in self.active_nodes()]
        specs.append(LayerSpec(kind=GLOBALPOOL))
        specs.append(LayerSpec(kind=DENSE, out_channels=space.n_classes))
        return specs

    def depth(self) -> int:
        """Searchable depth (final GAP+dense excluded, as in the paper)."""
        return len(self.active_nodes())

    def quant(self, space: SearchSpace = DEFAULT_SPACE) -> QuantConfig:
        return space.quant_config(self.w_bits_gene, self.a_bits_gene,
                                  self.i_bits_gene)

    def input_length(self, space: SearchSpace = DEFAULT_SPACE) -> int:
        return space.input_length(self.dec_gene)

    def phenotype_hash(self, space: SearchSpace = DEFAULT_SPACE) -> str:
        """Hash of the *expressed* genes only — mutations that touch dormant
        genes leave this unchanged, letting the search skip re-evaluation
        (the dormant-gene shortcut)."""
        parts = [s.short() for s in self.phenotype(space)]
        parts.append(self.quant(space).short())
        parts.append(f"dec{self.dec_gene}")
        return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]

    def is_valid(self, space: SearchSpace = DEFAULT_SPACE) -> bool:
        """Depth bounds + every layer's spatial shape stays >= 1."""
        d = self.depth()
        if not (space.min_depth <= d <= space.max_depth):
            return False
        try:
            shapes = decode_shapes(self, space)
        except ValueError:
            return False
        return all(l >= 1 for l, _ in shapes)


def decode_shapes(g: Genome, space: SearchSpace = DEFAULT_SPACE
                  ) -> List[Tuple[int, int]]:
    """(length, channels) after each phenotype layer."""
    l, c = g.input_length(space), 2
    shapes = []
    for spec in g.phenotype(space):
        l, c = out_shape(spec, l, c)
        shapes.append((l, c))
    return shapes


# ---------------------------------------------------------------------------
# Random construction / mutation / crossover
# ---------------------------------------------------------------------------

def random_genome(rng: np.random.Generator,
                  space: SearchSpace = DEFAULT_SPACE,
                  max_tries: int = 200) -> Genome:
    for _ in range(max_tries):
        n = space.max_depth
        op = tuple(int(v) for v in rng.integers(0, space.n_ops, n))
        # chain-biased connections: mostly the previous node, sometimes a skip
        conn = []
        for i in range(n):
            conn.append(int(rng.integers(0, i + 1)) if rng.random() < 0.25
                        else i)
        g = Genome(
            op_genes=op,
            conn_genes=tuple(conn),
            out_gene=int(rng.integers(space.min_depth, n + 1)),
            w_bits_gene=int(rng.integers(0, len(space.weight_bits))),
            a_bits_gene=int(rng.integers(0, len(space.act_bits))),
            i_bits_gene=int(rng.integers(0, len(space.input_bits))),
            dec_gene=int(rng.integers(0, len(space.input_decimations))),
        )
        if g.is_valid(space):
            return g
    raise RuntimeError("could not sample a valid genome")


def mutate(
    g: Genome,
    rng: np.random.Generator,
    space: SearchSpace = DEFAULT_SPACE,
    rate: float = 0.1,
    force_active_change: bool = True,
    max_tries: int = 200,
) -> Genome:
    """Point mutation. With ``force_active_change`` the mutation loop repeats
    until the *phenotype* changes (Suganuma's forced mutation for children);
    without it, a mutation may hit only dormant genes (neutral drift)."""
    base_hash = g.phenotype_hash(space)
    for _ in range(max_tries):
        op = list(g.op_genes)
        conn = list(g.conn_genes)
        out = g.out_gene
        wq, aq, iq, dq = (g.w_bits_gene, g.a_bits_gene, g.i_bits_gene,
                          g.dec_gene)
        for i in range(len(op)):
            if rng.random() < rate:
                op[i] = int(rng.integers(0, space.n_ops))
            if rng.random() < rate:
                conn[i] = int(rng.integers(0, i + 1))
        if rng.random() < rate:
            out = int(rng.integers(1, len(op) + 1))
        if rng.random() < rate:
            wq = int(rng.integers(0, len(space.weight_bits)))
        if rng.random() < rate:
            aq = int(rng.integers(0, len(space.act_bits)))
        if rng.random() < rate:
            iq = int(rng.integers(0, len(space.input_bits)))
        if rng.random() < rate:
            dq = int(rng.integers(0, len(space.input_decimations)))
        cand = Genome(tuple(op), tuple(conn), out, wq, aq, iq, dq)
        if not cand.is_valid(space):
            continue
        if force_active_change and cand.phenotype_hash(space) == base_hash:
            continue  # mutation was neutral (dormant genes only) — retry
        return cand
    return g  # give up: return parent unchanged


def crossover(a: Genome, b: Genome, rng: np.random.Generator,
              space: SearchSpace = DEFAULT_SPACE,
              max_tries: int = 50) -> Genome:
    """Single-point crossover over the node slots (biology-inspired ops the
    genetic encoding enables, paper §II-A)."""
    n = len(a.op_genes)
    for _ in range(max_tries):
        cut = int(rng.integers(1, n))
        op = a.op_genes[:cut] + b.op_genes[cut:]
        conn = a.conn_genes[:cut] + b.conn_genes[cut:]
        donor = a if rng.random() < 0.5 else b
        cand = Genome(op, conn, donor.out_gene, donor.w_bits_gene,
                      donor.a_bits_gene, donor.i_bits_gene, donor.dec_gene)
        if cand.is_valid(space):
            return cand
    return a


def describe(g: Genome, space: SearchSpace = DEFAULT_SPACE) -> str:
    """Fig.-4-style textual rendering of a genome's phenotype."""
    lines = [f"Input ({g.input_length(space)},2)  quant={g.quant(space).short()}"]
    l, c = g.input_length(space), 2
    from repro.hwlib.layers import layer_cost
    for spec in g.phenotype(space):
        cost = layer_cost(spec, l, c)
        l, c = cost.out_len, cost.out_channels
        lines.append(f"  {spec.short():>12s} [{cost.params}] ({l},{c})")
    return "\n".join(lines)
