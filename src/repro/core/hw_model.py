"""Hardware-aware objective models — paper §IV, Eqs. (1)-(4), plus the TPU
roofline model used at pod scale (DESIGN.md §2, "beyond-paper extension").

Latency (Eq. 1)::

    t_total = sum_j (n_in,j - 1) * sigma_{j-1} + l_j
    sigma_j = max(l_j, sigma_{j-1})           (pipelined output rate)

Power (Eqs. 2-3)::

    P_total = sum_i alpha_i * P*_idle,i + alpha_i * (t_a,i / t_total) * P*_calc,i

Energy (Eq. 4)::

    E_total = t_total * P_total

alpha_i are the per-layer unrolling (parallelization) factors.  P*_idle and
P*_calc are per-unrolling-unit idle/active power, which the paper estimates
with its FPGA profiler; we provide two calibration profiles:

* ``FPGA_ZU``  — Zynq-UltraScale-class constants, calibrated so Table I/II
  reproductions land in the paper's magnitude range (W, µJ).
* ``TPU_V5E``  — TPU-class constants (pJ/MAC at bf16/int8, 940 MHz), used
  when HALF's objective layer scores candidates for the TPU target.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.genome import Genome
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.hwlib.layers import LayerCost, layer_cost

# ---------------------------------------------------------------------------
# Hardware profiles
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    f_clk: float          # Hz
    p_idle_unit: float    # W per unrolling unit, idling (P*_idle at alpha=1)
    p_calc_unit: float    # W per unrolling unit, computing (P*_calc at alpha=1)
    p_static: float       # W, design-independent static power (in P_total)
    p_board: float        # W, board/peripheral power (NOT in P_total; used
                          # for wall-energy reporting as the paper discusses)
    alpha_cap: int        # max unrolling units the platform can host (resource cap)

    def describe(self) -> str:
        return (f"{self.name}: f={self.f_clk/1e6:.0f}MHz "
                f"P*idle={self.p_idle_unit*1e3:.2f}mW "
                f"P*calc={self.p_calc_unit*1e3:.2f}mW cap={self.alpha_cap}")


# Calibrated so the ECG case study lands in the paper's ranges
# (Table I: 4.4-8.2 W, 841 uJ - 3.1 mJ, 1.4e3-4.8e5 samples/s).
FPGA_ZU = HardwareProfile(
    name="fpga_zu",
    f_clk=300e6,
    p_idle_unit=0.5e-3,
    p_calc_unit=3.0e-3,
    p_static=4.3,   # Table I's P_total floor: PS + PL static + clock trees
    p_board=4.0,
    alpha_cap=4096,
)

# Low-power small FPGA (Pynq-Z1-class, run at reduced clock as in Table II).
FPGA_PYNQ = HardwareProfile(
    name="fpga_pynq",
    f_clk=0.5e6,
    p_idle_unit=0.6e-3,
    p_calc_unit=4.0e-3,
    p_static=0.2,
    p_board=1.6,
    alpha_cap=512,
)

# Large FPGA (ZCU102-class) for the high-throughput domain.
FPGA_ZCU102 = HardwareProfile(
    name="fpga_zcu102",
    f_clk=322e6,
    p_idle_unit=1.1e-3,
    p_calc_unit=7.0e-3,
    p_static=0.8,
    p_board=8.0,
    alpha_cap=16384,
)

# TPU-class profile: one v5e MXU lane-group as the "unrolling unit".
TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    f_clk=940e6,
    p_idle_unit=0.4e-3,
    p_calc_unit=2.2e-3,   # ~0.6 pJ/MAC bf16 + datapath overhead at 940 MHz
    p_static=25.0,
    p_board=60.0,
    alpha_cap=65536,
)

PROFILES = {p.name: p for p in (FPGA_ZU, FPGA_PYNQ, FPGA_ZCU102, TPU_V5E)}

# ---------------------------------------------------------------------------
# TPU pod roofline constants (assignment: v5e numbers)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (we budget one link per chip —
                              # conservative; a 2D-torus axis has 2)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three-term roofline for one compiled step on one mesh."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_collective: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 == perfectly compute-bound."""
        b = self.bound_s
        return self.compute_s / b if b > 0 else 0.0


def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / (chips * PEAK_FLOPS_BF16),
        memory_s=bytes_hbm / (chips * HBM_BW),
        collective_s=bytes_collective / (chips * ICI_BW),
        flops=flops, bytes_hbm=bytes_hbm,
        bytes_collective=bytes_collective, chips=chips,
    )


# ---------------------------------------------------------------------------
# Eq. (1): pipelined latency
# ---------------------------------------------------------------------------


def layer_costs_for(g: Genome, space: SearchSpace = DEFAULT_SPACE
                    ) -> List[LayerCost]:
    l, c = g.input_length(space), 2
    costs = []
    for spec in g.phenotype(space):
        cost = layer_cost(spec, l, c)
        costs.append(cost)
        l, c = cost.out_len, cost.out_channels
    return costs


def resolve_alphas(costs: Sequence[LayerCost], strategy: str,
                   profile: HardwareProfile) -> List[int]:
    """Map an implementation strategy to per-layer unrolling factors.

    * ``min``: alpha_i = 1 (fully folded — paper's min alpha_Impl).
    * ``max``: alpha_i = alpha_max_i, greedily capped by the platform's
      resource budget starting from the pipeline bottleneck (largest l_i),
      which is how the hardware generator allocates parallelism (§III-B).
    """
    if strategy == "min":
        return [1] * len(costs)
    if strategy != "max":
        raise ValueError(strategy)
    alphas = [1] * len(costs)
    budget = profile.alpha_cap - len(costs)
    # repeatedly unroll the current bottleneck stage
    for _ in range(10_000):
        lat = [c.l_cycles / a for c, a in zip(costs, alphas)]
        j = max(range(len(costs)), key=lambda i: lat[i])
        if alphas[j] >= costs[j].alpha_max:
            # bottleneck fully unrolled — unroll next-worst if budget remains
            rest = [i for i in range(len(costs)) if alphas[i] < costs[i].alpha_max]
            if not rest or budget <= 0:
                break
            j = max(rest, key=lambda i: lat[i])
        step = min(max(1, alphas[j]), costs[j].alpha_max - alphas[j], budget)
        if step <= 0:
            break
        alphas[j] += step
        budget -= step
    return alphas


def latency_cycles(costs: Sequence[LayerCost], alphas: Sequence[int]
                   ) -> Tuple[float, List[float]]:
    """Eq. (1) + the sigma recursion. Returns (t_total_cycles, sigmas)."""
    t_total = 0.0
    sigma_prev = 1.0  # input arrives at one value per cycle
    sigmas: List[float] = []
    for cost, a in zip(costs, alphas):
        l_j = cost.l_cycles / a
        t_total += (cost.n_in - 1) * sigma_prev + l_j
        sigma_prev = max(l_j, sigma_prev)
        sigmas.append(sigma_prev)
    return t_total, sigmas


def sample_runtime_cycles(costs: Sequence[LayerCost], alphas: Sequence[int]
                          ) -> float:
    """Pipeline fill (Eq. 1) + drain of the last layer's output stream —
    the steady-state per-sample runtime used for throughput/energy."""
    t_fill, sigmas = latency_cycles(costs, alphas)
    last = costs[-1]
    return t_fill + max(0, last.n_out - 1) * sigmas[-1]


# ---------------------------------------------------------------------------
# Eqs. (2)-(4): power and energy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HwEstimate:
    """Full analytic estimate for (genome, alphas, profile)."""

    t_total_s: float       # per-sample runtime (seconds)
    latency_s: float       # Eq. 1 pipeline latency (seconds)
    p_total_w: float       # Eq. 3 (+ static)
    e_total_j: float       # Eq. 4
    e_wall_j: float        # (P_total + P_board) * t_total — the measurable
    throughput_sps: float  # samples / s (pipelined: 1 sample per drain)
    params: int
    total_macs: int
    alphas: Tuple[int, ...]

    def objectives(self) -> dict:
        return {
            "latency_s": self.latency_s,
            "power_w": self.p_total_w,
            "energy_j": self.e_total_j,
        }


def estimate(g: Genome, *, strategy: str = "min",
             profile: HardwareProfile = FPGA_ZU,
             space: SearchSpace = DEFAULT_SPACE) -> HwEstimate:
    costs = layer_costs_for(g, space)
    alphas = resolve_alphas(costs, strategy, profile)
    t_lat, sigmas = latency_cycles(costs, alphas)
    t_cyc = sample_runtime_cycles(costs, alphas)
    t_s = t_cyc / profile.f_clk

    # Eq. 3 — per-layer active time t_a,i = n_out_i * l_i (cycles)
    p = profile.p_static
    for cost, a in zip(costs, alphas):
        l_i = cost.l_cycles / a
        t_a = cost.n_out * l_i
        duty = min(1.0, t_a / max(t_cyc, 1.0))
        p += a * profile.p_idle_unit + a * duty * profile.p_calc_unit

    # steady-state pipelined throughput: one sample every drain interval
    drain = max(1.0, max(0, costs[-1].n_out - 1) * sigmas[-1]
                + costs[-1].l_cycles / alphas[-1])
    # a new sample can enter once the bottleneck stage is free:
    bottleneck = max(c.l_cycles / a * c.n_out for c, a in zip(costs, alphas))
    interval = max(bottleneck, drain)
    thr = profile.f_clk / interval

    e = t_s * p  # Eq. 4
    return HwEstimate(
        t_total_s=t_s,
        latency_s=t_lat / profile.f_clk,
        p_total_w=p,
        e_total_j=e,
        e_wall_j=(p + profile.p_board) * t_s,
        throughput_sps=thr,
        params=sum(c.params for c in costs),
        total_macs=sum(c.total_macs for c in costs),
        alphas=tuple(alphas),
    )
