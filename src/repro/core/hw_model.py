"""Hardware-aware objective models — paper §IV, Eqs. (1)-(4), plus the TPU
roofline model used at pod scale (DESIGN.md §2, "beyond-paper extension").

Latency (Eq. 1)::

    t_total = sum_j (n_in,j - 1) * sigma_{j-1} + l_j
    sigma_j = max(l_j, sigma_{j-1})           (pipelined output rate)

Power (Eqs. 2-3)::

    P_total = sum_i alpha_i * P*_idle,i + alpha_i * (t_a,i / t_total) * P*_calc,i

Energy (Eq. 4)::

    E_total = t_total * P_total

alpha_i are the per-layer unrolling (parallelization) factors.  P*_idle and
P*_calc are per-unrolling-unit idle/active power, which the paper estimates
with its FPGA profiler; we provide two calibration profiles:

* ``FPGA_ZU``  — Zynq-UltraScale-class constants, calibrated so Table I/II
  reproductions land in the paper's magnitude range (W, µJ).
* ``TPU_V5E``  — TPU-class constants (pJ/MAC at bf16/int8, 940 MHz), used
  when HALF's objective layer scores candidates for the TPU target.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.genome import Genome, PopulationEncoding
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.hwlib.layers import (
    LayerCost,
    LayerCostArrays,
    OpCostTable,
    batch_layer_costs,
    layer_cost,
)

# ---------------------------------------------------------------------------
# Hardware profiles
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    f_clk: float          # Hz
    p_idle_unit: float    # W per unrolling unit, idling (P*_idle at alpha=1)
    p_calc_unit: float    # W per unrolling unit, computing (P*_calc at alpha=1)
    p_static: float       # W, design-independent static power (in P_total)
    p_board: float        # W, board/peripheral power (NOT in P_total; used
                          # for wall-energy reporting as the paper discusses)
    alpha_cap: int        # max unrolling units the platform can host (resource cap)

    def describe(self) -> str:
        return (f"{self.name}: f={self.f_clk/1e6:.0f}MHz "
                f"P*idle={self.p_idle_unit*1e3:.2f}mW "
                f"P*calc={self.p_calc_unit*1e3:.2f}mW cap={self.alpha_cap}")


# Calibrated so the ECG case study lands in the paper's ranges
# (Table I: 4.4-8.2 W, 841 uJ - 3.1 mJ, 1.4e3-4.8e5 samples/s).
FPGA_ZU = HardwareProfile(
    name="fpga_zu",
    f_clk=300e6,
    p_idle_unit=0.5e-3,
    p_calc_unit=3.0e-3,
    p_static=4.3,   # Table I's P_total floor: PS + PL static + clock trees
    p_board=4.0,
    alpha_cap=4096,
)

# Low-power small FPGA (Pynq-Z1-class, run at reduced clock as in Table II).
FPGA_PYNQ = HardwareProfile(
    name="fpga_pynq",
    f_clk=0.5e6,
    p_idle_unit=0.6e-3,
    p_calc_unit=4.0e-3,
    p_static=0.2,
    p_board=1.6,
    alpha_cap=512,
)

# Large FPGA (ZCU102-class) for the high-throughput domain.
FPGA_ZCU102 = HardwareProfile(
    name="fpga_zcu102",
    f_clk=322e6,
    p_idle_unit=1.1e-3,
    p_calc_unit=7.0e-3,
    p_static=0.8,
    p_board=8.0,
    alpha_cap=16384,
)

# TPU-class profile: one v5e MXU lane-group as the "unrolling unit".
TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    f_clk=940e6,
    p_idle_unit=0.4e-3,
    p_calc_unit=2.2e-3,   # ~0.6 pJ/MAC bf16 + datapath overhead at 940 MHz
    p_static=25.0,
    p_board=60.0,
    alpha_cap=65536,
)

PROFILES = {p.name: p for p in (FPGA_ZU, FPGA_PYNQ, FPGA_ZCU102, TPU_V5E)}

# ---------------------------------------------------------------------------
# TPU pod roofline constants (assignment: v5e numbers)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (we budget one link per chip —
                              # conservative; a 2D-torus axis has 2)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three-term roofline for one compiled step on one mesh."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_collective: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 == perfectly compute-bound."""
        b = self.bound_s
        return self.compute_s / b if b > 0 else 0.0


def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / (chips * PEAK_FLOPS_BF16),
        memory_s=bytes_hbm / (chips * HBM_BW),
        collective_s=bytes_collective / (chips * ICI_BW),
        flops=flops, bytes_hbm=bytes_hbm,
        bytes_collective=bytes_collective, chips=chips,
    )


# ---------------------------------------------------------------------------
# Eq. (1): pipelined latency
# ---------------------------------------------------------------------------


def layer_costs_for(g: Genome, space: SearchSpace = DEFAULT_SPACE
                    ) -> List[LayerCost]:
    l, c = g.input_length(space), 2
    costs = []
    for spec in g.phenotype(space):
        cost = layer_cost(spec, l, c)
        costs.append(cost)
        l, c = cost.out_len, cost.out_channels
    return costs


def resolve_alphas(costs: Sequence[LayerCost], strategy: str,
                   profile: HardwareProfile) -> List[int]:
    """Map an implementation strategy to per-layer unrolling factors.

    * ``min``: alpha_i = 1 (fully folded — paper's min alpha_Impl).
    * ``max``: alpha_i = alpha_max_i, greedily capped by the platform's
      resource budget starting from the pipeline bottleneck (largest l_i),
      which is how the hardware generator allocates parallelism (§III-B).
    """
    if strategy == "min":
        return [1] * len(costs)
    if strategy != "max":
        raise ValueError(strategy)
    alphas = [1] * len(costs)
    budget = profile.alpha_cap - len(costs)
    # repeatedly unroll the current bottleneck stage
    for _ in range(10_000):
        lat = [c.l_cycles / a for c, a in zip(costs, alphas)]
        j = max(range(len(costs)), key=lambda i: lat[i])
        if alphas[j] >= costs[j].alpha_max:
            # bottleneck fully unrolled — unroll next-worst if budget remains
            rest = [i for i in range(len(costs)) if alphas[i] < costs[i].alpha_max]
            if not rest or budget <= 0:
                break
            j = max(rest, key=lambda i: lat[i])
        step = min(max(1, alphas[j]), costs[j].alpha_max - alphas[j], budget)
        if step <= 0:
            break
        alphas[j] += step
        budget -= step
    return alphas


def latency_cycles(costs: Sequence[LayerCost], alphas: Sequence[int]
                   ) -> Tuple[float, List[float]]:
    """Eq. (1) + the sigma recursion. Returns (t_total_cycles, sigmas)."""
    t_total = 0.0
    sigma_prev = 1.0  # input arrives at one value per cycle
    sigmas: List[float] = []
    for cost, a in zip(costs, alphas):
        l_j = cost.l_cycles / a
        t_total += (cost.n_in - 1) * sigma_prev + l_j
        sigma_prev = max(l_j, sigma_prev)
        sigmas.append(sigma_prev)
    return t_total, sigmas


def sample_runtime_cycles(costs: Sequence[LayerCost], alphas: Sequence[int]
                          ) -> float:
    """Pipeline fill (Eq. 1) + drain of the last layer's output stream —
    the steady-state per-sample runtime used for throughput/energy."""
    t_fill, sigmas = latency_cycles(costs, alphas)
    last = costs[-1]
    return t_fill + max(0, last.n_out - 1) * sigmas[-1]


# ---------------------------------------------------------------------------
# Eqs. (2)-(4): power and energy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HwEstimate:
    """Full analytic estimate for (genome, alphas, profile)."""

    t_total_s: float       # per-sample runtime (seconds)
    latency_s: float       # Eq. 1 pipeline latency (seconds)
    p_total_w: float       # Eq. 3 (+ static)
    e_total_j: float       # Eq. 4
    e_wall_j: float        # (P_total + P_board) * t_total — the measurable
    throughput_sps: float  # samples / s (pipelined: 1 sample per drain)
    params: int
    total_macs: int
    alphas: Tuple[int, ...]

    def objectives(self) -> dict:
        return {
            "latency_s": self.latency_s,
            "power_w": self.p_total_w,
            "energy_j": self.e_total_j,
        }


def estimate(g: Genome, *, strategy: str = "min",
             profile: HardwareProfile = FPGA_ZU,
             space: SearchSpace = DEFAULT_SPACE) -> HwEstimate:
    costs = layer_costs_for(g, space)
    alphas = resolve_alphas(costs, strategy, profile)
    t_lat, sigmas = latency_cycles(costs, alphas)
    t_cyc = sample_runtime_cycles(costs, alphas)
    t_s = t_cyc / profile.f_clk

    # Eq. 3 — per-layer active time t_a,i = n_out_i * l_i (cycles)
    p = profile.p_static
    for cost, a in zip(costs, alphas):
        l_i = cost.l_cycles / a
        t_a = cost.n_out * l_i
        duty = min(1.0, t_a / max(t_cyc, 1.0))
        p += a * profile.p_idle_unit + a * duty * profile.p_calc_unit

    # steady-state pipelined throughput: one sample every drain interval
    drain = max(1.0, max(0, costs[-1].n_out - 1) * sigmas[-1]
                + costs[-1].l_cycles / alphas[-1])
    # a new sample can enter once the bottleneck stage is free:
    bottleneck = max(c.l_cycles / a * c.n_out for c, a in zip(costs, alphas))
    interval = max(bottleneck, drain)
    thr = profile.f_clk / interval

    e = t_s * p  # Eq. 4
    return HwEstimate(
        t_total_s=t_s,
        latency_s=t_lat / profile.f_clk,
        p_total_w=p,
        e_total_j=e,
        e_wall_j=(p + profile.p_board) * t_s,
        throughput_sps=thr,
        params=sum(c.params for c in costs),
        total_macs=sum(c.total_macs for c in costs),
        alphas=tuple(alphas),
    )


# ---------------------------------------------------------------------------
# Batched population evaluation — the vectorized twin of the scalar path
# above (DESIGN.md §2).  Every reduction walks the layer axis in the same
# left-to-right order as the scalar loops so results match bit-for-bit.
# ---------------------------------------------------------------------------


def _bit_length(x: np.ndarray) -> np.ndarray:
    """Element-wise ``int.bit_length`` (exact for 0 <= x < 2**53)."""
    return np.frexp(x.astype(np.float64))[1]


@functools.lru_cache(maxsize=8)
def table_for_space(space: SearchSpace = DEFAULT_SPACE) -> OpCostTable:
    """Op catalogue + GAP/dense head sentinels as an :class:`OpCostTable`
    (ids ``n_ops`` and ``n_ops + 1`` — see PopulationEncoding.phenotype_ops)."""
    return OpCostTable.from_specs(tuple(space.ops) + space.head_specs())


def population_layer_costs(enc: PopulationEncoding,
                           space: SearchSpace = DEFAULT_SPACE
                           ) -> LayerCostArrays:
    """Batched :func:`layer_costs_for` over an encoded population."""
    ops, valid, _ = enc.phenotype_ops(space)
    return batch_layer_costs(table_for_space(space), ops, valid,
                             enc.input_lengths(space))


@dataclasses.dataclass(frozen=True)
class AlphaEventTable:
    """Budget-independent precomputation of :func:`batch_resolve_alphas`.

    Everything about the doubling-event merge except the platform's
    resource budget: per-layer event counts/first rounds, the boundary-round
    event order, and the closed-form round totals ``S(r)`` tabulated for
    every round.  One table serves every :class:`HardwareProfile` scoring
    the same population (``MultiPlatformBackend``): a profile's α factors
    then cost one ``(N, R)`` budget comparison plus the boundary-round
    step, instead of the full binary search (DESIGN.md §10).
    """

    k_count: np.ndarray   # (N, T) doubling events per layer
    d: np.ndarray         # (N, T) first round of each layer
    amax: np.ndarray      # (N, T) per-layer unrolling caps
    order: np.ndarray     # (N, T) boundary event order (M-desc, index-asc)
    s_table: np.ndarray   # (N, R) budget units consumed by rounds 0..r


def build_alpha_events(costs: LayerCostArrays) -> AlphaEventTable:
    """Tabulate the doubling-event structure of a population's layers.

    ``s_table[:, r]`` is the closed-form round total ``S(r)`` (the binary
    search's ``total_after``) evaluated for every round up front — R is
    small (≈ ``log2(alpha_cap)``-scale), so the full table costs a handful
    of ``(N, T)`` integer passes and then serves every profile's budget
    query as one comparison.
    """
    amax = costs.alpha_max
    n, t_pad = amax.shape
    m = np.maximum(costs.macs_per_out, 1)
    k_count = _bit_length(amax - 1)
    theta = m.max(axis=1, keepdims=True)
    d = _bit_length((theta - 1) // m)
    big_m = m << d                                # in [theta, 2*theta)
    # event order: M-descending, ties to the lower layer index.  Dead and
    # finished events carry step 0 at query time, so they are harmless
    # wherever they land — the order never depends on the budget.
    key = (2 * theta - big_m) * t_pad + np.arange(t_pad)
    order = np.argsort(key, axis=1)

    n_rounds = int((d + k_count).max(initial=0)) + 2
    s_table = np.empty((n, n_rounds), dtype=np.int64)
    for r in range(n_rounds):
        c = np.clip(r - d + 1, 0, k_count)
        s_table[:, r] = (np.minimum(np.left_shift(1, c), amax) - 1) \
            .sum(axis=1)
    return AlphaEventTable(k_count=k_count, d=d, amax=amax, order=order,
                           s_table=s_table)


def _resolve_max_from_events(costs: LayerCostArrays,
                             profile: HardwareProfile,
                             ev: AlphaEventTable) -> np.ndarray:
    """``max``-strategy α resolution against a precomputed event table.

    Identical factors to the binary-search path, layer for layer: both
    compute the exact crossing round ``min{r : S(r) > budget}`` (here a
    table lookup) and apply the same boundary-round prefix clip.
    """
    budget = (profile.alpha_cap - costs.n_layers).astype(np.int64)
    over = ev.s_table > budget[:, None]
    # rows that never cross the budget finish every event; any round past
    # the table leaves the boundary empty, matching the search's terminal lo
    lo = np.where(over.any(axis=1), over.argmax(axis=1),
                  ev.s_table.shape[1])
    c_prev = np.clip(lo[:, None] - ev.d, 0, ev.k_count)
    a_prev = np.minimum(np.left_shift(1, c_prev), ev.amax)
    b_rem = np.maximum(budget - (a_prev - 1).sum(axis=1), 0)
    k = lo[:, None] - ev.d
    alive = (k >= 0) & (k < ev.k_count)
    a_pre = np.left_shift(1, np.where(alive, k, 0))
    step = np.where(alive, np.minimum(a_pre, ev.amax - a_pre), 0)
    step_sorted = np.take_along_axis(step, ev.order, axis=1)
    cum = np.cumsum(step_sorted, axis=1)
    applied = np.clip(b_rem[:, None] - (cum - step_sorted), 0, step_sorted)
    np.put_along_axis(step, ev.order, applied, axis=1)
    return a_prev + step


class SharedPopulationEval:
    """Per-population intermediates shared across platform evaluations.

    ``MultiPlatformBackend`` decodes/tabulates a population once and hands
    this object to each member backend; the lazily cached pieces (α event
    table, fully-folded latency recursion, per-profile max-α factors) are
    bit-identical to what each backend would have computed alone.
    """

    def __init__(self, costs: LayerCostArrays):
        self.costs = costs
        self._max_alphas: dict = {}   # alpha_cap -> (N, T) factors

    @functools.cached_property
    def alpha_events(self) -> AlphaEventTable:
        return build_alpha_events(self.costs)

    @functools.cached_property
    def min_latency(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(t_total, sigmas)`` of the fully folded (α=1) datapath."""
        return _latency_from_ratio(self.costs, self.costs.l_cycles)

    def max_alphas(self, profile: HardwareProfile) -> np.ndarray:
        """Cached ``max``-strategy factors for one profile (resolved from
        the shared event table on first use).  The cache keys on the
        resource budget (``alpha_cap``) — the only profile field the
        resolution depends on."""
        cached = self._max_alphas.get(int(profile.alpha_cap))
        if cached is None:
            cached = _resolve_max_from_events(self.costs, profile,
                                              self.alpha_events)
            self._max_alphas[int(profile.alpha_cap)] = cached
        return cached

    @functools.cached_property
    def min_cycles(self) -> "MinCycleQuantities":
        """Profile-independent cycle-domain quantities of the fully folded
        (α=1) datapath, shared by every member's ``min``-strategy estimate."""
        return _min_cycle_quantities(self.costs, self.min_latency)

    @functools.cached_property
    def param_totals(self) -> np.ndarray:
        return np.where(self.costs.valid, self.costs.params, 0).sum(axis=1)

    @functools.cached_property
    def mac_totals(self) -> np.ndarray:
        return np.where(self.costs.valid, self.costs.total_macs, 0) \
            .sum(axis=1)


def batch_resolve_alphas(costs: LayerCostArrays, strategy: str,
                         profile: HardwareProfile,
                         events: Optional[AlphaEventTable] = None
                         ) -> np.ndarray:
    """Vectorized :func:`resolve_alphas`: ``(N, T)`` unrolling factors.

    The scalar ``max`` loop repeatedly steps the highest-latency layer that
    still has unrolling capacity (the "rest" branch merely skips exhausted
    layers), and each step at most doubles that layer's factor.  A layer's
    successive pick priorities ``l, l/2, l/4, ...`` are strictly decreasing,
    so the loop consumes the *descending merge of per-layer doubling
    events*: event ``(i, k)`` has priority ``l_i / 2^k`` and step size
    ``min(2^k, alpha_max_i - 2^k)`` (the final partial step to the cap),
    ties resolving to the lower layer index (first-max ``argmax``).

    That merge has closed *round* structure.  With ``Θ = max_i l_i`` and
    ``d_i = ceil(log2(Θ / l_i))``, event ``(i, k)`` lands in round
    ``r = k + d_i``; scaled priorities ``M_i = l_i · 2^{d_i} ∈ [Θ, 2Θ)``
    make every round's priority range ``[Θ/2^r, 2Θ/2^r)`` strictly above
    the next round's, and each layer appears at most once per round.  So:

    1. after ``r`` whole rounds, layer ``i`` has applied its first
       ``c_i(r) = clip(r - d_i + 1, 0, K_i)`` events, which telescope to
       ``min(2^{c_i}, alpha_max_i) - 1`` budget units — giving a closed-form
       monotone total ``S(r)``;
    2. the budget-crossing round ``r*`` (smallest ``r`` with
       ``S(r) > budget``) is found by a ~6-step vectorized binary search;
    3. inside round ``r*``, events run in ``M_i``-descending order (ties by
       layer index): one tiny ``(N, T)`` sort + cumulative clip applies the
       boundary, including the scalar loop's final partial budget step.

    All arithmetic is integer-exact (the scalar loop's float priority
    comparisons are exact too: integer MACs divided by powers of two), so
    the factors are identical to the scalar loop, genome for genome —
    enforced by tests/test_cost_backend_parity.py.

    The inline binary-search body below is the *reference twin* of the
    shared event-table fast path (:func:`_resolve_max_from_events`): the
    boundary-round block is intentionally duplicated between them, and
    tests/test_multi_platform.py pins the two to exact equality across
    every profile and tight-cap boundary case — edit one, sweep both.
    """
    n, t_pad = costs.l_cycles.shape
    if strategy == "min":
        return np.ones((n, t_pad), np.int64)
    if strategy != "max":
        raise ValueError(strategy)
    if events is not None:
        return _resolve_max_from_events(costs, profile, events)
    amax = costs.alpha_max
    budget = (profile.alpha_cap - costs.n_layers).astype(np.int64)
    m = np.maximum(costs.macs_per_out, 1)        # padded slots -> 1
    k_count = _bit_length(amax - 1)              # events per layer; 0 if
    theta = m.max(axis=1, keepdims=True)         # amax == 1 (padded slots)
    d = _bit_length((theta - 1) // m)            # first round of layer i

    def total_after(r):
        """S(r): budget units consumed by rounds 0..r, closed form."""
        c = np.clip(r - d + 1, 0, k_count)
        return (np.minimum(np.left_shift(1, c), amax) - 1).sum(axis=1)

    # binary search the crossing round r* = min{r : S(r) > budget}
    lo = np.zeros(n, np.int64)
    hi = np.full(n, int((d + k_count).max()) + 1, np.int64)
    for _ in range(max(1, int(hi[0]).bit_length())):
        mid = (lo + hi) >> 1
        over = total_after(mid[:, None]) > budget
        hi = np.where(over, mid, hi)
        lo = np.where(over, lo, mid + 1)

    # state after the last whole round (r* - 1)
    c_prev = np.clip(lo[:, None] - d, 0, k_count)
    a_prev = np.minimum(np.left_shift(1, c_prev), amax)
    b_rem = np.maximum(budget - (a_prev - 1).sum(axis=1), 0)

    # boundary round r*: at most one event per layer, M-descending order
    k = lo[:, None] - d
    alive = (k >= 0) & (k < k_count)
    a_pre = np.left_shift(1, np.where(alive, k, 0))
    step = np.where(alive, np.minimum(a_pre, amax - a_pre), 0)
    big_m = m << d                                # in [theta, 2*theta)
    key = (2 * theta - big_m) * t_pad + np.arange(t_pad)
    key[~alive] = np.iinfo(np.int64).max          # dead events sort last
    order = np.argsort(key, axis=1)
    step_sorted = np.take_along_axis(step, order, axis=1)
    cum = np.cumsum(step_sorted, axis=1)
    applied = np.clip(b_rem[:, None] - (cum - step_sorted), 0, step_sorted)
    np.put_along_axis(step, order, applied, axis=1)  # unsort in place
    return a_prev + step


def _latency_from_ratio(costs: LayerCostArrays, l_over_a: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
    n, t_pad = costs.l_cycles.shape
    t_total = np.zeros(n)
    sigma_prev = np.ones(n)  # input arrives at one value per cycle
    sigmas = np.zeros((n, t_pad))
    for t in range(t_pad):
        v = costs.valid[:, t]
        l_j = l_over_a[:, t]
        # parenthesized to round exactly like the scalar `t_total += ...`
        t_total = np.where(
            v, t_total + ((costs.n_in[:, t] - 1) * sigma_prev + l_j), t_total)
        sigma_prev = np.where(v, np.maximum(l_j, sigma_prev), sigma_prev)
        sigmas[:, t] = sigma_prev
    return t_total, sigmas


def batch_latency_cycles(costs: LayerCostArrays, alphas: np.ndarray
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized Eq. (1): ``(t_total (N,), sigmas (N, T))``."""
    return _latency_from_ratio(costs, costs.l_cycles / alphas)


def batch_sample_runtime_cycles(costs: LayerCostArrays, alphas: np.ndarray
                                ) -> np.ndarray:
    """Vectorized :func:`sample_runtime_cycles` (fill + drain)."""
    t_fill, sigmas = batch_latency_cycles(costs, alphas)
    ar, last = np.arange(len(costs)), costs.last_index
    return t_fill + np.maximum(0, costs.n_out[ar, last] - 1) * sigmas[ar, last]


@dataclasses.dataclass(frozen=True)
class MinCycleQuantities:
    """Cycle-domain quantities of the fully folded (α=1) datapath.

    Everything here is independent of the :class:`HardwareProfile` (clock
    and power constants enter later), so one instance serves every platform
    scoring the same population (``SharedPopulationEval.min_cycles``).
    """

    t_lat: np.ndarray     # (N,) Eq. 1 pipeline latency, cycles
    sigmas: np.ndarray    # (N, T) output-rate recursion
    t_cyc: np.ndarray     # (N,) per-sample runtime (fill + drain), cycles
    duty: np.ndarray      # (N, T) per-layer duty fractions (Eq. 3)
    interval: np.ndarray  # (N,) steady-state sample interval, cycles


def _min_cycle_quantities(costs: LayerCostArrays,
                          min_latency: Tuple[np.ndarray, np.ndarray]
                          ) -> MinCycleQuantities:
    t_lat, sigmas = min_latency
    ar, last = np.arange(len(costs)), costs.last_index
    n_out_last = costs.n_out[ar, last]
    t_cyc = t_lat + np.maximum(0, n_out_last - 1) * sigmas[ar, last]
    duty = np.minimum(1.0, costs.n_out * costs.l_cycles
                      / np.maximum(t_cyc, 1.0)[:, None])
    drain = np.maximum(1.0, np.maximum(0, n_out_last - 1) * sigmas[ar, last]
                       + costs.l_cycles[ar, last])
    bottleneck = np.max(
        np.where(costs.valid, costs.l_cycles * costs.n_out, -np.inf), axis=1)
    return MinCycleQuantities(t_lat=t_lat, sigmas=sigmas, t_cyc=t_cyc,
                              duty=duty,
                              interval=np.maximum(bottleneck, drain))


@dataclasses.dataclass(frozen=True)
class BatchHwEstimate:
    """:class:`HwEstimate` for a whole population — every field an array."""

    t_total_s: np.ndarray       # (N,)
    latency_s: np.ndarray       # (N,)
    p_total_w: np.ndarray       # (N,)
    e_total_j: np.ndarray       # (N,)
    e_wall_j: np.ndarray        # (N,)
    throughput_sps: np.ndarray  # (N,)
    params: np.ndarray          # (N,) int64
    total_macs: np.ndarray      # (N,) int64
    alphas: np.ndarray          # (N, T) int64, padded slots == 1
    valid: np.ndarray           # (N, T) bool

    def __len__(self) -> int:
        return self.t_total_s.shape[0]

    def row(self, i: int) -> HwEstimate:
        """One genome's estimate as the scalar dataclass (for reporting)."""
        nl = int(self.valid[i].sum())
        return HwEstimate(
            t_total_s=float(self.t_total_s[i]),
            latency_s=float(self.latency_s[i]),
            p_total_w=float(self.p_total_w[i]),
            e_total_j=float(self.e_total_j[i]),
            e_wall_j=float(self.e_wall_j[i]),
            throughput_sps=float(self.throughput_sps[i]),
            params=int(self.params[i]),
            total_macs=int(self.total_macs[i]),
            alphas=tuple(int(a) for a in self.alphas[i, :nl]),
        )


def batch_estimate(costs: LayerCostArrays, *, strategy: str = "min",
                   profile: HardwareProfile = FPGA_ZU,
                   shared: Optional[SharedPopulationEval] = None
                   ) -> BatchHwEstimate:
    """Vectorized :func:`estimate` over pre-tabulated population costs.

    Pass ``shared`` (a :class:`SharedPopulationEval` over the same
    ``costs``) to reuse the platform-independent intermediates across
    several profiles — results are bit-identical either way.
    """
    n, t_pad = costs.l_cycles.shape
    ar = np.arange(n)
    last = costs.last_index
    if strategy == "min":
        # fully folded: every factor is 1 and the cycle-domain quantities
        # are profile-independent (sharable across platforms)
        alphas = np.ones((n, t_pad), np.int64)
        mc = shared.min_cycles if shared is not None else \
            _min_cycle_quantities(costs,
                                  _latency_from_ratio(costs, costs.l_cycles))
        t_lat, sigmas, t_cyc = mc.t_lat, mc.sigmas, mc.t_cyc
        duty_all, interval = mc.duty, mc.interval
    elif strategy == "max":
        alphas = shared.max_alphas(profile) if shared is not None \
            else batch_resolve_alphas(costs, strategy, profile)
        l_over_a = costs.l_cycles / alphas
        t_lat, sigmas = _latency_from_ratio(costs, l_over_a)
        n_out_last = costs.n_out[ar, last]
        t_cyc = t_lat + np.maximum(0, n_out_last - 1) * sigmas[ar, last]
        duty_all = np.minimum(1.0, costs.n_out * l_over_a
                              / np.maximum(t_cyc, 1.0)[:, None])
        drain = np.maximum(1.0, np.maximum(0, n_out_last - 1)
                           * sigmas[ar, last] + l_over_a[ar, last])
        bottleneck = np.max(
            np.where(costs.valid, l_over_a * costs.n_out, -np.inf), axis=1)
        interval = np.maximum(bottleneck, drain)
    else:
        raise ValueError(strategy)
    t_s = t_cyc / profile.f_clk

    # Eq. 3 — accumulated layer-by-layer in scalar order
    p = np.full(n, profile.p_static)
    for t in range(t_pad):
        v = costs.valid[:, t]
        a = alphas[:, t]
        p = np.where(v, p + (a * profile.p_idle_unit
                             + a * duty_all[:, t] * profile.p_calc_unit), p)

    thr = profile.f_clk / interval

    e = t_s * p  # Eq. 4
    if shared is not None:
        params_tot, macs_tot = shared.param_totals, shared.mac_totals
    else:
        params_tot = np.where(costs.valid, costs.params, 0).sum(axis=1)
        macs_tot = np.where(costs.valid, costs.total_macs, 0).sum(axis=1)
    return BatchHwEstimate(
        t_total_s=t_s,
        latency_s=t_lat / profile.f_clk,
        p_total_w=p,
        e_total_j=e,
        e_wall_j=(p + profile.p_board) * t_s,
        throughput_sps=thr,
        params=params_tot,
        total_macs=macs_tot,
        alphas=alphas,
        valid=costs.valid,
    )


def estimate_population(enc: PopulationEncoding, *, strategy: str = "min",
                        profile: HardwareProfile = FPGA_ZU,
                        space: SearchSpace = DEFAULT_SPACE) -> BatchHwEstimate:
    """Batched :func:`estimate`: decode + tabulate + Eq. 1-4 in one pass."""
    return batch_estimate(population_layer_costs(enc, space),
                          strategy=strategy, profile=profile)
