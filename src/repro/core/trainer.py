"""Expensive-objective evaluation: train a candidate, measure detection and
false-alarm rates (paper §VI: hard limits 90 % detection / 20 % false alarm).

Candidates are small 1D-CNNs (hwlib layers decoded from a genome) trained
with AdamW on the synthetic ECG dataset.  Quantization-aware training applies
the genome's fake-quant config so the expensive objectives reflect the
quantized model that will be deployed.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.genome import Genome
from repro.core.search_space import DEFAULT_SPACE, SearchSpace
from repro.hwlib.layers import LayerSpec, apply_layer, init_layer, out_shape
from repro.hwlib.quant import QuantConfig, fake_quant, quantize_layer_params
from repro.optim import adamw, apply_updates, clip_by_global_norm


@dataclasses.dataclass
class TrainResult:
    detection_rate: float
    false_alarm_rate: float
    val_loss: float
    steps: int

    def meets_constraints(self, det_min: float = 0.90,
                          fa_max: float = 0.20) -> bool:
        return self.detection_rate >= det_min and self.false_alarm_rate <= fa_max


def init_candidate(rng: jax.Array, specs: Sequence[LayerSpec], in_ch: int = 2
                   ) -> List[Dict[str, Any]]:
    params = []
    c = in_ch
    keys = jax.random.split(rng, len(specs))
    for k, spec in zip(keys, specs):
        params.append(init_layer(k, spec, c))
        if spec.out_channels:  # convs and dense change the channel count
            c = spec.out_channels
    return params


def forward(params: Sequence[Dict[str, Any]], specs: Sequence[LayerSpec],
            x: jnp.ndarray, quant: QuantConfig | None = None,
            train: bool = False) -> jnp.ndarray:
    """Full candidate forward. x: (B, L, 2) -> logits (B, n_classes)."""
    h = x
    if quant is not None:
        h = fake_quant(h, quant.input_bits)
    for p, s in zip(params, specs):
        if quant is not None:
            p = quantize_layer_params(p, s, quant)
        h = apply_layer(p, s, h, train=train)
        if quant is not None and s.kind == "dwsep_conv":
            h = fake_quant(h, quant.act_bits)
    return h


def refresh_bn_stats(params: List[Dict[str, Any]],
                     specs: Sequence[LayerSpec], x: jnp.ndarray,
                     quant: QuantConfig | None = None) -> List[Dict[str, Any]]:
    """BN re-estimation: recompute each BN layer's running stats from a
    calibration batch under the *current* weights (functionally — returns a
    new params list).  Standard practice in functional JAX training loops;
    the stats are what batchnorm-folding consumes at compile time."""

    @jax.jit
    def _refresh(params, x):
        new_params = []
        h = x
        if quant is not None:
            h = fake_quant(h, quant.input_bits)
        for p, s in zip(params, specs):
            q = quantize_layer_params(p, s, quant) if quant is not None else p
            if s.kind == "dwsep_conv" and "bn_scale" in p:
                from repro.hwlib.layers import _depthwise_conv1d
                pre = jnp.einsum(
                    "blc,cd->bld",
                    _depthwise_conv1d(h, q["dw"], s.stride), q["pw"]) + q["b"]
                p = dict(p)
                p["bn_mean"] = jnp.mean(pre, axis=(0, 1))
                p["bn_var"] = jnp.var(pre, axis=(0, 1))
            new_params.append(p)
            q2 = dict(quantize_layer_params(p, s, quant)) if quant is not None else p
            h = apply_layer(q2, s, h, train=False)
            if quant is not None and s.kind == "dwsep_conv":
                h = fake_quant(h, quant.act_bits)
        return new_params

    return _refresh(list(params), x)


def _loss_fn(params, specs, quant, x, y):
    logits = forward(params, specs, x, quant, train=True)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


def make_train_step(specs: Sequence[LayerSpec], quant: QuantConfig | None,
                    opt):
    @jax.jit
    def step(params, opt_state, x, y):
        loss, grads = jax.value_and_grad(_loss_fn)(params, specs, quant, x, y)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return step


def evaluate(params, specs, quant, x: np.ndarray, y: np.ndarray,
             batch: int = 256) -> Tuple[float, float, float]:
    """(detection_rate, false_alarm_rate, mean_nll) on a dataset."""
    @jax.jit
    def fwd(xb):
        return forward(params, specs, xb, quant, train=False)

    preds, nll_sum = [], 0.0
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i:i + batch])
        logits = fwd(xb)
        logp = jax.nn.log_softmax(logits)
        yb = jnp.asarray(y[i:i + batch])
        nll_sum += float(-jnp.take_along_axis(
            logp, yb[:, None], axis=1).sum())
        preds.append(np.asarray(jnp.argmax(logits, axis=-1)))
    pred = np.concatenate(preds)
    pos, neg = y == 1, y == 0
    det = float((pred[pos] == 1).mean()) if pos.any() else 0.0
    fa = float((pred[neg] == 1).mean()) if neg.any() else 1.0
    return det, fa, nll_sum / len(x)


def train_candidate(
    genome: Genome,
    data_train: Tuple[np.ndarray, np.ndarray],
    data_val: Tuple[np.ndarray, np.ndarray],
    *,
    space: SearchSpace = DEFAULT_SPACE,
    steps: int = 300,
    batch_size: int = 64,
    lr: float = 3e-3,
    seed: int = 0,
    use_quant: bool = True,
) -> TrainResult:
    """Train one candidate and return the expensive objectives.

    The dataset arrives at max resolution (decimation 16); the genome's
    decimation gene subsamples further if it asks for a shorter input.
    """
    specs = genome.phenotype(space)
    quant = genome.quant(space) if use_quant else None
    want_len = genome.input_length(space)

    def prep(x):
        if x.shape[1] == want_len:
            return x
        stride = x.shape[1] // want_len
        return x[:, : want_len * stride : stride]

    x_tr, y_tr = prep(data_train[0]), data_train[1]
    x_va, y_va = prep(data_val[0]), data_val[1]

    rng = jax.random.PRNGKey(seed)
    params = init_candidate(rng, specs)
    opt = adamw(lr, b1=0.9, b2=0.99, weight_decay=1e-4)
    opt_state = opt.init(params)
    step_fn = make_train_step(specs, quant, opt)

    nrng = np.random.default_rng(seed)
    n = len(x_tr)
    for s in range(steps):
        idx = nrng.integers(0, n, batch_size)
        params, opt_state, _ = step_fn(params, opt_state,
                                       jnp.asarray(x_tr[idx]),
                                       jnp.asarray(y_tr[idx]))
    # BN re-estimation on a calibration slice before deployment-mode eval
    calib = jnp.asarray(x_tr[nrng.integers(0, n, min(256, n))])
    params = refresh_bn_stats(params, specs, calib, quant)
    det, fa, nll = evaluate(params, specs, quant, x_va, y_va)
    return TrainResult(detection_rate=det, false_alarm_rate=fa,
                       val_loss=nll, steps=steps)
